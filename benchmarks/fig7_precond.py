"""Paper Fig. 7 / Tab. 1: preconditioner comparison on Wishart-correlated
random weights. Reports the true activation loss E‖WX−BAX‖² per variant
(rootcov must win; cov close; diagonal variants worse; identity worst-ish)."""
from __future__ import annotations

import time

import numpy as np
import jax.numpy as jnp

from benchmarks.common import emit
from repro.core.precond import activation_stats, preconditioner
from repro.core.svd import weighted_svd


def run(d=256, dp=256, l=2048, ratio=0.5, seed=0):
    rng = np.random.default_rng(seed)
    W = jnp.asarray(rng.normal(size=(dp, d)) / np.sqrt(d), jnp.float32)
    # Wishart-style covariance with 0.9 off-diagonal decay (paper setup)
    Cd = 0.9 ** np.abs(np.subtract.outer(np.arange(d), np.arange(d)))
    X = jnp.asarray(np.linalg.cholesky(Cd + 1e-9 * np.eye(d))
                    @ rng.normal(size=(d, l)), jnp.float32)
    C, _ = activation_stats(X)
    r = int(ratio * min(d, dp))
    base = float(jnp.sum((W @ X) ** 2))
    out = {}
    for kind in ("identity", "hessian", "l1", "l2", "cov", "rootcov"):
        t0 = time.perf_counter()
        P = preconditioner(kind, X=X, C=C)
        lr = weighted_svd(W, P, r, junction="left")
        us = (time.perf_counter() - t0) * 1e6
        R = (W - lr.reconstruct()) @ X
        loss = float(jnp.sum(R * R)) / base
        out[kind] = loss
        emit(f"fig7_precond_{kind}", us, f"rel_loss={loss:.5f}")
    assert out["rootcov"] == min(out.values()), out
    return out


if __name__ == "__main__":
    run()
