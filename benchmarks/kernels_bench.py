"""Kernel microbenchmarks (interpret-mode correctness + wall time on this
host; TPU wall-time is the deployment measurement)."""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from benchmarks.common import emit, time_call
from repro.kernels import ops, ref


def run():
    rng = np.random.default_rng(0)
    # latent_matmul at a realistic layer size
    M, d, r, N = 512, 1024, 768, 1024
    x = jnp.asarray(rng.normal(size=(M, d)), jnp.float32)
    a2t = jnp.asarray(rng.normal(size=(d - r, r)) / np.sqrt(d - r), jnp.float32)
    b = jnp.asarray(rng.normal(size=(r, N)) / np.sqrt(r), jnp.float32)
    us = time_call(lambda: ops.latent_matmul(x, a2t, b, interpret=True))
    err = float(jnp.max(jnp.abs(
        ops.latent_matmul(x, a2t, b, interpret=True)
        - ref.latent_matmul_ref(x, a2t, b))))
    flops = 2 * M * ((d - r) * r + r * N)
    emit("kernel_latent_matmul", us, f"flops={flops};err={err:.2e}")

    B, H, S, rk, rv = 4, 16, 1024, 128, 128
    qt = jnp.asarray(rng.normal(size=(B, H, rk)), jnp.float32)
    ck = jnp.asarray(rng.normal(size=(B, S, rk)), jnp.float32)
    cv = jnp.asarray(rng.normal(size=(B, S, rv)), jnp.float32)
    vl = jnp.full((B,), S, jnp.int32)
    us = time_call(lambda: ops.mla_decode(qt, ck, cv, vl, scale=0.1,
                                          interpret=True))
    err = float(jnp.max(jnp.abs(
        ops.mla_decode(qt, ck, cv, vl, scale=0.1, interpret=True)
        - ref.mla_decode_ref(qt, ck, cv, vl, scale=0.1))))
    emit("kernel_mla_decode", us,
         f"cache_bytes={B * S * (rk + rv) * 4};err={err:.2e}")

    B, S, Hh, P, G, Nn = 2, 256, 8, 32, 1, 32
    xs = jnp.asarray(rng.normal(size=(B, S, Hh, P)) * 0.5, jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.2, size=(B, S, Hh)), jnp.float32)
    A = jnp.asarray(-rng.uniform(0.5, 2.0, size=(Hh,)), jnp.float32)
    Bm = jnp.asarray(rng.normal(size=(B, S, G, Nn)) * 0.3, jnp.float32)
    Cm = jnp.asarray(rng.normal(size=(B, S, G, Nn)) * 0.3, jnp.float32)
    us = time_call(lambda: ops.ssd_scan(xs, dt, A, Bm, Cm, chunk=64,
                                        interpret=True))
    y_k, st_k = ops.ssd_scan(xs, dt, A, Bm, Cm, chunk=64, interpret=True)
    y_r, st_r = ref.ssd_scan_ref(xs, dt, A, Bm, Cm)
    err = float(jnp.max(jnp.abs(y_k - y_r)))
    emit("kernel_ssd_scan", us, f"err={err:.2e}")


if __name__ == "__main__":
    run()
