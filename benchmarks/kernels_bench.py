"""Kernel microbenchmarks.

Headline numbers time the JITTED path on the active backend (``ops.*``
with the backend-default lowering — real Pallas kernels on TPU).
Interpret mode is used ONLY for the correctness cross-check against the
jnp oracles, never for the reported wall time.
"""
from __future__ import annotations

import dataclasses

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_call
from repro.kernels import ops, ref


def _err(a, b):
    return float(jnp.max(jnp.abs(jnp.asarray(a, jnp.float32)
                                 - jnp.asarray(b, jnp.float32))))


def run(quick: bool = False):
    backend = jax.default_backend()
    rng = np.random.default_rng(0)

    # latent_matmul at a realistic layer size
    M, d, r, N = (256, 512, 384, 512) if quick else (512, 1024, 768, 1024)
    x = jnp.asarray(rng.normal(size=(M, d)), jnp.float32)
    a2t = jnp.asarray(rng.normal(size=(d - r, r)) / np.sqrt(d - r), jnp.float32)
    b = jnp.asarray(rng.normal(size=(r, N)) / np.sqrt(r), jnp.float32)
    us = time_call(lambda: ops.latent_matmul(x, a2t, b))
    err = _err(ops.latent_matmul(x, a2t, b, interpret=True),
               ref.latent_matmul_ref(x, a2t, b))
    flops = 2 * M * ((d - r) * r + r * N)
    emit("kernel_latent_matmul", us,
         f"flops={flops};err={err:.2e};backend={backend}")

    # mla_decode over a latent cache
    B, H, S, rk, rv = (2, 8, 256, 64, 64) if quick else (4, 16, 1024, 128, 128)
    qt = jnp.asarray(rng.normal(size=(B, H, rk)), jnp.float32)
    ck = jnp.asarray(rng.normal(size=(B, S, rk)), jnp.float32)
    cv = jnp.asarray(rng.normal(size=(B, S, rv)), jnp.float32)
    vl = jnp.full((B,), S, jnp.int32)
    us = time_call(lambda: ops.mla_decode(qt, ck, cv, vl, scale=0.1))
    err = _err(ops.mla_decode(qt, ck, cv, vl, scale=0.1, interpret=True),
               ref.mla_decode_ref(qt, ck, cv, vl, scale=0.1))
    emit("kernel_mla_decode", us,
         f"cache_bytes={B * S * (rk + rv) * 4};err={err:.2e};backend={backend}")

    # grouped decode with fused value decompression
    G, R, Dh = (2, H // 2, 32)
    qtg = qt.reshape(B, G, R, rk)
    bv = jnp.asarray(rng.normal(size=(G, rv, Dh)) / np.sqrt(rv), jnp.float32)
    us = time_call(lambda: ops.mla_decode_grouped(qtg, ck, cv, bv, vl,
                                                  scale=0.1))
    err = _err(ops.mla_decode_grouped(qtg, ck, cv, bv, vl, scale=0.1,
                                      interpret=True),
               ref.mla_decode_grouped_ref(qtg, ck, cv, bv, vl, scale=0.1))
    emit("kernel_mla_decode_grouped", us, f"err={err:.2e};backend={backend}")

    # ring (sliding-window) grouped decode: wrapped (start, length)
    # validity over the same latent cache — the windowed serving path
    start = jnp.asarray(rng.integers(0, S, size=(B,)), jnp.int32)
    length = jnp.full((B,), max(S // 2, 1), jnp.int32)
    us = time_call(lambda: ops.mla_decode_grouped_ring(
        qtg, ck, cv, bv, start, length, scale=0.1))
    err = _err(ops.mla_decode_grouped_ring(qtg, ck, cv, bv, start, length,
                                           scale=0.1, interpret=True),
               ref.mla_decode_grouped_ring_ref(qtg, ck, cv, bv, start,
                                               length, scale=0.1))
    emit("kernel_mla_decode_grouped_ring", us,
         f"window={max(S // 2, 1)};err={err:.2e};backend={backend}")

    # int8-cache grouped decode: in-kernel dequant vs the fp kernel at
    # the SAME shapes — the memo carries the cache-byte shrink (4x on
    # the latent rows; the fp32 per-row scales add (rk+rv)⁻¹ overhead)
    from repro.kernels import quant as kq
    ckq, cks = kq.quantize_rows(ck)
    cvq, cvs = kq.quantize_rows(cv)
    qbytes = B * S * (rk + rv) + B * S * 2 * 4
    us = time_call(lambda: ops.mla_decode_grouped_quant(
        qtg, ckq, cks, cvq, cvs, bv, vl, scale=0.1))
    err = _err(ops.mla_decode_grouped_quant(qtg, ckq, cks, cvq, cvs, bv,
                                            vl, scale=0.1, interpret=True),
               ref.mla_decode_grouped_quant_ref(qtg, ckq, cks, cvq, cvs,
                                                bv, vl, scale=0.1))
    emit("kernel_mla_decode_grouped_quant", us,
         f"cache_bytes={qbytes};fp_cache_bytes={B * S * (rk + rv) * 4};"
         f"err={err:.2e};backend={backend}")

    # int8-cache ring decode
    us = time_call(lambda: ops.mla_decode_grouped_ring_quant(
        qtg, ckq, cks, cvq, cvs, bv, start, length, scale=0.1))
    err = _err(ops.mla_decode_grouped_ring_quant(
        qtg, ckq, cks, cvq, cvs, bv, start, length, scale=0.1,
        interpret=True),
        ref.mla_decode_grouped_ring_quant_ref(
            qtg, ckq, cks, cvq, cvs, bv, start, length, scale=0.1))
    emit("kernel_mla_decode_grouped_ring_quant", us,
         f"window={max(S // 2, 1)};err={err:.2e};backend={backend}")

    # flash prefill directly in latent space
    T = 128 if quick else 512
    qtp = jnp.asarray(rng.normal(size=(B, H, T, rk)), jnp.float32)
    ckp = jnp.asarray(rng.normal(size=(B, T, rk)), jnp.float32)
    cvp = jnp.asarray(rng.normal(size=(B, T, rv)), jnp.float32)
    vlp = jnp.full((B,), T, jnp.int32)
    us = time_call(lambda: ops.mla_prefill(qtp, ckp, cvp, vlp, scale=0.1))
    err = _err(ops.mla_prefill(qtp, ckp, cvp, vlp, scale=0.1, interpret=True),
               ref.mla_prefill_ref(qtp, ckp, cvp, vlp, scale=0.1))
    emit("kernel_mla_prefill", us,
         f"tokens={T};err={err:.2e};backend={backend}")

    # int8-cache prefill (the chunked-prefill carry-in path: every chunk
    # attends to already-quantized history)
    ckpq, ckps = kq.quantize_rows(ckp)
    cvpq, cvps = kq.quantize_rows(cvp)
    us = time_call(lambda: ops.mla_prefill_quant(
        qtp, ckpq, ckps, cvpq, cvps, vlp, scale=0.1))
    err = _err(ops.mla_prefill_quant(qtp, ckpq, ckps, cvpq, cvps, vlp,
                                     scale=0.1, interpret=True),
               ref.mla_prefill_quant_ref(qtp, ckpq, ckps, cvpq, cvps, vlp,
                                         scale=0.1))
    emit("kernel_mla_prefill_quant", us,
         f"tokens={T};err={err:.2e};backend={backend}")

    # ssd scan
    B2, S2, Hh, P, Gs, Nn = 2, 256, 8, 32, 1, 32
    xs = jnp.asarray(rng.normal(size=(B2, S2, Hh, P)) * 0.5, jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.2, size=(B2, S2, Hh)), jnp.float32)
    A = jnp.asarray(-rng.uniform(0.5, 2.0, size=(Hh,)), jnp.float32)
    Bm = jnp.asarray(rng.normal(size=(B2, S2, Gs, Nn)) * 0.3, jnp.float32)
    Cm = jnp.asarray(rng.normal(size=(B2, S2, Gs, Nn)) * 0.3, jnp.float32)
    us = time_call(lambda: ops.ssd_scan(xs, dt, A, Bm, Cm, chunk=64))
    y_k, _ = ops.ssd_scan(xs, dt, A, Bm, Cm, chunk=64, interpret=True)
    y_r, _ = ref.ssd_scan_ref(xs, dt, A, Bm, Cm)
    emit("kernel_ssd_scan", us, f"err={_err(y_k, y_r):.2e};backend={backend}")

    # scan-based generation: whole continuation as one dispatch
    from repro.configs import REGISTRY, reduced
    from repro.models import lm, transformer as Tm
    cfg = dataclasses.replace(reduced(REGISTRY["deepseek-coder-33b"]),
                              dtype="float32")
    key = jax.random.PRNGKey(0)
    params = Tm.init_params(key, cfg)
    gen_len = 8 if quick else 16
    prompt = jax.random.randint(key, (2, 8), 0, cfg.vocab_size)
    prefill = jax.jit(lm.make_prefill_step(cfg, 8 + gen_len))
    cache, logits = prefill(params, {"tokens": prompt})
    tok = jnp.argmax(logits, axis=-1)[:, None]
    gen = lm.jit_generate(cfg, gen_len, donate_cache=False)
    us = time_call(lambda: gen(params, cache, tok))
    emit("serving_scan_generate", us,
         f"us_per_tok={us / gen_len:.1f};gen_len={gen_len};backend={backend}")


if __name__ == "__main__":
    run()
