"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV. ``--fast`` trims the trained-model
table to fewer steps (CI); default reproduces the full set.
"""
from __future__ import annotations

import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--only", default=None)
    args, _ = ap.parse_known_args()

    from benchmarks import (appc_qkv_ablation, appi_sparse, fig7_precond,
                            fig10_attention_aware, junction_params,
                            kernels_bench, roofline, table2_perplexity,
                            table3_flops)

    suites = {
        "fig7_precond": fig7_precond.run,
        "fig10_attention_aware": fig10_attention_aware.run,
        "junction_params": junction_params.run,
        "table3_flops": table3_flops.run,
        "appc_qkv_ablation": appc_qkv_ablation.run,
        "appi_sparse": appi_sparse.run,
        "kernels": kernels_bench.run,
        "table2_perplexity": (lambda: table2_perplexity.run(
            steps=120 if args.fast else 300)),
        "roofline": roofline.run,
    }
    failed = []
    for name, fn in suites.items():
        if args.only and name != args.only:
            continue
        print(f"# --- {name} ---", flush=True)
        try:
            fn()
        except Exception:  # noqa: BLE001
            failed.append(name)
            traceback.print_exc()
    if failed:
        print(f"# FAILED: {failed}", file=sys.stderr)
        raise SystemExit(1)
    print("# all benchmarks complete")


if __name__ == "__main__":
    main()
