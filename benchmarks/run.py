"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV. ``--fast`` trims the trained-model
table to fewer steps (CI); default reproduces the full set.
"""
from __future__ import annotations

import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--quick", action="store_true",
                    help="CI gate: --fast plus reduced kernel/serving sizes")
    ap.add_argument("--only", default=None)
    args, _ = ap.parse_known_args()
    fast = args.fast or args.quick

    from benchmarks import (appc_qkv_ablation, appi_sparse, fig7_precond,
                            fig10_attention_aware, junction_params,
                            kernels_bench, roofline, serving,
                            table2_perplexity, table3_flops)

    suites = {
        "fig7_precond": fig7_precond.run,
        "fig10_attention_aware": fig10_attention_aware.run,
        "junction_params": junction_params.run,
        "table3_flops": table3_flops.run,
        "appc_qkv_ablation": appc_qkv_ablation.run,
        "appi_sparse": appi_sparse.run,
        "kernels": (lambda: kernels_bench.run(quick=args.quick)),
        "serving": (lambda: serving.run(quick=args.quick)),
        "table2_perplexity": (lambda: table2_perplexity.run(
            steps=120 if fast else 300)),
        "roofline": roofline.run,
    }
    if args.quick and not args.only:
        # the CI gate skips the trained-model table: its method-ordering
        # assert is statistical and too noisy at reduced step counts
        suites.pop("table2_perplexity")
    failed = []
    for name, fn in suites.items():
        if args.only and name != args.only:
            continue
        print(f"# --- {name} ---", flush=True)
        try:
            fn()
        except Exception:  # noqa: BLE001
            failed.append(name)
            traceback.print_exc()
    if failed:
        print(f"# FAILED: {failed}", file=sys.stderr)
        raise SystemExit(1)
    print("# all benchmarks complete")


if __name__ == "__main__":
    main()
