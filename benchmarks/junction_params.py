"""Paper §3.3 / App. A.2: junction-matrix parameter & FLOP accounting.

Reproduces the worked example: at 25% latent compression of a d×d weight
the naive factorization COSTS 1.5d² params (50% MORE than dense) while the
block-identity junction gives (15/16)d² (< d²) — and times the Pallas
latent_matmul realizing the saving."""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from benchmarks.common import emit, time_call
from repro.core.svd import weighted_svd
from repro.core.precond import activation_stats, psd_sqrt
from repro.kernels import ops, ref


def run(d=512, seed=0):
    r = int(0.75 * d)  # "25% latent compression" example from §3.3
    dense_params = d * d
    naive = r * (d + d)
    block_id = r * (d + d) - r * r
    emit("junction_params_dense", 0.0, f"params={dense_params}")
    emit("junction_params_naive", 0.0,
         f"params={naive};ratio={naive / dense_params:.3f}")
    emit("junction_params_blockid", 0.0,
         f"params={block_id};ratio={block_id / dense_params:.3f}")
    assert naive > dense_params and block_id < dense_params

    # realized in the kernel: time dense vs block-identity matmul
    rng = np.random.default_rng(seed)
    M = 512
    x = jnp.asarray(rng.normal(size=(M, d)), jnp.float32)
    W = jnp.asarray(rng.normal(size=(d, d)) / np.sqrt(d), jnp.float32)
    X_stats = jnp.asarray(rng.normal(size=(d, 2048)), jnp.float32)
    C, _ = activation_stats(X_stats)
    lr = weighted_svd(W.T, psd_sqrt(C), r, junction="block_identity")
    a2t = jnp.asarray(np.asarray(lr.A2).T)
    b = jnp.asarray(np.asarray(lr.B).T)
    perm = jnp.asarray(lr.perm)

    us_dense = time_call(lambda: x @ W)
    us_latent = time_call(
        lambda: ops.latent_matmul(x, a2t, b, perm, interpret=True))
    y_k = ops.latent_matmul(x, a2t, b, perm, interpret=True)
    y_r = ref.latent_matmul_ref(x, a2t, b, np.asarray(perm))
    err = float(jnp.max(jnp.abs(y_k - y_r)))
    flops_dense = 2 * M * d * d
    flops_latent = 2 * M * (r * (2 * d) - r * r) // 1
    emit("junction_kernel_dense", us_dense, f"flops={flops_dense}")
    emit("junction_kernel_blockid", us_latent,
         f"flops={2 * M * ((d - r) * r + r * d)};allclose_err={err:.2e}")
    return block_id, naive


if __name__ == "__main__":
    run()
