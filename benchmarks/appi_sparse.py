"""Paper App. I (Figs. 13–15): sparse vs low-rank vs low-rank+sparse
under the activation metric, at matched parameter budgets."""
from __future__ import annotations

import time

import numpy as np
import jax.numpy as jnp

from benchmarks.common import emit
from repro.core.precond import activation_stats, psd_sqrt
from repro.core.sparse import (lowrank_plus_sparse_fista,
                               lowrank_plus_sparse_hard, sparse_only,
                               weighted_loss)
from repro.core.svd import weighted_svd


def run(d=128, dp=128, l=1024, seed=0):
    rng = np.random.default_rng(seed)
    W = jnp.asarray(rng.normal(size=(dp, d)) / np.sqrt(d), jnp.float32)
    Cd = 0.9 ** np.abs(np.subtract.outer(np.arange(d), np.arange(d)))
    X = jnp.asarray(np.linalg.cholesky(Cd + 1e-9 * np.eye(d))
                    @ rng.normal(size=(d, l)), jnp.float32)
    C, _ = activation_stats(X)
    P = psd_sqrt(C)
    base = weighted_loss(W, jnp.zeros_like(W), C)

    results = {}
    for frac in (0.25, 0.5):
        budget = int(frac * W.size)
        r = budget // (dp + d)
        t0 = time.perf_counter()
        lr = weighted_svd(W, P, r, junction="left")
        l_lr = weighted_loss(W, lr.reconstruct(), C) / base
        emit(f"appi_lowrank_{int(frac*100)}pct",
             (time.perf_counter() - t0) * 1e6, f"rel_loss={l_lr:.5f};r={r}")

        t0 = time.perf_counter()
        so = sparse_only(W, C, budget, iters=20)
        l_so = weighted_loss(W, so.reconstruct(), C) / base
        emit(f"appi_sparse_{int(frac*100)}pct",
             (time.perf_counter() - t0) * 1e6,
             f"rel_loss={l_so:.5f};nnz={so.nnz()}")

        r2 = r // 2
        k2 = budget - r2 * (dp + d)
        t0 = time.perf_counter()
        hs = lowrank_plus_sparse_hard(W, C, r2, k2, iters=8)
        l_hs = weighted_loss(W, hs.reconstruct(), C) / base
        emit(f"appi_lrsparse_hard_{int(frac*100)}pct",
             (time.perf_counter() - t0) * 1e6,
             f"rel_loss={l_hs:.5f};r={r2};nnz={hs.nnz()}")

        t0 = time.perf_counter()
        fi = lowrank_plus_sparse_fista(W, C, r2, lam=2e-3, iters=15)
        l_fi = weighted_loss(W, fi.reconstruct(), C) / base
        emit(f"appi_lrsparse_fista_{int(frac*100)}pct",
             (time.perf_counter() - t0) * 1e6,
             f"rel_loss={l_fi:.5f};nnz={fi.nnz()}")
        results[frac] = (l_lr, l_so, l_hs)
        # paper Fig. 14: sparse is competitive/better than low-rank+sparse
        assert l_so <= l_hs * 1.25
    return results


if __name__ == "__main__":
    run()
