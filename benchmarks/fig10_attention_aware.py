"""Paper Fig. 10: attention-aware (joint QK HOSVD) vs activation-aware
(local ASVD) on the attention-map error, across ranks."""
from __future__ import annotations

import time

import numpy as np
import jax.numpy as jnp

from benchmarks.common import emit
from repro.core.joint_qk import JointQK, attention_map_loss, joint_qk_svd
from repro.core.precond import activation_stats, psd_sqrt
from repro.core.svd import weighted_svd


def run(d=256, dh=64, H=6, Hk=2, l=1024, seed=0):
    # note: ranks must stay <= Hk*dh for the local stacked-K baseline
    rng = np.random.default_rng(seed)
    Wq = jnp.asarray(rng.normal(size=(H, dh, d)) / np.sqrt(d), jnp.float32)
    Wk = jnp.asarray(rng.normal(size=(Hk, dh, d)) / np.sqrt(d), jnp.float32)
    Cd = 0.9 ** np.abs(np.subtract.outer(np.arange(d), np.arange(d)))
    X = jnp.asarray(np.linalg.cholesky(Cd + 1e-9 * np.eye(d))
                    @ rng.normal(size=(d, l)), jnp.float32)
    C, _ = activation_stats(X)
    P = psd_sqrt(C)
    results = {}
    for r in (32, 64, 96, 128):
        t0 = time.perf_counter()
        jqk = joint_qk_svd(Wq, Wk, P, r, r, iters=8)
        us = (time.perf_counter() - t0) * 1e6
        l_joint = attention_map_loss(Wq, Wk, jqk, X)
        lrq = weighted_svd(Wq.reshape(H * dh, d), P, r, junction="left")
        lrk = weighted_svd(Wk.reshape(Hk * dh, d), P, r, junction="left")
        local = JointQK(A_q=lrq.A, A_k=lrk.A,
                        B_q=lrq.B.reshape(H, dh, r),
                        B_k=lrk.B.reshape(Hk, dh, r))
        l_local = attention_map_loss(Wq, Wk, local, X)
        gain_db = 10 * np.log10(l_local / l_joint)
        results[r] = gain_db
        emit(f"fig10_attnaware_r{r}", us,
             f"joint={l_joint:.1f};local={l_local:.1f};gain_dB={gain_db:.2f}")
    assert all(g > 0 for g in results.values()), results
    return results


if __name__ == "__main__":
    run()
