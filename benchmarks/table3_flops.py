"""Paper Tab. 3 / Fig. 5 analogue: FLOPs / MACs / parameter counts of
compressed models vs compression ratio (analytic, matching calflops'
counting of linear layers; token length 128 as in the paper)."""
from __future__ import annotations

import dataclasses

from benchmarks.common import emit
from repro.configs import REGISTRY, LatentConfig
from repro.core.ranks import latent_ranks


def model_linear_params(cfg, rk=None):
    """Linear-layer parameters (MHA + MLP; embeddings excluded, as the
    paper compresses 'all linear layers in MLP and MHA')."""
    d, L = cfg.d_model, cfg.num_layers
    if rk is None:
        per_attn = d * cfg.q_dim + 2 * d * cfg.kv_dim + cfg.q_dim * d
        per_mlp = (3 if cfg.gated_mlp else 2) * d * cfg.d_ff
        return L * (per_attn + per_mlp)
    bi = cfg.latent.junction == "block_identity"

    def lr(d_in, d_out, r):
        return r * (d_in + d_out) - (r * r if bi else 0)

    per_attn = (lr(d, cfg.q_dim, rk["r_q"]) + lr(d, cfg.kv_dim, rk["r_k"])
                + lr(d, cfg.kv_dim, rk["r_v"]) + lr(cfg.q_dim, d, rk["r_o"]))
    per_mlp = ((2 if cfg.gated_mlp else 1) * lr(d, cfg.d_ff, rk["r_u"])
               + lr(cfg.d_ff, d, rk["r_d"]))
    return L * (per_attn + per_mlp)


def run(arch="opt-6.7b", token_len=128):
    cfg = REGISTRY[arch]
    dense = model_linear_params(cfg)
    emit("table3_dense", 0.0,
         f"params={dense / 1e9:.2f}B;flops={2 * dense * token_len / 1e12:.2f}T"
         f";macs={dense * token_len / 1e9:.0f}G")
    rows = {}
    for pct in (10, 20, 30, 40, 50, 60, 70, 80, 90):
        c = pct / 100.0
        ccfg = dataclasses.replace(
            cfg, latent=LatentConfig(enabled=True, compression=c))
        rk = latent_ranks(ccfg)
        n = model_linear_params(ccfg, rk)
        rows[pct] = n
        emit(f"table3_latent_{pct}pct", 0.0,
             f"params={n / 1e9:.2f}B;flops={2 * n * token_len / 1e12:.2f}T"
             f";macs={n * token_len / 1e9:.0f}G;ratio={n / dense:.3f}")
    # near-linear reduction claim (within rank-rounding tolerance)
    for pct in (10, 20, 30, 40, 50):
        assert abs(rows[pct] / dense - (1 - pct / 100)) < 0.08, pct
    return rows


if __name__ == "__main__":
    run()
