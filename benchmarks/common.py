"""Shared benchmark utilities. Every benchmark prints
``name,us_per_call,derived`` CSV rows via ``emit``."""
from __future__ import annotations

import time
from typing import Callable, Optional

import jax

ROWS = []


def emit(name: str, us_per_call: float, derived: str = ""):
    row = f"{name},{us_per_call:.1f},{derived}"
    ROWS.append(row)
    print(row, flush=True)


def time_call(fn: Callable, *args, iters: int = 3, warmup: int = 1) -> float:
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6
