"""Paper App. C (Remark 8) ablation: naive joint-QKV SVD vs joint-QK.

The paper found joint-QKV (one shared A for stacked Q,K,V) WORSE on the
attention objective than the targeted joint-QK; we reproduce that — and
Fig. 8's other face: on the plain ACTIVATION objective joint-QKV beats
split-QKV at matched parameter budget."""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from benchmarks.common import emit
from repro.core.joint_qk import JointQK, attention_map_loss, joint_qk_svd
from repro.core.precond import activation_stats, psd_sqrt
from repro.core.svd import weighted_svd


def run(d=128, dh=16, H=4, l=1024, r=48, seed=0):
    rng = np.random.default_rng(seed)
    Wq = jnp.asarray(rng.normal(size=(H, dh, d)) / np.sqrt(d), jnp.float32)
    Wk = jnp.asarray(rng.normal(size=(H, dh, d)) / np.sqrt(d), jnp.float32)
    Wv = jnp.asarray(rng.normal(size=(H, dh, d)) / np.sqrt(d), jnp.float32)
    Cd = 0.9 ** np.abs(np.subtract.outer(np.arange(d), np.arange(d)))
    X = jnp.asarray(np.linalg.cholesky(Cd + 1e-9 * np.eye(d))
                    @ rng.normal(size=(d, l)), jnp.float32)
    C, _ = activation_stats(X)
    P = psd_sqrt(C)

    # (a) attention-map objective: joint-QK vs naive joint-QKV
    jqk = joint_qk_svd(Wq, Wk, P, r, r, iters=8)
    l_qk = attention_map_loss(Wq, Wk, jqk, X)
    W_qkv = jnp.concatenate([Wq.reshape(H * dh, d), Wk.reshape(H * dh, d),
                             Wv.reshape(H * dh, d)])
    # matched parameter budget: the QKV factorization spends one shared A
    # over 3 matrices; rank chosen so params match 2 planes of rank r
    r_qkv = int(r * 2 * (4 * H * dh + 2 * d) / (3 * H * dh + d) / 2)
    lr_qkv = weighted_svd(W_qkv, P, r_qkv, junction="left")
    Bq = lr_qkv.B[:H * dh].reshape(H, dh, r_qkv)
    Bk = lr_qkv.B[H * dh:2 * H * dh].reshape(H, dh, r_qkv)
    qkv_as_qk = JointQK(A_q=lr_qkv.A, A_k=lr_qkv.A, B_q=Bq, B_k=Bk)
    l_qkv = attention_map_loss(Wq, Wk, qkv_as_qk, X)
    emit("appc_jointQK_attnloss", 0.0, f"loss={l_qk:.1f}")
    emit("appc_jointQKV_attnloss", 0.0,
         f"loss={l_qkv:.1f};rank={r_qkv};worse_by={l_qkv / l_qk:.2f}x")
    assert l_qk < l_qkv, "paper Remark 8: joint-QK should beat naive QKV"

    # (b) activation objective: joint-QKV vs split at matched params
    lr_joint = weighted_svd(W_qkv, P, r_qkv, junction="left")
    R = (W_qkv - lr_joint.reconstruct()) @ X
    act_joint = float(jnp.sum(R * R))
    r_split = max(4, (r_qkv * (3 * H * dh + d)) // (3 * (H * dh + d)))
    act_split = 0.0
    for Wi in (Wq, Wk, Wv):
        lri = weighted_svd(Wi.reshape(H * dh, d), P, r_split, junction="left")
        Ri = (Wi.reshape(H * dh, d) - lri.reconstruct()) @ X
        act_split += float(jnp.sum(Ri * Ri))
    emit("appc_jointQKV_actloss", 0.0, f"loss={act_joint:.2f}")
    emit("appc_splitQKV_actloss", 0.0,
         f"loss={act_split:.2f};r_joint={r_qkv};r_split={r_split}")
    return l_qk, l_qkv


if __name__ == "__main__":
    run()
