"""Paper Tab. 2 / Fig. 4 analogue: perplexity of a TRAINED OPT-family
model under every compression method × compression ratio.

The released OPT checkpoints are unavailable offline (DESIGN §6); we
train an opt-125m-architecture byte-LM (ReLU MLP, learned positions,
biases — the paper's exact setting for the closed-form joint-UD update)
for a few hundred steps and compress it, validating the paper's ORDERING
claims. Calibration follows the paper: random segments, zero-shot."""
from __future__ import annotations

import dataclasses
import math
import time

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import emit
from repro.configs import REGISTRY, LatentConfig, reduced
from repro.core.compress import CompressionPlan, Compressor
from repro.data import DataConfig, TokenDataset
from repro.models import lm, transformer as T
from repro.optim import AdamW, AdamWConfig

METHODS = ("plain", "asvd_hessian", "asvd_l2", "asvd_cov", "asvd_rootcov",
           "latentllm", "quant")
RATIOS = (0.1, 0.2, 0.3)
# quant = latentllm + int8 fake-quant of the factors; its perplexity may
# exceed latentllm's by at most this factor (the int8 accuracy gate)
QUANT_PPL_GATE = 1.05


def train_small(steps=300, d_model=128, layers=3, seq=128, batch=8, seed=0):
    cfg = dataclasses.replace(
        reduced(REGISTRY["opt-125m"], layers=layers, d_model=d_model),
        dtype="float32")
    key = jax.random.PRNGKey(seed)
    params = T.init_params(key, cfg)
    data = TokenDataset(DataConfig(seq_len=seq, global_batch=batch,
                                   seed=seed, n_tokens=500_000))
    opt = AdamW(AdamWConfig(lr=3e-3, warmup_steps=20, total_steps=steps))
    opt_state = opt.init(params)
    step = jax.jit(lm.make_train_step(cfg, opt, remat=False),
                   donate_argnums=(0, 1))
    for s in range(steps):
        b = jax.tree.map(jnp.asarray, data.batch_at(s))
        params, opt_state, m = step(params, opt_state, b,
                                    jnp.asarray(s, jnp.int32))
    evals = [jax.tree.map(jnp.asarray, data.batch_at(10_000 + i))
             for i in range(4)]
    calib = jax.tree.map(jnp.asarray, data.batch_at(20_000))
    return cfg, params, calib, evals


def ppl(cfg, params, evals):
    """Token-weighted perplexity: each batch's mean NLL is weighted by
    its REAL token count (``batch["mask"]`` when present — ragged eval
    batches with padded tails then contribute exactly their valid
    tokens, nothing from the padding). Fully-dense batches reduce to
    the old plain mean."""
    es = jax.jit(lm.make_eval_step(cfg))
    tot = cnt = 0.0
    for b in evals:
        w = float(np.sum(np.asarray(b["mask"])[:, 1:])) if "mask" in b \
            else float(b["labels"][:, 1:].size)
        tot += float(es(params, b)) * w
        cnt += w
    return math.exp(min(tot / max(cnt, 1.0), 20.0))


def run(steps=300):
    cfg, params, calib, evals = train_small(steps=steps)
    base_ppl = ppl(cfg, params, evals)
    emit("table2_uncompressed", 0.0, f"ppl={base_ppl:.2f}")
    table = {}
    for ratio in RATIOS:
        rcfg = dataclasses.replace(
            cfg, latent=LatentConfig(enabled=False, compression=ratio))
        lat_cfg = dataclasses.replace(
            rcfg, latent=dataclasses.replace(rcfg.latent, enabled=True))
        for method in METHODS:
            plan = CompressionPlan(method=method, compression=ratio)
            t0 = time.perf_counter()
            lp, _ = Compressor(params, rcfg, plan=plan) \
                .calibrate(calib).compress()
            us = (time.perf_counter() - t0) * 1e6
            p = ppl(lat_cfg, lp, evals)
            table[(method, ratio)] = p
            emit(f"table2_{method}_{int(ratio * 100)}pct", us,
                 f"ppl={p:.2f};base={base_ppl:.2f}")
    # the paper's ordering claims at every ratio
    for ratio in RATIOS:
        assert table[("latentllm", ratio)] <= table[("plain", ratio)]
        assert table[("asvd_rootcov", ratio)] <= table[("plain", ratio)]
        # int8 fake-quant rides on latentllm's solution: its perplexity
        # delta must stay within the quantization gate
        assert table[("quant", ratio)] <= \
            table[("latentllm", ratio)] * QUANT_PPL_GATE, \
            (ratio, table[("quant", ratio)], table[("latentllm", ratio)])
    return table


if __name__ == "__main__":
    run()
