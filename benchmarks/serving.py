"""Serving-path benchmark: prefill / decode wall time on the latent fast
path, scan-generation vs the per-token Python loop, the latent-vs-dense
KV cache footprint, and continuous-batching Engine throughput (req/s and
tok/s under burst vs staggered arrival). Emits CSV rows AND writes
``BENCH_serving.json`` (repo root) so the perf trajectory is tracked
across PRs.
"""
from __future__ import annotations

import dataclasses
import json
import time

import jax
import jax.numpy as jnp

from benchmarks.common import emit
from repro.configs import REGISTRY, LatentConfig, reduced
from repro.models import lm, transformer as T
from repro.serve import (Engine, Request, SamplingParams, cache_bytes,
                         synthetic_prompts)

OUT_JSON = "BENCH_serving.json"


def _absorbed_cfg():
    """NoPE latent config: exercises the absorbed MLA kernel path
    (flash prefill + grouped decode, R=2 query heads per kv group) end
    to end. 2 kv heads keep kv_dim > r_k+r_v so the latent cache win is
    visible even at the reduced size (MQA-reduced configs cap r at
    kv_dim and the ratio degenerates to 100%)."""
    cfg = dataclasses.replace(reduced(REGISTRY["deepseek-coder-33b"]),
                              dtype="float32")
    return dataclasses.replace(
        cfg, pos_emb="none", qkv_bias=False, num_kv_heads=2,
        latent=LatentConfig(enabled=True, compression=0.3))


def _timed(fn, *args, iters=3):
    out = fn(*args)              # compile + warm up
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e3, out  # ms


def run(quick: bool = False, out_path: str = OUT_JSON):
    cfg = _absorbed_cfg()
    B, P, G = (2, 16, 8) if quick else (4, 64, 32)
    max_len = P + G
    key = jax.random.PRNGKey(0)
    params = T.init_params(key, cfg)
    prompt = jax.random.randint(key, (B, P), 0, cfg.vocab_size)

    prefill = jax.jit(lm.make_prefill_step(cfg, max_len))
    prefill_ms, (cache, logits) = _timed(
        prefill, params, {"tokens": prompt})
    tok = jnp.argmax(logits, axis=-1)[:, None].astype(prompt.dtype)

    # scan path: whole continuation = one dispatch (no donation here so
    # the timing loop can reuse the same cache buffers)
    gen = lm.jit_generate(cfg, G - 1, donate_cache=False)
    scan_ms, _ = _timed(gen, params, cache, tok)

    # per-token Python loop (the old serving path) on the same cache
    decode = jax.jit(lm.make_decode_step(cfg))

    def loop(params, cache, tok):
        for _ in range(G - 1):
            logits, cache = decode(params, cache, {"tokens": tok})
            tok = jnp.argmax(logits, axis=-1)[:, None].astype(tok.dtype)
        return tok, cache

    loop_ms, _ = _timed(loop, params, cache, tok)

    # ---- continuous-batching engine throughput -----------------------
    n_req, slots = (6, 2) if quick else (16, 4)
    # same mixed-length traffic shape the serve CLI generates
    prompts = synthetic_prompts(jax.random.PRNGKey(0), n_req, P,
                                cfg.vocab_size)

    def make_requests():
        return [Request(p, SamplingParams(max_new_tokens=G))
                for p in prompts]

    eng = Engine(cfg, params, num_slots=slots, max_len=max_len)
    eng.run(make_requests())          # warm the burst-admission shapes

    eng.run(make_requests())          # burst: everything queued up front
    burst = dict(eng.last_stats)

    def staggered_pass():
        """One request every other engine step; returns wall seconds."""
        pending = make_requests()
        t0 = time.perf_counter()
        eng.submit(pending.pop())
        tick = 0
        while eng.has_work() or pending:
            if pending and tick % 2 == 0:
                eng.submit(pending.pop())
            eng.step()
            tick += 1
        return time.perf_counter() - t0

    staggered_pass()                  # warm the 1-at-a-time admit shapes
    stag_s = staggered_pass()
    stag_toks = n_req * G

    scan_ms_tok = scan_ms / (G - 1)
    loop_ms_tok = loop_ms / (G - 1)
    dense_cfg = dataclasses.replace(
        cfg, latent=LatentConfig(enabled=False))
    results = {
        "arch": cfg.name,
        "backend": jax.default_backend(),
        "batch": B,
        "prompt_len": P,
        "gen_len": G,
        "prefill_ms": round(prefill_ms, 3),
        "decode_ms_per_tok_scan": round(scan_ms_tok, 4),
        "decode_ms_per_tok_loop": round(loop_ms_tok, 4),
        "scan_speedup_vs_loop": round(loop_ms_tok / max(scan_ms_tok, 1e-9), 3),
        "latent_cache_bytes": int(cache_bytes(cfg, B, max_len)),
        "dense_cache_bytes": int(cache_bytes(dense_cfg, B, max_len)),
        "engine_slots": slots,
        "engine_requests": n_req,
        "engine_req_per_s_burst": burst["req_per_s"],
        "engine_tok_per_s_burst": burst["tok_per_s"],
        "engine_tok_per_s_staggered": round(stag_toks / stag_s, 3),
    }
    with open(out_path, "w") as f:
        json.dump(results, f, indent=2)
        f.write("\n")

    emit("serving_prefill", prefill_ms * 1e3,
         f"prompt={P}x{B};backend={results['backend']}")
    emit("serving_decode_scan", scan_ms_tok * 1e3,
         f"ms_per_tok={scan_ms_tok:.3f};gen_len={G}")
    emit("serving_decode_loop", loop_ms_tok * 1e3,
         f"ms_per_tok={loop_ms_tok:.3f};speedup={results['scan_speedup_vs_loop']}")
    emit("serving_cache_ratio",
         results["latent_cache_bytes"] / results["dense_cache_bytes"] * 100,
         f"latent_bytes={results['latent_cache_bytes']};"
         f"dense_bytes={results['dense_cache_bytes']}")
    emit("serving_engine_burst", burst["seconds"] * 1e6,
         f"req_per_s={burst['req_per_s']};tok_per_s={burst['tok_per_s']};"
         f"slots={slots};reqs={n_req}")
    emit("serving_engine_staggered", stag_s * 1e6,
         f"tok_per_s={results['engine_tok_per_s_staggered']};"
         f"arrival=1_per_2_steps")
    print(f"# wrote {out_path}")
    return results


if __name__ == "__main__":
    run()
