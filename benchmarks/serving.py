"""Serving-path benchmark: prefill / decode wall time on the latent fast
path, scan-generation vs the per-token Python loop, the latent-vs-dense
KV cache footprint, and continuous-batching Engine throughput (req/s and
tok/s under burst vs staggered arrival) — single-device AND sharded over
a 2x4 debug mesh (the sharded pass runs in a subprocess with 8 fake CPU
devices so the parent's device topology is untouched), plus a windowed
(gemma2-style ring-cache) engine pass whose prompts wrap the ring and
whose decode runs the (start, length) ring kernels, plus a PAGED pass on
shared-prefix traffic where the radix tree cuts prefill tokens computed
(prefix_hit_rate / prefill_tokens_computed land in the JSON), plus an
OVERLOAD pass (paged pool sized below the working set + tight deadlines
on part of the traffic) recording preemption/timeout counts, p50/p99
completion latency, and goodput, plus a SERVER-MODE pass driving the
full HTTP+SSE front-end with N concurrent client threads (``server_*``
entries: req/s, tok/s, client-observed TTFT and e2e p50/p99 — what the
wire delivers, including HTTP + scheduler-queue overhead), plus a
LONGPROMPT pass (``longprompt_*`` entries) where a long prompt arrives
mid-decode of resident short streams: chunked prefill
(``prefill_chunk``/``token_budget``) must keep resident ms/token p99
within 2x of the no-admission baseline while the unchunked engine shows
the monopolizing-prefill stall. Emits CSV rows AND writes
``BENCH_serving.json`` (repo root) so the perf trajectory is tracked
across PRs.
"""
from __future__ import annotations

import dataclasses
import json
import os
import subprocess
import sys
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.configs import REGISTRY, LatentConfig, reduced
from repro.models import lm, transformer as T
from repro.serve import (Engine, MetricsRegistry, Request, RequestState,
                         SamplingParams, ServeClient, ServeServer,
                         cache_bytes, synthetic_prompts)

OUT_JSON = "BENCH_serving.json"


def _absorbed_cfg():
    """NoPE latent config: exercises the absorbed MLA kernel path
    (flash prefill + grouped decode, R=2 query heads per kv group) end
    to end. 2 kv heads keep kv_dim > r_k+r_v so the latent cache win is
    visible even at the reduced size (MQA-reduced configs cap r at
    kv_dim and the ratio degenerates to 100%)."""
    cfg = dataclasses.replace(reduced(REGISTRY["deepseek-coder-33b"]),
                              dtype="float32")
    return dataclasses.replace(
        cfg, pos_emb="none", qkv_bias=False, num_kv_heads=2,
        latent=LatentConfig(enabled=True, compression=0.3))


def _windowed_cfg():
    """gemma2-style sliding-window absorbed config: local/global layer
    alternation with softcaps, served over a ring CacheLayout — decode
    dispatches the (start, length) ring kernels."""
    cfg = dataclasses.replace(reduced(REGISTRY["gemma2-27b"]),
                              dtype="float32")
    return dataclasses.replace(
        cfg, pos_emb="none", qkv_bias=False, num_kv_heads=2,
        latent=LatentConfig(enabled=True, compression=0.3))


def _engine_throughput(cfg, params, prompts, gen_len, slots, max_len,
                       paged=False, block_size=8, cache_dtype="fp"):
    """(burst stats dict, staggered wall seconds, engine) for one
    Engine, with warm passes so jit compile never lands in the timed
    run. ``paged=True`` serves the same traffic through the block-table
    arena — the warm pass seeds the radix tree, so the timed burst
    prefills only uncached suffixes."""

    def make_requests():
        return [Request(p, SamplingParams(max_new_tokens=gen_len))
                for p in prompts]

    eng = Engine(cfg, params, num_slots=slots, max_len=max_len,
                 paged=paged, block_size=block_size, cache_dtype=cache_dtype)
    eng.run(make_requests())          # warm the burst-admission shapes
    eng.run(make_requests())          # burst: everything queued up front
    burst = dict(eng.last_stats)

    def staggered_pass():
        """One request every other engine step; returns wall seconds."""
        pending = make_requests()
        t0 = time.perf_counter()
        eng.submit(pending.pop())
        tick = 0
        while eng.has_work() or pending:
            if pending and tick % 2 == 0:
                eng.submit(pending.pop())
            eng.step()
            tick += 1
        return time.perf_counter() - t0

    staggered_pass()                  # warm the 1-at-a-time admit shapes
    return burst, staggered_pass(), eng


def _server_entries(cfg, params, prompts, gen_len, slots, max_len):
    """Full-stack server mode: the HTTP+SSE front-end over the engine,
    one concurrent client THREAD per request, measured from the client
    side. The engine-only numbers bound what the front-end can deliver;
    these entries track what actually crosses the wire — TTFT and e2e
    include HTTP handling, the scheduler command queue, and SSE
    streaming."""
    eng = Engine(cfg, params, num_slots=slots, max_len=max_len,
                 max_queue=max(len(prompts), 8), metrics=MetricsRegistry())
    eng.run([Request(p, SamplingParams(max_new_tokens=gen_len))
             for p in prompts])        # warm burst admit/decode shapes
    eng.run([Request(prompts[0], SamplingParams(max_new_tokens=gen_len))])
    # ^ concurrent arrival admits in small buckets too — warm bucket 1
    srv = ServeServer(eng)
    host, port = srv.start()
    out = [None] * len(prompts)

    def worker(i):
        out[i] = ServeClient(host, port).generate(
            [int(t) for t in prompts[i]], max_new_tokens=gen_len)

    threads = [threading.Thread(target=worker, args=(i,))
               for i in range(len(prompts))]
    t0 = time.perf_counter()
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=600)
    wall = time.perf_counter() - t0
    srv.stop(drain=True, timeout_s=120.0)
    oks = [r for r in out if r is not None and r["finish_reason"]]
    ttft = np.asarray([r["client_ttft_s"] for r in oks])
    e2e = np.asarray([r["client_latency_s"] for r in oks])
    return {
        "server_clients": len(prompts),
        "server_finished": len(oks),
        "server_wall_s": round(wall, 4),
        "server_req_per_s": round(len(oks) / wall, 3),
        "server_tok_per_s": round(
            sum(r["num_generated"] for r in oks) / wall, 3),
        "server_ttft_p50_s": round(float(np.percentile(ttft, 50)), 4),
        "server_ttft_p99_s": round(float(np.percentile(ttft, 99)), 4),
        "server_e2e_p50_s": round(float(np.percentile(e2e, 50)), 4),
        "server_e2e_p99_s": round(float(np.percentile(e2e, 99)), 4),
    }


def _longprompt_entries(cfg, params, quick: bool) -> dict:
    """Chunked-prefill SLO pass: a pool of resident short streams
    decodes while one LONG prompt arrives mid-run. Three engines see
    the same traffic — no long admission (baseline), unchunked (the
    long prefill monopolizes one dispatch), and chunked
    (``prefill_chunk`` + ``token_budget`` interleave it). Reported:
    per-step resident ms/token p50/p99 over the decode window and the
    p99 ratio vs the no-admission baseline — the acceptance bar is the
    CHUNKED ratio staying within 2x while the unchunked one shows the
    stall the scheduler removes.

    Measurement hygiene (each matters at sub-ms step scale):
    residents are fully warmed IN before the timed window (their own
    prompts chunk too, so a fixed step count under-admits); the chunk
    size keeps chunk-carrying steps to ~25% of the window and the long
    prefill finishes well inside it (trailing chunk-on-two-rows steps
    otherwise dominate p99); ``slo_drift_factor`` is pinned off so
    wall-time feedback cannot reshape the batch mid-run and trigger
    recompiles; and p50/p99 are computed over the POOLED samples of
    all ``passes`` so a single OS scheduling spike cannot set either
    side's tail."""
    slots = 12
    nres = slots - 1                         # one slot kept for the long
    G = 32 if quick else 64                  # resident decode steps timed
    P_long = 128 if quick else 256
    chunk, budget = 16, 64                   # long done in ~P/chunk steps
    passes = 5
    rng = np.random.RandomState(7)
    short = [rng.randint(0, cfg.vocab_size, size=8 + i % 4).astype(np.int32)
             for i in range(nres)]
    long_prompt = rng.randint(0, cfg.vocab_size,
                              size=P_long).astype(np.int32)
    max_len = P_long + G + 8

    def one_pass(eng, admit_long):
        """Per-step wall times over the resident decode window; the long
        prompt (when admitted) lands on the first timed step."""
        residents = [eng.submit(p, SamplingParams(max_new_tokens=G))
                     for p in short]
        while eng._prefilling or int(eng._active.sum()) < len(residents):
            eng.step()                       # warm in: all residents live
        samples = []
        lreq = None
        while any(not r.finished for r in residents):
            if lreq is None and admit_long:
                lreq = eng.submit(long_prompt,
                                  SamplingParams(max_new_tokens=4))
            rows = int(eng._active.sum())
            t0 = time.perf_counter()
            eng.step()
            if rows:
                samples.append((time.perf_counter() - t0) / rows)
        while eng.has_work():
            eng.step()
        assert all(r.finished for r in residents)
        assert lreq is None or lreq.finished
        return np.asarray(samples)

    def timed(admit_long, chunked):
        kw = dict(prefill_chunk=chunk, token_budget=budget,
                  slo_drift_factor=float("inf")) if chunked else {}
        eng = Engine(cfg, params, num_slots=slots, max_len=max_len, **kw)
        one_pass(eng, admit_long)            # warm every dispatch shape
        pool = np.concatenate([one_pass(eng, admit_long)
                               for _ in range(passes)])
        return (round(float(np.percentile(pool, 50)) * 1e3, 4),
                round(float(np.percentile(pool, 99)) * 1e3, 4))

    base_p50, base_p99 = timed(False, False)
    blk_p50, blk_p99 = timed(True, False)
    chk_p50, chk_p99 = timed(True, True)
    return {
        "longprompt_len": P_long,
        "longprompt_chunk": chunk,
        "longprompt_token_budget": budget,
        "longprompt_resident_mstok_p50_baseline": base_p50,
        "longprompt_resident_mstok_p99_baseline": base_p99,
        "longprompt_resident_mstok_p50_unchunked": blk_p50,
        "longprompt_resident_mstok_p99_unchunked": blk_p99,
        "longprompt_resident_mstok_p50_chunked": chk_p50,
        "longprompt_resident_mstok_p99_chunked": chk_p99,
        "longprompt_p99_ratio_unchunked":
            round(blk_p99 / max(base_p99, 1e-9), 3),
        "longprompt_p99_ratio_chunked":
            round(chk_p99 / max(base_p99, 1e-9), 3),
    }


_SHARDED_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses, json, time
import jax
from repro.configs import REGISTRY, LatentConfig, reduced
from repro.launch.mesh import make_debug_mesh
from repro.models import transformer as T
from repro.serve import Engine, Request, SamplingParams, synthetic_prompts

quick = __QUICK__
P, G = (16, 8) if quick else (64, 32)
n_req, slots = (6, 2) if quick else (16, 4)
cfg = dataclasses.replace(reduced(REGISTRY["deepseek-coder-33b"]),
                          dtype="float32")
# num_kv_heads=4 divides the 2x4 mesh's model axis, so the absorbed
# decode/prefill Pallas kernels run per-shard rather than falling back
cfg = dataclasses.replace(cfg, pos_emb="none", qkv_bias=False,
                          num_kv_heads=4,
                          latent=LatentConfig(enabled=True, compression=0.3))
mesh = make_debug_mesh(2, 4)
params = T.init_params(jax.random.PRNGKey(0), cfg)
prompts = synthetic_prompts(jax.random.PRNGKey(0), n_req, P, cfg.vocab_size)

def make_requests():
    return [Request(p, SamplingParams(max_new_tokens=G)) for p in prompts]

eng = Engine(cfg, params, num_slots=slots, max_len=P + G, mesh=mesh)
eng.run(make_requests())              # warm the burst-admission shapes
eng.run(make_requests())
burst = dict(eng.last_stats)

def staggered_pass():
    pending = make_requests()
    t0 = time.perf_counter()
    eng.submit(pending.pop())
    tick = 0
    while eng.has_work() or pending:
        if pending and tick % 2 == 0:
            eng.submit(pending.pop())
        eng.step()
        tick += 1
    return time.perf_counter() - t0

staggered_pass()
stag_s = staggered_pass()
print("RESULT:" + json.dumps({
    "engine_mesh": "2x4",
    "engine_burst_s_sharded": burst["seconds"],
    "engine_req_per_s_burst_sharded": burst["req_per_s"],
    "engine_tok_per_s_burst_sharded": burst["tok_per_s"],
    "engine_tok_per_s_staggered_sharded": round(n_req * G / stag_s, 3),
}))
"""


def _sharded_entries(quick: bool) -> dict:
    """Engine throughput on a 2x4 debug mesh, in a subprocess (the
    8-fake-device XLA flag must be set before jax initializes)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = "src" + os.pathsep + env.get("PYTHONPATH", "")
    try:
        r = subprocess.run(
            [sys.executable, "-c",
             _SHARDED_SCRIPT.replace("__QUICK__", repr(bool(quick)))],
            env=env, capture_output=True, text=True, timeout=1200)
        line = [l for l in r.stdout.splitlines() if l.startswith("RESULT:")]
        if r.returncode != 0 or not line:
            print(f"# sharded serving bench failed: {r.stderr[-500:]}")
            return {}
        return json.loads(line[-1][len("RESULT:"):])
    except (subprocess.TimeoutExpired, OSError) as e:
        print(f"# sharded serving bench skipped: {e}")
        return {}


def _timed(fn, *args, iters=3):
    out = fn(*args)              # compile + warm up
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e3, out  # ms


def run(quick: bool = False, out_path: str = OUT_JSON):
    cfg = _absorbed_cfg()
    B, P, G = (2, 16, 8) if quick else (4, 64, 32)
    max_len = P + G
    key = jax.random.PRNGKey(0)
    params = T.init_params(key, cfg)
    prompt = jax.random.randint(key, (B, P), 0, cfg.vocab_size)

    prefill = jax.jit(lm.make_prefill_step(cfg, max_len))
    prefill_ms, (cache, logits) = _timed(
        prefill, params, {"tokens": prompt})
    tok = jnp.argmax(logits, axis=-1)[:, None].astype(prompt.dtype)

    # scan path: whole continuation = one dispatch (no donation here so
    # the timing loop can reuse the same cache buffers)
    gen = lm.jit_generate(cfg, G - 1, donate_cache=False)
    scan_ms, _ = _timed(gen, params, cache, tok)

    # per-token Python loop (the old serving path) on the same cache
    decode = jax.jit(lm.make_decode_step(cfg))

    def loop(params, cache, tok):
        for _ in range(G - 1):
            logits, cache = decode(params, cache, {"tokens": tok})
            tok = jnp.argmax(logits, axis=-1)[:, None].astype(tok.dtype)
        return tok, cache

    loop_ms, _ = _timed(loop, params, cache, tok)

    # ---- continuous-batching engine throughput -----------------------
    n_req, slots = (6, 2) if quick else (16, 4)
    # same mixed-length traffic shape the serve CLI generates
    prompts = synthetic_prompts(jax.random.PRNGKey(0), n_req, P,
                                cfg.vocab_size)
    burst, stag_s, _ = _engine_throughput(cfg, params, prompts, G, slots,
                                          max_len)
    stag_toks = n_req * G

    # ---- server mode: HTTP+SSE front-end, concurrent clients ---------
    server = _server_entries(cfg, params, prompts, G, slots, max_len)

    # ---- paged engine on shared-prefix traffic -----------------------
    # few-shot-template-style workload: every request shares a P//2
    # prefix, so the radix tree turns repeat prefills into block reuse
    # (same absorbed NoPE config; max_len = P+G tiles the block size)
    rng = np.random.RandomState(0)
    shared = rng.randint(0, cfg.vocab_size, size=P // 2).astype(np.int32)
    pprompts = [np.concatenate([
        shared, rng.randint(0, cfg.vocab_size,
                            size=1 + i % (P // 2)).astype(np.int32)])
        for i in range(n_req)]
    pburst, pstag_s, peng = _engine_throughput(
        cfg, params, pprompts, G, slots, max_len, paged=True)
    prep = peng.cache_report()

    # ---- quantized latent cache: int8 arena on the same traffic ------
    # greedy decode matches the fp engine token-for-token (tested), so
    # the quant_* deltas are pure footprint/throughput effects of the
    # in-kernel-dequant kernels + quantize-on-write
    qburst, qstag_s, qeng = _engine_throughput(
        cfg, params, prompts, G, slots, max_len, cache_dtype="int8")
    qrep = qeng.cache_report()

    # ---- chunked prefill under a long-prompt arrival -----------------
    longprompt = _longprompt_entries(cfg, params, quick)

    # ---- windowed (ring-cache) engine throughput ---------------------
    # gemma2-style traffic whose prompts exceed the reduced window (16),
    # so admission wraps the ring and decode runs the ring kernels
    wcfg = _windowed_cfg()
    wparams = T.init_params(jax.random.PRNGKey(1), wcfg)
    wprompts = synthetic_prompts(jax.random.PRNGKey(1), n_req,
                                 max(P, 24), wcfg.vocab_size)
    wmax_len = max(p.size for p in wprompts) + G
    wburst, wstag_s, _ = _engine_throughput(wcfg, wparams, wprompts, G, slots,
                                            wmax_len)

    # ---- overload: pool below the working set + deadlines ------------
    # the robust-lifecycle path under pressure: the paged pool holds 2/3
    # of what the residents want, so mid-decode exhaustion preempts and
    # resumes instead of crashing, and every 4th request carries a tight
    # completion deadline so the timeout sweep runs in the timed loop.
    # Reported: preemption/timeout counts, p50/p99 completion latency,
    # and goodput (tokens of requests that actually FINISHED per second).
    obs = 8
    need = [int(np.ceil((p.size + G) / obs)) for p in prompts]
    o_blocks = max(max(need), 2 * sum(sorted(need)[-slots:]) // 3)
    oeng = Engine(cfg, params, num_slots=slots, max_len=max_len,
                  paged=True, block_size=obs, num_blocks=o_blocks)

    def overload_pass():
        reqs = []
        t0 = time.perf_counter()
        for i, p in enumerate(prompts):
            reqs.append(oeng.submit(
                p, SamplingParams(max_new_tokens=G),
                deadline_s=0.05 if i % 4 == 3 else None))
        while oeng.has_work():
            oeng.step()
        return reqs, time.perf_counter() - t0

    overload_pass()                   # warm the admit/resume shapes
    oreqs, owall = overload_pass()
    olat = np.array(sorted(r.finish_time - r.submit_time for r in oreqs))
    o_fin = [r for r in oreqs if r.state is RequestState.FINISHED]
    o_good = sum(r.num_generated for r in o_fin) / owall

    scan_ms_tok = scan_ms / (G - 1)
    loop_ms_tok = loop_ms / (G - 1)
    dense_cfg = dataclasses.replace(
        cfg, latent=LatentConfig(enabled=False))
    results = {
        "arch": cfg.name,
        "backend": jax.default_backend(),
        "batch": B,
        "prompt_len": P,
        "gen_len": G,
        "prefill_ms": round(prefill_ms, 3),
        "decode_ms_per_tok_scan": round(scan_ms_tok, 4),
        "decode_ms_per_tok_loop": round(loop_ms_tok, 4),
        "scan_speedup_vs_loop": round(loop_ms_tok / max(scan_ms_tok, 1e-9), 3),
        "latent_cache_bytes": int(cache_bytes(cfg, B, max_len)),
        "dense_cache_bytes": int(cache_bytes(dense_cfg, B, max_len)),
        "engine_slots": slots,
        "engine_requests": n_req,
        "engine_req_per_s_burst": burst["req_per_s"],
        "engine_tok_per_s_burst": burst["tok_per_s"],
        "engine_tok_per_s_staggered": round(stag_toks / stag_s, 3),
        **server,
        "engine_req_per_s_burst_paged": pburst["req_per_s"],
        "engine_tok_per_s_burst_paged": pburst["tok_per_s"],
        "engine_tok_per_s_staggered_paged": round(stag_toks / pstag_s, 3),
        "paged_prefix_hit_rate": prep["prefix_hit_rate"],
        "paged_prefill_tokens_computed": prep["prefill_tokens_computed"],
        "paged_prefill_tokens_total": prep["prefill_tokens_computed"]
        + prep["prefill_tokens_saved"],      # what the linear arena computes
        "paged_blocks_in_use": prep["blocks_in_use"],
        "overload_num_blocks": o_blocks,
        "overload_preemptions": int(sum(r.num_preemptions for r in oreqs)),
        "overload_timeouts": sum(
            r.state is RequestState.TIMEOUT for r in oreqs),
        "overload_finished": len(o_fin),
        "overload_p50_latency_s": round(float(np.percentile(olat, 50)), 4),
        "overload_p99_latency_s": round(float(np.percentile(olat, 99)), 4),
        "overload_goodput_tok_per_s": round(o_good, 3),
        "engine_req_per_s_burst_quant": qburst["req_per_s"],
        "engine_tok_per_s_burst_quant": qburst["tok_per_s"],
        "engine_tok_per_s_staggered_quant": round(stag_toks / qstag_s, 3),
        "quant_slot_bytes": qrep["slot_bytes"],
        "quant_fp_slot_bytes": qrep["fp_slot_bytes"],
        "quant_cache_shrink_vs_fp": round(
            qrep["fp_slot_bytes"] / max(qrep["slot_bytes"], 1), 4),
        "quant_compression_vs_dense": qrep["compression_vs_dense"],
        **longprompt,
        "windowed_arch": wcfg.name,
        "windowed_window": wcfg.sliding_window,
        "engine_req_per_s_burst_windowed": wburst["req_per_s"],
        "engine_tok_per_s_burst_windowed": wburst["tok_per_s"],
        "engine_tok_per_s_staggered_windowed": round(stag_toks / wstag_s, 3),
    }
    results.update(_sharded_entries(quick))
    with open(out_path, "w") as f:
        json.dump(results, f, indent=2)
        f.write("\n")

    emit("serving_prefill", prefill_ms * 1e3,
         f"prompt={P}x{B};backend={results['backend']}")
    emit("serving_decode_scan", scan_ms_tok * 1e3,
         f"ms_per_tok={scan_ms_tok:.3f};gen_len={G}")
    emit("serving_decode_loop", loop_ms_tok * 1e3,
         f"ms_per_tok={loop_ms_tok:.3f};speedup={results['scan_speedup_vs_loop']}")
    emit("serving_cache_ratio",
         results["latent_cache_bytes"] / results["dense_cache_bytes"] * 100,
         f"latent_bytes={results['latent_cache_bytes']};"
         f"dense_bytes={results['dense_cache_bytes']}")
    emit("serving_engine_burst", burst["seconds"] * 1e6,
         f"req_per_s={burst['req_per_s']};tok_per_s={burst['tok_per_s']};"
         f"slots={slots};reqs={n_req}")
    emit("serving_engine_staggered", stag_s * 1e6,
         f"tok_per_s={results['engine_tok_per_s_staggered']};"
         f"arrival=1_per_2_steps")
    emit("serving_server_concurrent", server["server_wall_s"] * 1e6,
         f"clients={server['server_clients']};"
         f"req_per_s={server['server_req_per_s']};"
         f"tok_per_s={server['server_tok_per_s']};"
         f"ttft_p50_s={server['server_ttft_p50_s']};"
         f"ttft_p99_s={server['server_ttft_p99_s']};"
         f"e2e_p50_s={server['server_e2e_p50_s']};"
         f"e2e_p99_s={server['server_e2e_p99_s']}")
    emit("serving_engine_burst_paged", pburst["seconds"] * 1e6,
         f"req_per_s={pburst['req_per_s']};tok_per_s={pburst['tok_per_s']};"
         f"prefix_hit_rate={prep['prefix_hit_rate']};"
         f"shared_prefix={P // 2}")
    emit("serving_engine_staggered_paged", pstag_s * 1e6,
         f"tok_per_s={results['engine_tok_per_s_staggered_paged']};"
         f"arrival=1_per_2_steps")
    emit("serving_prefix_reuse", prep["prefix_hit_rate"] * 100,
         f"prefill_computed={prep['prefill_tokens_computed']};"
         f"prefill_total={results['paged_prefill_tokens_total']};"
         f"blocks_in_use={prep['blocks_in_use']}")
    emit("serving_engine_overload", owall * 1e6,
         f"blocks={o_blocks};preempt={results['overload_preemptions']};"
         f"timeout={results['overload_timeouts']};"
         f"p50_s={results['overload_p50_latency_s']};"
         f"p99_s={results['overload_p99_latency_s']};"
         f"goodput_tok_per_s={results['overload_goodput_tok_per_s']}")
    emit("serving_engine_burst_quant", qburst["seconds"] * 1e6,
         f"req_per_s={qburst['req_per_s']};tok_per_s={qburst['tok_per_s']};"
         f"cache_dtype=int8;"
         f"staggered_tok_per_s={results['engine_tok_per_s_staggered_quant']}")
    emit("serving_quant_cache", results["quant_slot_bytes"],
         f"fp_slot_bytes={results['quant_fp_slot_bytes']};"
         f"shrink_vs_fp={results['quant_cache_shrink_vs_fp']};"
         f"vs_dense={results['quant_compression_vs_dense']}")
    emit("serving_longprompt_chunked",
         longprompt["longprompt_resident_mstok_p99_chunked"] * 1e3,
         f"p99_ratio_chunked={longprompt['longprompt_p99_ratio_chunked']};"
         f"p99_ratio_unchunked="
         f"{longprompt['longprompt_p99_ratio_unchunked']};"
         f"long_len={longprompt['longprompt_len']};"
         f"chunk={longprompt['longprompt_chunk']};"
         f"budget={longprompt['longprompt_token_budget']}")
    emit("serving_engine_burst_windowed", wburst["seconds"] * 1e6,
         f"arch={wcfg.name};window={wcfg.sliding_window};"
         f"req_per_s={wburst['req_per_s']};tok_per_s={wburst['tok_per_s']}")
    emit("serving_engine_staggered_windowed", wstag_s * 1e6,
         f"tok_per_s={results['engine_tok_per_s_staggered_windowed']};"
         f"arrival=1_per_2_steps;ring_kernels=true")
    if "engine_tok_per_s_burst_sharded" in results:
        emit("serving_engine_burst_sharded",
             results["engine_burst_s_sharded"] * 1e6,
             f"mesh={results['engine_mesh']};"
             f"req_per_s={results['engine_req_per_s_burst_sharded']};"
             f"tok_per_s={results['engine_tok_per_s_burst_sharded']};"
             f"staggered_tok_per_s="
             f"{results['engine_tok_per_s_staggered_sharded']}")
    print(f"# wrote {out_path}")
    return results


if __name__ == "__main__":
    run()
