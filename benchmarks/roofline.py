"""Roofline report: reads the dry-run results (results/dryrun.json) and
prints per-(arch × shape × mesh) the three roofline terms, the dominant
bottleneck, MODEL_FLOPS/HLO_FLOPS, and the roofline fraction.

Run the sweep first:
  PYTHONPATH=src python -m repro.launch.dryrun --all --both-meshes \
      --out results/dryrun.json --resume
"""
from __future__ import annotations

import json
import os
import sys

from benchmarks.common import emit

DEFAULT = "results/dryrun.json"


def load(path=DEFAULT):
    if not os.path.exists(path):
        return []
    with open(path) as f:
        return json.load(f)


def run(path=DEFAULT, mesh="16x16"):
    rows = load(path)
    if not rows:
        emit("roofline_missing", 0.0, f"no results at {path}")
        return {}
    table = {}
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        if r.get("latent") is not None or r.get("remat_policy", "nothing") != "nothing":
            continue
        name = f"roofline_{r['arch']}_{r['shape']}_{r['mesh']}"
        if r["status"] == "skipped":
            emit(name, 0.0, "skipped=" + r["reason"][:60])
            continue
        if r["status"] != "ok":
            emit(name, 0.0, "ERROR=" + r.get("error", "?")[:80])
            continue
        rf = r["roofline"]
        mem = r["memory"]["peak_per_device"] / 1e9
        step_s = max(rf["compute_s"], rf["memory_s"], rf["collective_s"])
        table[(r["arch"], r["shape"], r["mesh"])] = r
        emit(name, step_s * 1e6,
             f"bound={rf['bound']};compute_s={rf['compute_s']:.3f};"
             f"memory_s={rf['memory_s']:.3f};collective_s={rf['collective_s']:.3f};"
             f"useful={rf['useful_flops_ratio']:.2f};"
             f"roofline_frac={rf['roofline_fraction']:.4f};mem_GB={mem:.1f}")
    return table


if __name__ == "__main__":
    run(*(sys.argv[1:2] or [DEFAULT]))
