# One-command gates for every PR. `make check` = tier-1 verify + the
# serving/kernel fast-path tests + a reduced-config compression smoke
# test (new pipeline end to end) + the 8-fake-device distributed gate.
# `make bench` runs the quick benchmark sweep (writes BENCH_serving.json,
# incl. engine req/s / tok/s, single-device and 2x4-mesh sharded).
# `make soak` runs the slow engine soak tests that pytest.ini excludes
# from tier-1 verify.
PYTHON ?= python
export PYTHONPATH := src:.$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: verify verify-dist smoke serve-smoke kernels bench bench-quant \
    check soak soak-faults

verify:
	$(PYTHON) -m pytest -x -q

# serving + distributed tier-1 tests under 8 fake CPU devices: the
# sharded-engine / sharded-train subprocesses get their device pool,
# and the single-device serving suite is re-checked against a
# multi-device XLA client (catches placement regressions GSPMD hides
# on 1 device).
verify-dist:
	XLA_FLAGS=--xla_force_host_platform_device_count=8 \
	    $(PYTHON) -m pytest -x -q tests/test_engine_sharded.py \
	    tests/test_engine_window.py tests/test_distributed.py \
	    tests/test_engine.py tests/test_paged.py tests/test_sampling.py \
	    tests/test_serving.py tests/test_faults.py tests/test_server.py \
	    tests/test_chunked_prefill.py tests/test_quant.py

kernels:
	$(PYTHON) -m pytest -x -q tests/test_kernels.py tests/test_serving.py \
	    tests/test_engine.py tests/test_engine_window.py \
	    tests/test_paged.py tests/test_sampling.py \
	    tests/test_cache_layout.py tests/test_chunked_prefill.py \
	    tests/test_quant.py

soak:
	$(PYTHON) -m pytest -q -m soak

# randomized fault soak for the robust request lifecycle: injected step
# failures, NaN logits, pool hogs, and clock skew over the linear and
# paged engines (tests/test_faults.py::test_fault_soak)
soak-faults:
	$(PYTHON) -m pytest -q -m soak tests/test_faults.py

smoke:
	$(PYTHON) examples/compress_arch.py --arch h2o-danube-3-4b \
	    --method latentllm --compression 0.3
	$(PYTHON) examples/compress_arch.py --arch h2o-danube-3-4b \
	    --method asvd_rootcov --compression 0.3 --spare-ends

# boot the HTTP+SSE server on an ephemeral port with a reduced config,
# stream one request through serve/client.py, scrape /metrics +
# /healthz, drain, exit — then (chunked scheduler on) admit a LONG
# prompt mid-decode of a short stream and require it to prefill in
# bounded chunks — asserts internally, non-zero on any failure
serve-smoke:
	$(PYTHON) -m repro.launch.serve --reduced --latent 0.3 --serve \
	    --port 0 --smoke --batch 1 --prompt-len 12 --gen-len 8 \
	    --num-slots 2 --max-len 72 --prefill-chunk 8 --token-budget 12
	$(PYTHON) -m repro.launch.serve --reduced --latent 0.3 --serve \
	    --quant-cache --port 0 --smoke --batch 1 --prompt-len 12 \
	    --gen-len 8 --num-slots 2 --max-len 72

bench:
	$(PYTHON) benchmarks/run.py --quick

# int8-latent-cache quick pass: the quant kernel microbenches + the
# serving sweep whose quant_* entries land in BENCH_serving.json
bench-quant:
	$(PYTHON) -c "from benchmarks.kernels_bench import run; run(quick=True)"
	$(PYTHON) -c "from benchmarks.serving import run; run(quick=True)"

# `verify` already collects the kernel/serving tests; `kernels` stays a
# standalone convenience target for quick fast-path iteration.
check: verify smoke verify-dist
