# One-command gates for every PR. `make check` = tier-1 verify + a
# reduced-config compression smoke test (new pipeline end to end).
PYTHON ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: verify smoke check

verify:
	$(PYTHON) -m pytest -x -q

smoke:
	$(PYTHON) examples/compress_arch.py --arch h2o-danube-3-4b \
	    --method latentllm --compression 0.3
	$(PYTHON) examples/compress_arch.py --arch h2o-danube-3-4b \
	    --method asvd_rootcov --compression 0.3 --spare-ends

check: verify smoke
