"""Checkpoint manager (atomic, keep-k, restore) + data pipeline determinism."""
import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.checkpoint import CheckpointManager
from repro.data import DataConfig, TokenDataset, synthetic_corpus, tokenizer


def _tree():
    return {"a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
            "b": {"c": jnp.ones((2,), jnp.bfloat16),
                  "d": [jnp.zeros((5,), jnp.int32)]}}


def test_checkpoint_roundtrip(tmp_path):
    m = CheckpointManager(str(tmp_path), keep=2)
    tree = _tree()
    m.save(3, tree, {"step": 3})
    restored, extra = m.restore(jax.tree.map(jnp.zeros_like, tree))
    assert extra["step"] == 3
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_checkpoint_keep_k_and_latest(tmp_path):
    m = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        m.save(s, _tree(), {"step": s})
    assert m.all_steps() == [3, 4]
    assert m.latest_step() == 4


def test_checkpoint_no_partial_state_on_crash(tmp_path):
    """A leftover tmp dir (simulated crash) never shadows a valid ckpt."""
    m = CheckpointManager(str(tmp_path), keep=3)
    m.save(1, _tree(), {"step": 1})
    os.makedirs(tmp_path / "tmp.2")  # crashed writer
    (tmp_path / "tmp.2" / "junk.npy").write_bytes(b"garbage")
    assert m.latest_step() == 1
    restored, extra = m.restore(jax.tree.map(jnp.zeros_like, _tree()))
    assert extra["step"] == 1


def test_checkpoint_shape_mismatch_raises(tmp_path):
    m = CheckpointManager(str(tmp_path))
    m.save(0, {"a": jnp.zeros((3,))}, {})
    with pytest.raises(ValueError):
        m.restore({"a": jnp.zeros((4,))})


def test_data_determinism_and_sharding():
    cfg = DataConfig(seq_len=64, global_batch=8, seed=7, n_tokens=100_000)
    full = TokenDataset(cfg, shard_index=0, num_shards=1)
    s0 = TokenDataset(cfg, shard_index=0, num_shards=2)
    s1 = TokenDataset(cfg, shard_index=1, num_shards=2)
    for step in (0, 5, 11):
        g = full.batch_at(step)["tokens"]
        a = s0.batch_at(step)["tokens"]
        b = s1.batch_at(step)["tokens"]
        np.testing.assert_array_equal(g, np.concatenate([a, b], axis=0))
        # replay: same step -> identical batch
        np.testing.assert_array_equal(g, full.batch_at(step)["tokens"])


def test_tokenizer_roundtrip():
    s = "latent tensors! ünïcode"
    ids = tokenizer.encode(s)
    assert tokenizer.decode(ids) == s


def test_synthetic_corpus_deterministic():
    assert synthetic_corpus(1000, 3) == synthetic_corpus(1000, 3)
    assert synthetic_corpus(1000, 3) != synthetic_corpus(1000, 4)
