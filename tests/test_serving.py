"""Serving fast path: scan generation vs the per-token Python loop, and
the end-to-end serve driver."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import REGISTRY, LatentConfig, reduced
from repro.models import lm, transformer as T


def _cfg(name, **kw):
    cfg = dataclasses.replace(reduced(REGISTRY[name]), dtype="float32")
    return dataclasses.replace(cfg, **kw) if kw else cfg


@pytest.mark.parametrize("name,latent", [
    ("opt-125m", False),         # learned pos-emb, qkv bias
    ("deepseek-coder-33b", False),
    ("deepseek-coder-33b", True),
    ("mamba2-2.7b", False),      # pure SSM cache carry through scan
])
def test_scan_generation_matches_python_loop(name, latent):
    cfg = _cfg(name)
    if latent:
        cfg = dataclasses.replace(
            cfg, latent=LatentConfig(enabled=True, compression=0.3))
    key = jax.random.PRNGKey(0)
    params = T.init_params(key, cfg)
    prompt = jax.random.randint(key, (2, 8), 0, cfg.vocab_size)
    g_scan = lm.greedy_generate(cfg, params, prompt, steps=12, max_len=24,
                                use_scan=True)
    g_loop = lm.greedy_generate(cfg, params, prompt, steps=12, max_len=24,
                                use_scan=False)
    assert g_scan.shape == (2, 12)
    np.testing.assert_array_equal(np.asarray(g_scan), np.asarray(g_loop))


def test_scan_generation_absorbed_latent_path():
    """NoPE latent config: prefill kernel + absorbed decode, all under
    one scan dispatch — and identical to the stepwise loop."""
    cfg = _cfg("deepseek-coder-33b", pos_emb="none", qkv_bias=False,
               latent=LatentConfig(enabled=True, compression=0.3))
    key = jax.random.PRNGKey(1)
    params = T.init_params(key, cfg)
    prompt = jax.random.randint(key, (2, 10), 0, cfg.vocab_size)
    g_scan = lm.greedy_generate(cfg, params, prompt, steps=8, max_len=20,
                                use_scan=True)
    g_loop = lm.greedy_generate(cfg, params, prompt, steps=8, max_len=20,
                                use_scan=False)
    np.testing.assert_array_equal(np.asarray(g_scan), np.asarray(g_loop))


def test_generate_step_is_single_dispatch():
    """N-token generation traces the decode body ONCE (lax.scan), not N
    times — the jaxpr must contain a scan over `steps` iterations."""
    cfg = _cfg("deepseek-coder-33b")
    key = jax.random.PRNGKey(2)
    params = T.init_params(key, cfg)
    prompt = jax.random.randint(key, (1, 4), 0, cfg.vocab_size)
    prefill = lm.make_prefill_step(cfg, max_len=16)
    cache, logits = prefill(params, {"tokens": prompt})
    tok = jnp.argmax(logits, axis=-1)[:, None]
    gen = lm.make_generate_step(cfg, steps=7)
    jaxpr = jax.make_jaxpr(gen)(params, cache, tok)
    scans = [e for e in jaxpr.jaxpr.eqns if e.primitive.name == "scan"]
    assert any(e.params.get("length") == 7 for e in scans), \
        "generation is not a single lax.scan over the decode steps"
    toks, _ = gen(params, cache, tok)
    assert toks.shape == (1, 7)


def test_generate_eos_masks_finished_rows():
    """Satellite fix: rows that emit eos stop producing content — the
    remaining steps emit pad_id, identically on scan and loop paths."""
    cfg = _cfg("deepseek-coder-33b")
    key = jax.random.PRNGKey(3)
    params = T.init_params(key, cfg)
    prompt = jax.random.randint(key, (2, 6), 0, cfg.vocab_size)
    base = np.asarray(lm.greedy_generate(cfg, params, prompt, steps=10,
                                         max_len=20))
    # pick a token from the middle of row 0 as "eos"
    eos, pad = int(base[0, 4]), -1
    kw = dict(steps=10, max_len=20, eos_id=eos, pad_id=pad)
    g_scan = np.asarray(lm.greedy_generate(cfg, params, prompt,
                                           use_scan=True, **kw))
    g_loop = np.asarray(lm.greedy_generate(cfg, params, prompt,
                                           use_scan=False, **kw))
    np.testing.assert_array_equal(g_scan, g_loop)
    for b in range(2):
        hits = np.nonzero(base[b] == eos)[0]
        if hits.size:  # everything after the first eos is padding
            i = hits[0]
            np.testing.assert_array_equal(g_scan[b, :i + 1], base[b, :i + 1])
            assert (g_scan[b, i + 1:] == pad).all()
        else:
            np.testing.assert_array_equal(g_scan[b], base[b])
    assert (g_scan[0, 5:] == pad).all()  # row 0 definitely stopped


def test_serve_main_runs_engine(capsys):
    """The serve CLI is a thin driver over the Engine: per-request
    outputs, throughput, and per-slot latent-vs-dense cache bytes."""
    from repro.launch import serve
    done = serve.main(["--arch", "opt-125m", "--reduced", "--batch", "3",
                       "--prompt-len", "8", "--gen-len", "6",
                       "--num-slots", "2", "--no-warmup"])
    assert len(done) == 3
    assert all(r.finished and r.num_generated == 6 for r in done)
    out = capsys.readouterr().out
    assert "req/s" in out and "ms/tok" in out
    assert "cache/slot" in out
    assert out.count("[req ") == 3
