"""Engine scheduling: ragged continuous batching == sequential
generation, slot recycling, fused single-dispatch steps, early finish."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import REGISTRY, LatentConfig, reduced
from repro.models import lm, transformer as T
from repro.serve import Engine, LatentCacheArena, Request, SamplingParams


def _cfg(name, **kw):
    cfg = dataclasses.replace(reduced(REGISTRY[name]), dtype="float32")
    return dataclasses.replace(cfg, **kw) if kw else cfg


def _prompts(seed, lens, vocab):
    rng = np.random.RandomState(seed)
    return [rng.randint(0, vocab, size=L).astype(np.int32) for L in lens]


@pytest.mark.parametrize("name,latent", [
    ("opt-125m", False),            # learned pos-emb, qkv bias
    ("deepseek-coder-33b", False),  # rope
    ("deepseek-coder-33b", True),   # latent absorbed NoPE kernels
])
def test_ragged_batch_matches_sequential_greedy(name, latent):
    """Acceptance: a mixed batch of ragged-length requests decoded in
    one fused dispatch per step is bit-identical to sequential
    single-request greedy generation."""
    cfg = _cfg(name)
    if latent:
        cfg = _cfg(name, pos_emb="none", qkv_bias=False,
                   latent=LatentConfig(enabled=True, compression=0.3))
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    prompts = _prompts(0, (3, 11, 6, 9, 4), cfg.vocab_size)
    eng = Engine(cfg, params, num_slots=2, max_len=32)
    reqs = [eng.submit(p, SamplingParams(max_new_tokens=6)) for p in prompts]
    eng.run()
    for p, r in zip(prompts, reqs):
        assert r.finished and r.finish_reason == "length"
        ref = np.asarray(lm.greedy_generate(cfg, params, p[None], steps=6,
                                            max_len=32))[0]
        np.testing.assert_array_equal(r.output(), ref)


def test_slot_reuse_and_recycling():
    """More requests than slots: the arena recycles; concurrency never
    exceeds num_slots; every request completes."""
    cfg = _cfg("deepseek-coder-33b")
    params = T.init_params(jax.random.PRNGKey(1), cfg)
    prompts = _prompts(1, (5, 3, 8, 4, 7, 6, 3), cfg.vocab_size)
    eng = Engine(cfg, params, num_slots=2, max_len=24)
    for i, p in enumerate(prompts):
        eng.submit(p, SamplingParams(max_new_tokens=3 + (i % 3)))
    peak = 0
    while eng.step():
        peak = max(peak, int(eng._active.sum()))
        assert eng.arena.num_free + int(eng._active.sum()) == 2
    assert peak == 2  # it really batched
    assert len(eng.finished) == len(prompts)
    assert all(r.finished for r in eng.finished)


def test_engine_step_is_single_fused_dispatch():
    """Acceptance (jaxpr-checked): the engine step traces model forward
    AND per-slot sampling into ONE jaxpr — a serving step is a single
    dispatch across all slots, not forward-then-sample round trips."""
    cfg = _cfg("deepseek-coder-33b")
    params = T.init_params(jax.random.PRNGKey(2), cfg)
    B = 3
    cache = T.init_cache(cfg, B, 16)
    cache["pos"] = jnp.array([3, 7, 5], jnp.int32)  # ragged slots
    step = lm.make_engine_step(cfg)
    jaxpr = jax.make_jaxpr(step)(
        params, cache, jnp.zeros((B, 1), jnp.int32),
        jnp.zeros((B, 2), jnp.uint32), jnp.ones((B,), jnp.int32),
        jnp.zeros((B,), jnp.float32), jnp.zeros((B,), jnp.int32),
        jnp.ones((B,), jnp.float32), jnp.ones((B,), bool))
    def prims(jx, acc):
        for e in jx.eqns:
            acc.add(e.primitive.name)
            for v in e.params.values():
                if hasattr(v, "jaxpr"):  # pjit / scan sub-jaxprs
                    prims(v.jaxpr, acc)
        return acc

    top = {e.primitive.name for e in jaxpr.jaxpr.eqns}
    assert "scan" in top                  # the layer stack
    assert "argmax" in top                # token selection, same jaxpr
    assert "random_fold_in" in top        # per-slot PRNG streams
    assert "sort" in prims(jaxpr.jaxpr, set())  # top-k/top-p filtering
    # and the step returns sampled TOKENS (int32), not logits
    assert jaxpr.out_avals[0].dtype == jnp.int32


def test_mixed_sampling_params_one_batch():
    """Greedy and sampled requests share the arena; greedy rows stay
    bit-identical to sequential; sampled rows are seed-reproducible and
    independent of slot placement."""
    cfg = _cfg("opt-125m")
    params = T.init_params(jax.random.PRNGKey(3), cfg)
    prompts = _prompts(3, (4, 9, 6), 256)
    sp = [SamplingParams(max_new_tokens=5),
          SamplingParams(temperature=0.9, top_k=16, seed=5, max_new_tokens=5),
          SamplingParams(temperature=1.1, top_p=0.9, seed=6, max_new_tokens=5)]

    def run(num_slots):
        eng = Engine(cfg, params, num_slots=num_slots, max_len=32)
        reqs = [eng.submit(p, s) for p, s in zip(prompts, sp)]
        eng.run()
        return [tuple(r.output_tokens) for r in reqs]

    a, b = run(3), run(1)
    assert a == b  # slot placement / batching never changes tokens
    ref = np.asarray(lm.greedy_generate(cfg, params, prompts[0][None],
                                        steps=5, max_len=32))[0]
    np.testing.assert_array_equal(np.asarray(a[0]), ref)


def test_eos_and_stop_token_finish_early():
    cfg = _cfg("deepseek-coder-33b")
    params = T.init_params(jax.random.PRNGKey(4), cfg)
    prompt = _prompts(4, (6,), cfg.vocab_size)[0]
    probe = Engine(cfg, params, num_slots=1, max_len=32)
    seq = probe.run([Request(prompt, SamplingParams(max_new_tokens=8))])[0] \
        .output_tokens
    # first token that doesn't appear earlier in the sequence
    idx = next((i for i in range(1, len(seq)) if seq[i] not in seq[:i]), None)
    if idx is None:
        pytest.skip("degenerate constant sequence")
    eng = Engine(cfg, params, num_slots=1, max_len=32)
    r_eos = eng.submit(prompt, SamplingParams(max_new_tokens=8,
                                              eos_id=seq[idx]))
    r_stop = eng.submit(prompt, SamplingParams(max_new_tokens=8,
                                               stop_tokens=(seq[idx],)))
    eng.run()
    assert r_eos.finish_reason == "eos"
    assert r_eos.output_tokens == seq[:idx + 1]   # eos itself emitted
    assert r_stop.finish_reason == "stop"
    assert r_stop.output_tokens == seq[:idx]      # stop token swallowed


def test_streaming_callback_and_stats():
    cfg = _cfg("deepseek-coder-33b")
    params = T.init_params(jax.random.PRNGKey(5), cfg)
    streamed = []
    eng = Engine(cfg, params, num_slots=2, max_len=24)
    req = eng.submit(_prompts(5, (4,), cfg.vocab_size)[0],
                     SamplingParams(max_new_tokens=4),
                     on_token=lambda r, t: streamed.append(t))
    done = eng.run()
    assert streamed == req.output_tokens and len(streamed) == 4
    assert done == [req]
    assert eng.last_stats["requests"] == 1
    assert eng.last_stats["tokens"] == 4
    assert eng.last_stats["tok_per_s"] > 0


def test_engine_rejects_unsupported_configs():
    params = None  # never touched: validation precedes any compute
    with pytest.raises(ValueError, match="recurrent"):
        Engine(_cfg("mamba2-2.7b"), params)
    # sliding-window configs are SERVED now (ring CacheLayout) — the
    # windowed acceptance itself is covered by tests/test_engine_window.py
    wcfg = _cfg("gemma2-27b")
    weng = Engine(wcfg, T.init_params(jax.random.PRNGKey(0), wcfg),
                  num_slots=1, max_len=16)
    assert any(l is not None and l.is_ring
               for l in weng.arena.layouts[0])
    cfg = _cfg("deepseek-coder-33b")
    params = T.init_params(jax.random.PRNGKey(6), cfg)
    eng = Engine(cfg, params, num_slots=1, max_len=16, strict=True)
    with pytest.raises(ValueError, match="exceeds arena max_len"):
        eng.submit(np.arange(10), SamplingParams(max_new_tokens=10))
    # default (non-strict) admission policy rejects instead of raising
    # mid-traffic — tests/test_faults.py covers the full lifecycle
    soft = Engine(cfg, params, num_slots=1, max_len=16)
    r = soft.submit(np.arange(10), SamplingParams(max_new_tokens=10))
    assert r.finished and r.finish_reason == "rejected"
    assert "exceeds arena max_len" in r.error


def test_arena_slot_accounting():
    cfg = _cfg("deepseek-coder-33b")
    arena = LatentCacheArena(cfg, num_slots=3, max_len=16)
    s = [arena.acquire() for _ in range(3)]
    assert sorted(s) == [0, 1, 2] and arena.acquire() is None
    arena.release(s[1])
    assert arena.num_free == 1 and arena.acquire() == s[1]
    assert arena.slot_bytes() > 0
    assert arena.cache["pos"].shape == (3,)


def test_arena_release_validates():
    """Satellite fix: release() detects misuse in O(1) and raises
    instead of silently corrupting the free list (the old assert
    scanned the list AND vanished under -O)."""
    cfg = _cfg("deepseek-coder-33b")
    arena = LatentCacheArena(cfg, num_slots=2, max_len=16)
    s = arena.acquire()
    arena.release(s)
    with pytest.raises(ValueError, match="double release"):
        arena.release(s)
    with pytest.raises(ValueError, match="out of range"):
        arena.release(5)
    assert arena.num_free == 2  # failed releases never mutate the list


@pytest.mark.soak
def test_engine_soak_slot_churn():
    """Soak: heavy churn through a small arena with mixed params —
    everything drains, lengths respect caps, greedy rows stay exact."""
    cfg = _cfg("deepseek-coder-33b")
    params = T.init_params(jax.random.PRNGKey(7), cfg)
    rng = np.random.RandomState(7)
    eng = Engine(cfg, params, num_slots=3, max_len=48)
    reqs = []
    for i in range(40):
        p = rng.randint(0, cfg.vocab_size, size=rng.randint(2, 20))
        temp = 0.0 if i % 3 == 0 else float(rng.uniform(0.5, 1.5))
        reqs.append(eng.submit(p, SamplingParams(
            temperature=temp, top_k=int(rng.choice([0, 8, 32])),
            seed=i, max_new_tokens=int(rng.randint(1, 12)))))
    eng.run()
    assert len(eng.finished) == 40
    for r in reqs:
        assert r.finished and 1 <= r.num_generated <= r.sampling.max_new_tokens
    greedy = [r for i, r in enumerate(reqs) if i % 3 == 0][:4]
    for r in greedy:
        ref = np.asarray(lm.greedy_generate(
            cfg, params, r.prompt[None], steps=r.sampling.max_new_tokens,
            max_len=48))[0]
        np.testing.assert_array_equal(r.output(), ref)


def test_request_rejects_multidim_prompt():
    """Satellite fix: a (2, L) batch passed by mistake must error, not
    silently flatten into one long prompt."""
    with pytest.raises(ValueError, match="1-D"):
        Request(np.zeros((2, 5), np.int32))
    with pytest.raises(ValueError, match="1-D"):
        Request(np.zeros((1, 5), np.int32))   # even a singleton batch
    Request(np.zeros((5,), np.int32))         # 1-D still fine
    Request([1, 2, 3])                        # lists coerce to 1-D


def test_cache_report_consistent_bases():
    """Satellite fix: slot_bytes and dense_slot_bytes share one base —
    per slot of an ARENA-shaped cache (per-slot pos vector included).
    A dense config must therefore report ratio exactly 1.0, and a
    latent config strictly less."""
    cfg = _cfg("deepseek-coder-33b")
    params = T.init_params(jax.random.PRNGKey(8), cfg)
    rep = Engine(cfg, params, num_slots=3, max_len=16).cache_report()
    assert rep["slot_bytes"] == rep["dense_slot_bytes"]
    assert rep["ratio"] == 1.0
    lat = _cfg("deepseek-coder-33b", pos_emb="none", qkv_bias=False,
               num_kv_heads=2,
               latent=LatentConfig(enabled=True, compression=0.3))
    lp = T.init_params(jax.random.PRNGKey(9), lat)
    lrep = Engine(lat, lp, num_slots=3, max_len=16).cache_report()
    assert lrep["slot_bytes"] < lrep["dense_slot_bytes"]
    assert 0 < lrep["ratio"] < 1
