"""Sharded serving engine on 8 fake CPU devices: a 2x4 (data, model)
debug mesh must produce bit-identical tokens to the single-device
engine, recycle slots under mixed traffic, and keep decode a single
fused dispatch (with the Pallas kernels running per-shard).

Run in a SUBPROCESS so the 8-device XLA flag never leaks into the
other tests (smoke tests must see 1 device)."""
import json
import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses
import json
import numpy as np
import jax, jax.numpy as jnp
from repro.configs import REGISTRY, LatentConfig, reduced
from repro.launch.mesh import make_debug_mesh
from repro.models import lm, transformer as T
from repro.serve import Engine, SamplingParams

def _cfg(name, **kw):
    cfg = dataclasses.replace(reduced(REGISTRY[name]), dtype="float32")
    return dataclasses.replace(cfg, **kw) if kw else cfg

out = {}
mesh = make_debug_mesh(2, 4)
rng = np.random.RandomState(0)
prompts = [rng.randint(0, 250, size=L).astype(np.int32)
           for L in (3, 11, 6, 9, 4)]

# num_kv_heads=4 divides the model axis -> per-shard Pallas kernels;
# opt-125m exercises the dense (learned pos-emb, qkv-bias) einsum path.
latent_cfg = _cfg("deepseek-coder-33b", pos_emb="none", qkv_bias=False,
                  num_kv_heads=4,
                  latent=LatentConfig(enabled=True, compression=0.3))
dense_cfg = _cfg("opt-125m")

def run_engine(cfg, params, m, sps, num_slots=4):
    eng = Engine(cfg, params, num_slots=num_slots, max_len=32, mesh=m)
    reqs = [eng.submit(p, sp) for p, sp in zip(prompts, sps)]
    eng.run()
    return [list(map(int, r.output_tokens)) for r in reqs], eng

greedy = [SamplingParams(max_new_tokens=6) for _ in prompts]
sampled = [SamplingParams(temperature=0.8 + 0.1 * i, top_k=(0, 16, 0, 8, 0)[i],
                          top_p=(1.0, 1.0, 0.9, 1.0, 0.95)[i], seed=10 + i,
                          max_new_tokens=6) for i in range(len(prompts))]

for label, cfg in (("latent", latent_cfg), ("dense", dense_cfg)):
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    g_ref, _ = run_engine(cfg, params, None, greedy)
    g_mesh, _ = run_engine(cfg, params, mesh, greedy)
    out[f"greedy_equal_{label}"] = g_ref == g_mesh
    s_ref, _ = run_engine(cfg, params, None, sampled)
    s_mesh, _ = run_engine(cfg, params, mesh, sampled)
    out[f"sampled_equal_{label}"] = s_ref == s_mesh

# -- slot recycling under mixed traffic on the mesh -------------------
params = T.init_params(jax.random.PRNGKey(1), latent_cfg)
eng = Engine(latent_cfg, params, num_slots=2, max_len=24, mesh=mesh)
churn = [rng.randint(0, 250, size=rng.randint(2, 9)).astype(np.int32)
         for _ in range(7)]
for i, p in enumerate(churn):
    eng.submit(p, SamplingParams(temperature=0.0 if i % 2 else 0.9,
                                 seed=i, max_new_tokens=3 + (i % 3)))
peak, invariant = 0, True
while eng.step():
    peak = max(peak, int(eng._active.sum()))
    invariant &= (eng.arena.num_free + int(eng._active.sum()) == 2)
out["recycle_peak"] = peak
out["recycle_invariant"] = bool(invariant)
out["recycle_done"] = int(len(eng.finished))

# -- the sharded decode step is still ONE fused dispatch --------------
B = 4
cache = T.init_cache(latent_cfg, B, 16)
cache["pos"] = jnp.array([3, 7, 5, 2], jnp.int32)
pp = T.init_params(jax.random.PRNGKey(2), latent_cfg)
step = lm.make_engine_step(latent_cfg)
with mesh:
    jaxpr = jax.make_jaxpr(step)(
        pp, cache, jnp.zeros((B, 1), jnp.int32),
        jnp.zeros((B, 2), jnp.uint32), jnp.ones((B,), jnp.int32),
        jnp.zeros((B,), jnp.float32), jnp.zeros((B,), jnp.int32),
        jnp.ones((B,), jnp.float32), jnp.ones((B,), bool))

def prims(jx, acc):
    for e in jx.eqns:
        acc.add(e.primitive.name)
        for v in e.params.values():
            if hasattr(v, "jaxpr"):
                sub = v.jaxpr if hasattr(v.jaxpr, "eqns") else v
                prims(sub, acc)
    return acc

top = {e.primitive.name for e in jaxpr.jaxpr.eqns}
allp = prims(jaxpr.jaxpr, set())
out["one_dispatch"] = bool("scan" in top and "argmax" in top
                           and "random_fold_in" in top)
out["per_shard_kernels"] = bool("shard_map" in allp)
out["tokens_out"] = bool(jaxpr.out_avals[0].dtype == jnp.int32)
print("RESULT:" + json.dumps(out))
"""


@pytest.fixture(scope="module")
def sharded_out():
    env = dict(os.environ,
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..", "src"))
    r = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                       capture_output=True, text=True, timeout=1500)
    assert r.returncode == 0, r.stderr[-3000:]
    line = [l for l in r.stdout.splitlines() if l.startswith("RESULT:")][-1]
    return json.loads(line[len("RESULT:"):])


@pytest.mark.slow
def test_sharded_engine_tokens_bit_identical(sharded_out):
    """Acceptance: 2x4 mesh == single device, greedy AND seeded
    sampling, latent (per-shard kernels) and dense configs."""
    assert sharded_out["greedy_equal_latent"]
    assert sharded_out["greedy_equal_dense"]
    assert sharded_out["sampled_equal_latent"]
    assert sharded_out["sampled_equal_dense"]


@pytest.mark.slow
def test_sharded_engine_slot_recycling(sharded_out):
    """Mixed traffic churns through a 2-slot sharded arena: every
    request completes, concurrency caps at num_slots, accounting
    invariant holds at every step."""
    assert sharded_out["recycle_peak"] == 2
    assert sharded_out["recycle_invariant"]
    assert sharded_out["recycle_done"] == 7


@pytest.mark.slow
def test_sharded_decode_is_single_fused_dispatch(sharded_out):
    """Under the mesh the step still traces forward + sampling into one
    jaxpr, with the grouped decode kernel dispatched per-shard
    (shard_map) rather than gathered."""
    assert sharded_out["one_dispatch"]
    assert sharded_out["per_shard_kernels"]
    assert sharded_out["tokens_out"]
