"""Tests for the composable compression pipeline API: method registry,
per-layer CompressionPlan resolution, streaming multi-batch calibration,
and the backward-compatible ``compress_model`` wrapper."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import REGISTRY, LatentConfig, reduced
from repro.core.compress import (CompressionMethod, CompressionPlan,
                                 Compressor, PlanRule, StreamingStats,
                                 available_methods, compress_model,
                                 get_method, register_method)
from repro.core.precond import activation_stats
from repro.core.ranks import latent_ranks
from repro.models import transformer as T


@pytest.fixture(scope="module")
def tiny_model():
    cfg = dataclasses.replace(
        reduced(REGISTRY["opt-125m"], layers=2, d_model=64),
        dtype="float32",
        latent=LatentConfig(enabled=False, compression=0.3))
    key = jax.random.PRNGKey(0)
    params = T.init_params(key, cfg)
    toks = jax.random.randint(key, (4, 32), 0, cfg.vocab_size)
    return cfg, params, {"tokens": toks}


def _lat(cfg):
    return dataclasses.replace(
        cfg, latent=dataclasses.replace(cfg.latent, enabled=True))


# ----------------------------------------------------------------------
# registry
# ----------------------------------------------------------------------

def test_unknown_method_raises_with_available_list():
    with pytest.raises(ValueError) as ei:
        get_method("not_a_method")
    msg = str(ei.value)
    assert "not_a_method" in msg
    for name in ("plain", "latentllm"):
        assert name in msg


def test_builtins_registered():
    names = available_methods()
    for name in ("plain", "asvd_hessian", "asvd_l1", "asvd_l2", "asvd_cov",
                 "asvd_rootcov", "latentllm"):
        assert name in names
    assert get_method("latentllm").attention_aware
    assert not get_method("asvd_rootcov").attention_aware


def test_duplicate_registration_rejected():
    with pytest.raises(ValueError, match="already registered"):
        register_method(CompressionMethod("plain", precond="identity"))


def test_registered_custom_method_end_to_end(tiny_model):
    cfg, params, batch = tiny_model
    register_method(CompressionMethod(
        "custom_cov_joint", precond="cov", attention_aware=True,
        description="test: full-cov weighting with joint QK"),
        overwrite=True)
    lp, rep = Compressor(params, cfg, method="custom_cov_joint") \
        .calibrate(batch).compress()
    assert rep["method"] == "custom_cov_joint"
    assert all(e["modules"]["attention"]["method"] == "custom_cov_joint"
               for e in rep["entries"])
    logits, _, _ = T.forward(lp, _lat(cfg), tokens=batch["tokens"])
    assert bool(jnp.all(jnp.isfinite(logits)))


# ----------------------------------------------------------------------
# CompressionPlan
# ----------------------------------------------------------------------

def test_plan_override_resolution(tiny_model):
    cfg, _, _ = tiny_model
    plan = CompressionPlan(
        method="latentllm", compression=0.3,
        rules=(PlanRule(blocks="1:", compression=0.5),
               PlanRule(blocks=-1, module="mlp", method="asvd_l2",
                        ranks={"r_d": 16})))
    n = cfg.num_layers
    r0 = plan.resolve(cfg, 0, n, "attention")
    assert r0.method.name == "latentllm" and r0.compression == 0.3
    r1a = plan.resolve(cfg, n - 1, n, "attention")
    assert r1a.method.name == "latentllm" and r1a.compression == 0.5
    r1m = plan.resolve(cfg, n - 1, n, "mlp")
    assert r1m.method.name == "asvd_l2"
    assert r1m.ranks["r_d"] == 16
    # harder compression -> ranks no larger than the uniform ones
    uni = latent_ranks(cfg)
    assert r1a.ranks["r_q"] <= uni["r_q"]


def test_plan_unknown_rank_key_raises(tiny_model):
    cfg, _, _ = tiny_model
    plan = CompressionPlan(rules=(PlanRule(ranks={"r_bogus": 8}),))
    with pytest.raises(ValueError, match="r_bogus"):
        plan.resolve(cfg, 0, 2, "mlp")


def test_plan_dict_round_trip():
    plan = CompressionPlan(
        method="asvd_rootcov", compression=0.25,
        rules=(PlanRule(blocks=(0, "last:1"), module="mlp",
                        method="plain", compression=0.4,
                        ranks={"r_u": 24}),
               PlanRule(blocks="2:-2", compression=0.6)))
    again = CompressionPlan.from_dict(plan.to_dict())
    assert again == plan


def test_per_layer_rank_override_compresses_and_serves(tiny_model):
    cfg, params, batch = tiny_model
    plan = CompressionPlan(
        method="latentllm",
        rules=(PlanRule(blocks=1, module="mlp", ranks={"r_d": 16}),))
    lp, rep = Compressor(params, cfg, plan=plan).calibrate(batch).compress()
    assert rep["entries"][1]["modules"]["mlp"]["ranks"]["r_d"] == 16
    # factors are zero-padded back to the uniform ranks so the stacked
    # scan and the latent cache keep homogeneous shapes ...
    uni = latent_ranks(cfg)
    down_b = lp["groups"][0]["mlp"]["down_b"]  # stacked (n_layers, r_d, d)
    assert down_b.shape[1] == uni["r_d"]
    # ... and the pad region really is zero (the override is effective)
    assert float(jnp.max(jnp.abs(down_b[1, 16:, :]))) == 0.0
    assert float(jnp.max(jnp.abs(down_b[0, 16:, :]))) > 0.0
    logits, _, _ = T.forward(lp, _lat(cfg), tokens=batch["tokens"])
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_rank_override_above_uniform_rejected(tiny_model):
    cfg, params, batch = tiny_model
    uni = latent_ranks(cfg)
    plan = CompressionPlan(
        rules=(PlanRule(blocks=0, module="mlp",
                        ranks={"r_d": uni["r_d"] + 8}),))
    with pytest.raises(ValueError, match="only reduce"):
        Compressor(params, cfg, plan=plan).calibrate(batch).compress()


def test_plan_summary_reports_params(tiny_model):
    cfg, _, _ = tiny_model
    plan = CompressionPlan.spare_ends(compression=0.3, spare=1)
    rows = plan.summary_rows(cfg)
    assert len(rows) == cfg.num_layers
    for row in rows:
        assert 0 < row["params_latent"] < row["params_dense"]
        assert row["flops_latent"] == 2 * row["params_latent"]
    # middle blocks are compressed harder than the spared ends
    if len(rows) > 2:
        assert (rows[1]["params_latent"] < rows[0]["params_latent"])
    text = plan.summary(cfg)
    assert "total block params" in text


# ----------------------------------------------------------------------
# streaming calibration
# ----------------------------------------------------------------------

def test_streaming_stats_match_single_batch():
    key = jax.random.PRNGKey(7)
    X = jax.random.normal(key, (48, 640)) * 2.0 + 0.5
    st = StreamingStats(48)
    for lo, hi in ((0, 100), (100, 350), (350, 640)):
        st.update(X[:, lo:hi], columns=True)
    fs = st.finalize(1e-2)
    C_ref, mu_ref = activation_stats(X, 1e-2)
    np.testing.assert_allclose(np.asarray(fs.C), np.asarray(C_ref),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(fs.mu), np.asarray(mu_ref),
                               rtol=1e-5, atol=1e-5)
    assert fs.count == 640
    assert fs.X.shape == (48, 640)


def test_streaming_stats_row_major_update():
    h = jax.random.normal(jax.random.PRNGKey(3), (2, 16, 24))
    st = StreamingStats(24).update(h)
    fs = st.finalize(0.0)
    X = h.reshape(-1, 24).T
    np.testing.assert_allclose(np.asarray(fs.C),
                               np.asarray((X @ X.T) / X.shape[1]),
                               rtol=1e-5, atol=1e-5)


def test_multi_batch_compression_matches_concatenated(tiny_model):
    """Two half-batches streamed == one concatenated batch, end to end."""
    cfg, params, batch = tiny_model
    toks = batch["tokens"]
    halves = [{"tokens": toks[:2]}, {"tokens": toks[2:]}]
    lp_stream, _ = Compressor(params, cfg, method="asvd_rootcov") \
        .calibrate(halves).compress()
    lp_concat, _ = Compressor(params, cfg, method="asvd_rootcov") \
        .calibrate(batch).compress()
    for a, b in zip(jax.tree.leaves(lp_stream), jax.tree.leaves(lp_concat)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=5e-3, atol=5e-3)


# ----------------------------------------------------------------------
# backward compatibility + misc driver behavior
# ----------------------------------------------------------------------

def test_compress_model_wrapper_matches_compressor(tiny_model):
    cfg, params, batch = tiny_model
    lp_old, rep_old = compress_model(params, cfg, batch, method="asvd_l2")
    lp_new, _ = Compressor(params, cfg, method="asvd_l2") \
        .calibrate(batch).compress()
    assert rep_old["blocks"] == cfg.num_layers
    for a, b in zip(jax.tree.leaves(lp_old), jax.tree.leaves(lp_new)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-6, atol=1e-6)


def test_compress_model_unknown_method_raises(tiny_model):
    cfg, params, batch = tiny_model
    with pytest.raises(ValueError, match="available"):
        compress_model(params, cfg, batch, method="nope")


def test_compress_before_calibrate_raises(tiny_model):
    cfg, params, _ = tiny_model
    with pytest.raises(RuntimeError, match="calibrate"):
        Compressor(params, cfg).compress()


def test_report_entries_have_recon_and_timing(tiny_model):
    cfg, params, batch = tiny_model
    lp, rep = compress_model(params, cfg, batch, method="latentllm")
    assert rep["n_blocks"] == cfg.num_layers
    assert len(rep["entries"]) == rep["blocks"]
    for e in rep["entries"]:
        assert e["seconds"] >= 0.0
        for mod, mi in e["modules"].items():
            assert "ranks" in mi and "method" in mi
            for v in mi.get("recon", {}).values():
                assert 0.0 <= v < 1.5
