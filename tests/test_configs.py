"""Config registry + analytic parameter counts + rank selection."""
import math

import pytest

from repro.configs import ASSIGNED, REGISTRY, SHAPES, reduced, input_specs, shape_applicable
from repro.core.ranks import latent_ranks, rank_for_reduction

EXPECTED_PARAMS_B = {
    "mamba2-2.7b": 2.7, "chameleon-34b": 34.3, "musicgen-large": 2.4,
    "qwen1.5-110b": 111.2, "h2o-danube-3-4b": 4.0, "gemma2-27b": 27.2,
    "deepseek-coder-33b": 33.3, "phi3.5-moe-42b-a6.6b": 41.9,
    "llama4-maverick-400b-a17b": 397.7, "zamba2-7b": 6.6,
}


@pytest.mark.parametrize("name", ASSIGNED)
def test_param_counts_match_advertised(name):
    got = REGISTRY[name].num_params() / 1e9
    assert abs(got - EXPECTED_PARAMS_B[name]) / EXPECTED_PARAMS_B[name] < 0.05


def test_moe_active_params():
    phi = REGISTRY["phi3.5-moe-42b-a6.6b"]
    assert abs(phi.num_active_params() / 1e9 - 6.6) < 0.5
    l4 = REGISTRY["llama4-maverick-400b-a17b"]
    assert 12 < l4.num_active_params() / 1e9 < 20


@pytest.mark.parametrize("name", ASSIGNED)
def test_input_specs_all_cells(name):
    cfg = REGISTRY[name]
    for shape in SHAPES.values():
        ok, why = shape_applicable(cfg, shape)
        if not ok:
            assert shape.name == "long_500k"
            continue
        specs = input_specs(cfg, shape)
        assert specs, (name, shape.name)
        for leaf in specs.values():
            assert leaf.shape[0] == shape.global_batch


def test_long_500k_only_subquadratic():
    runnable = [n for n in ASSIGNED
                if shape_applicable(REGISTRY[n], SHAPES["long_500k"])[0]]
    assert set(runnable) == {"mamba2-2.7b", "zamba2-7b", "h2o-danube-3-4b"}


def test_rank_for_reduction_block_identity_formula():
    d, dp, c = 1024, 1024, 0.25
    r = rank_for_reduction(d, dp, c, block_identity=True)
    params = r * (d + dp) - r * r
    target = (1 - c) * d * dp
    assert abs(params - target) / target < 0.05
    # §3.3: always fewer params than dense for r < min(d, d')
    assert params < d * dp


@pytest.mark.parametrize("name", ASSIGNED)
def test_reduced_configs_tiny(name):
    r = reduced(REGISTRY[name])
    assert r.d_model <= 128 and r.num_layers <= 8
    assert r.family == REGISTRY[name].family
