"""Async serving front-end (ISSUE 8): the HTTP+SSE server, scheduler
thread, and metrics layer over the Engine — SSE streams from CONCURRENT
clients bit-identical to direct ``Engine.run()`` (greedy and seeded
sampling), admission backpressure mapped to HTTP statuses, live
/metrics while requests are in flight, cancel-by-id, drain semantics,
and the report-schema contract tests."""
import contextlib
import dataclasses
import http.client
import json
import threading
import time

import jax
import numpy as np
import pytest

from repro.configs import REGISTRY, LatentConfig, reduced
from repro.models import transformer as T
from repro.serve import (Engine, MetricsRegistry, RequestState,
                         RingHistogram, SamplingParams, ServeClient,
                         ServeHTTPError, ServeServer)
from repro.serve.client import sse_events
from repro.serve.request import Request
from repro.serve.server import BadRequest, build_request, request_result


def _cfg(name="deepseek-coder-33b", **kw):
    cfg = dataclasses.replace(reduced(REGISTRY[name]), dtype="float32")
    return dataclasses.replace(cfg, **kw) if kw else cfg


LATENT = _cfg(pos_emb="none", qkv_bias=False,
              latent=LatentConfig(enabled=True, compression=0.3))


@pytest.fixture(scope="module")
def params():
    return T.init_params(jax.random.PRNGKey(0), LATENT)


def _prompts(seed, lens, vocab=250):
    rng = np.random.RandomState(seed)
    return [rng.randint(0, vocab, size=L).astype(np.int32) for L in lens]


# greedy AND seeded-sampling traffic for the bit-identity acceptance run
PROMPTS = _prompts(0, (5, 9, 7, 11))
SPS = [SamplingParams(max_new_tokens=6),
       SamplingParams(max_new_tokens=6),
       SamplingParams(max_new_tokens=6, temperature=0.9, top_k=16, seed=13),
       SamplingParams(max_new_tokens=6, temperature=0.7, top_p=0.9, seed=29)]


def _sp_body(sp):
    return {"max_new_tokens": sp.max_new_tokens,
            "temperature": sp.temperature, "top_k": sp.top_k,
            "top_p": sp.top_p, "seed": sp.seed}


@pytest.fixture(scope="module")
def refs(params):
    """Direct single-threaded Engine.run() — the serving reference."""
    eng = Engine(LATENT, params, num_slots=2, max_len=32)
    reqs = [eng.submit(p, sp) for p, sp in zip(PROMPTS, SPS)]
    eng.run()
    assert all(r.state is RequestState.FINISHED for r in reqs)
    return [[int(t) for t in r.output_tokens] for r in reqs]


@contextlib.contextmanager
def _serving(params, **kw):
    eng = Engine(LATENT, params, num_slots=kw.pop("num_slots", 2),
                 max_len=kw.pop("max_len", 32),
                 max_queue=kw.pop("max_queue", 16),
                 metrics=MetricsRegistry(), **kw)
    srv = ServeServer(eng)
    host, port = srv.start()
    try:
        yield srv, ServeClient(host, port)
    finally:
        srv.stop(drain=False, timeout_s=60.0)


def _post_stream(srv, body):
    """Raw streaming POST: returns (conn, resp, events) WITHOUT reading
    the stream — the request is live in the engine once status is 200."""
    conn = http.client.HTTPConnection(srv.host, srv.port, timeout=120)
    conn.request("POST", "/v1/generate", json.dumps(body),
                 {"Content-Type": "application/json"})
    resp = conn.getresponse()
    return conn, resp, (sse_events(resp) if resp.status == 200 else None)


# -- acceptance: concurrent SSE == direct Engine.run() -----------------

def test_concurrent_sse_bit_identical_to_engine_run(params, refs):
    """N client threads stream concurrently; per-request greedy AND
    seeded-sampled tokens are bit-identical to the direct run, and the
    per-token SSE events agree with the terminal done payload."""
    with _serving(params) as (srv, client):
        out = [None] * len(PROMPTS)
        streamed = [[] for _ in PROMPTS]

        def worker(i):
            out[i] = client.generate(
                [int(t) for t in PROMPTS[i]],
                on_token=streamed[i].append, **_sp_body(SPS[i]))

        threads = [threading.Thread(target=worker, args=(i,))
                   for i in range(len(PROMPTS))]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=300)
        for i, ref in enumerate(refs):
            assert out[i] is not None, f"client {i} did not finish"
            assert out[i]["finish_reason"] == "length"
            assert out[i]["tokens"] == streamed[i] == ref
            assert out[i]["ttft_s"] is not None
            assert out[i]["latency_s"] >= out[i]["ttft_s"]
        # non-streaming JSON mode: same engine, same answer
        blob = client.generate([int(t) for t in PROMPTS[0]], stream=False,
                               **_sp_body(SPS[0]))
        assert blob["tokens"] == refs[0]
        assert blob["state"] == "finished"


def test_text_prompt_roundtrip(params):
    """``{"text": ...}`` bodies tokenize server-side (byte tokenizer)."""
    with _serving(params) as (srv, client):
        out = client.generate(text="serve", max_new_tokens=4)
        assert out["num_generated"] == 4
        assert out["finish_reason"] == "length"


# -- admission errors on the wire --------------------------------------

def test_bad_request_http_400(params):
    with _serving(params) as (srv, client):
        for body in ({},                                    # no prompt
                     {"prompt": [1], "text": "x"},          # both
                     {"prompt": "not-a-list"},
                     {"prompt": [1.5, 2.5]},
                     {"prompt": [1], "bogus_field": 1},
                     {"prompt": [1], "max_new_tokens": 0},  # bad sampling
                     {"prompt": [1, LATENT.vocab_size + 7]}):  # engine rej
            with pytest.raises(ServeHTTPError) as e:
                client._json_call("POST", "/v1/generate", body)
            assert e.value.status == 400, body
        # malformed JSON body
        conn = http.client.HTTPConnection(srv.host, srv.port, timeout=30)
        conn.request("POST", "/v1/generate", b"{not json",
                     {"Content-Type": "application/json"})
        assert conn.getresponse().status == 400
        conn.close()
        # unknown routes
        assert client.healthz()["status"] == "ok"
        with pytest.raises(ServeHTTPError) as e:
            client._json_call("GET", "/nope")
        assert e.value.status == 404


def test_backpressure_live_metrics_and_drain(params):
    """One slot, queue bound 1: request A runs, B queues, C bounces with
    429 + the engine's reject reason. While A streams, /metrics already
    serves TTFT quantiles, occupancy gauges, and lifecycle counters
    (observability is LIVE, not post-hoc). stop(drain=True) then lets A
    and B finish their streams — clients see complete token sequences
    and done events — before the listener exits."""
    with _serving(params, num_slots=1, max_len=128, max_queue=1) \
            as (srv, client):
        long_body = {"prompt": [3, 5, 7], "max_new_tokens": 80}
        conn_a, resp_a, ev_a = _post_stream(srv, long_body)
        assert resp_a.status == 200
        assert next(ev_a)[0] == "start"          # A admitted and streaming
        conn_b, resp_b, ev_b = _post_stream(srv, long_body)
        assert resp_b.status == 200              # B queued behind A
        with pytest.raises(ServeHTTPError) as e:  # C: bounded queue
            client.generate([1, 2], max_new_tokens=4)
        assert e.value.status == 429 and "queue full" in e.value.reason

        deadline = time.monotonic() + 60
        while time.monotonic() < deadline:       # A's first token lands
            snap = client.metrics()
            if snap["histograms"].get("ttft_s", {}).get("count"):
                break
            time.sleep(0.05)
        hist = snap["histograms"]["ttft_s"]
        assert hist["count"] >= 1 and "p50" in hist and "p99" in hist
        g = snap["gauges"]
        assert g["running"] >= 1 and g["queue_depth"] >= 1
        assert g["slots_total"] == 1 and g["slot_bytes"] > 0
        assert g["cache_bytes_in_use"] == g["slot_bytes"] * g["slots_total"]
        assert g["cache_compression_ratio"] >= 1.0
        assert snap["counters"]["requests_submitted"] >= 2
        hz = client.healthz()
        assert hz["status"] == "ok" and hz["running"] >= 1
        prom = client.metrics("prometheus")
        assert "# TYPE serve_queue_depth gauge" in prom
        assert 'serve_ttft_s{quantile="0.5"}' in prom
        assert "serve_requests_submitted_total" in prom

        # first-SIGINT path: drain — both in-flight streams complete
        assert srv.stop(drain=True, timeout_s=300.0)
        for conn, evs in ((conn_a, ev_a), (conn_b, ev_b)):
            toks, done = [], None
            for event, payload in evs:
                if event == "token":
                    toks.append(payload["token"])
                elif event == "done":
                    done = payload
            assert done is not None and done["state"] == "finished"
            assert done["tokens"] == toks and len(toks) == 80
            conn.close()
        snap = srv.metrics.snapshot()
        assert snap["histograms"]["e2e_s"]["count"] >= 2
        assert snap["histograms"]["ms_per_token"]["count"] >= 2
        assert srv.health()["status"] == "stopped"


def test_cancel_live_request(params):
    with _serving(params, num_slots=1, max_len=128) as (srv, client):
        conn, resp, evs = _post_stream(
            srv, {"prompt": [2, 4, 6], "max_new_tokens": 90})
        assert resp.status == 200
        event, payload = next(evs)
        assert event == "start"
        rid = payload["request_id"]
        assert rid == int(resp.headers["X-Request-Id"])
        while True:                             # mid-decode, then cancel
            event, payload = next(evs)
            if event == "token":
                break
        assert client.cancel(rid) is True
        done = None
        for event, payload in evs:
            if event == "done":
                done = payload
        assert done is not None and done["state"] == "cancelled"
        assert done["finish_reason"] == "cancelled"
        assert 0 < done["num_generated"] < 90
        conn.close()
        assert client.cancel(rid) is False      # terminal: exactly once
        assert client.cancel(10 ** 6) is False  # unknown id
        # the slot is free again: a fresh request runs to completion
        out = client.generate([1, 2, 3], max_new_tokens=3)
        assert out["finish_reason"] == "length"


def test_abort_stop_cancels_residents(params):
    """The second-SIGINT path: stop(drain=False) cancels the resident
    mid-stream; its client still receives a terminal done event."""
    with _serving(params, num_slots=1, max_len=128) as (srv, client):
        conn, resp, evs = _post_stream(
            srv, {"prompt": [9, 9], "max_new_tokens": 90})
        assert next(evs)[0] == "start"
        assert srv.stop(drain=False, timeout_s=120.0)
        done = [p for e, p in evs if e == "done"]
        assert done and done[0]["state"] == "cancelled"
        conn.close()


def test_paged_server_block_gauges(params):
    """A paged engine's /metrics adds block occupancy and prefix hit
    rate; repeated prompts drive the hit rate above zero."""
    with _serving(params, num_slots=2, paged=True, block_size=8) \
            as (srv, client):
        body = [int(t) for t in PROMPTS[3]]
        for _ in range(2):                       # second run hits the tree
            out = client.generate(body, max_new_tokens=4)
            assert out["finish_reason"] == "length"
        g = client.metrics()["gauges"]
        assert g["num_blocks"] > 0 and "blocks_in_use" in g
        assert g["prefix_hit_rate"] > 0
        prom = client.metrics("prometheus")
        assert "# TYPE serve_prefix_hit_rate gauge" in prom


# -- schema contracts (satellite: report key sets are API) -------------

def test_report_contracts(params):
    eng = Engine(LATENT, params, num_slots=2, max_len=32)
    reqs = [eng.submit(p, SamplingParams(max_new_tokens=3))
            for p in PROMPTS[:2]]
    eng.run()
    life = eng.lifecycle_report()
    assert set(life) == {"queued", "running", "prefilling", "finished",
                         "rejected", "draining", "counters"}
    assert set(eng.scheduler_report()) == {
        "chunked", "token_budget", "prefill_chunk", "prefill_chunks",
        "prefill_chunk_tokens", "prefill_backlog_tokens", "prefilling",
        "prefill_share", "slo_backoffs", "ttft_risk_boosts"}
    assert set(eng.last_stats) == {"requests", "tokens", "steps", "seconds",
                                   "req_per_s", "tok_per_s"}
    assert set(eng.cache_report()) == {
        "slot_bytes", "dense_slot_bytes", "ratio", "cache_dtype",
        "fp_slot_bytes", "compression_vs_dense"}
    paged = Engine(LATENT, params, num_slots=2, max_len=32, paged=True,
                   block_size=8)
    assert set(paged.cache_report()) == {
        "slot_bytes", "dense_slot_bytes", "ratio", "cache_dtype",
        "fp_slot_bytes", "compression_vs_dense", "prefix_hit_rate",
        "prefix_hit_requests", "requests_admitted", "blocks_in_use",
        "num_blocks", "prefill_tokens_saved", "prefill_tokens_computed"}
    assert set(request_result(reqs[0])) == {
        "request_id", "tokens", "num_generated", "finish_reason", "state",
        "error", "num_preemptions", "ttft_s", "latency_s"}


def test_request_timing_fields(params):
    eng = Engine(LATENT, params, num_slots=1, max_len=32,
                 metrics=MetricsRegistry())
    r = eng.submit(PROMPTS[0], SamplingParams(max_new_tokens=4))
    assert r.ttft_s is None and r.latency_s is None      # not started
    eng.run()
    assert r.first_token_time is not None
    assert 0 <= r.ttft_s <= r.latency_s
    snap = eng.metrics.snapshot()
    assert snap["counters"]["requests_finished"] == 1
    assert snap["histograms"]["ttft_s"]["count"] == 1
    assert snap["histograms"]["e2e_s"]["count"] == 1
    assert snap["histograms"]["ms_per_token"]["count"] == 1


# -- units: no engine needed -------------------------------------------

def test_build_request_validation():
    with pytest.raises(BadRequest, match="JSON object"):
        build_request([1, 2])
    with pytest.raises(BadRequest, match="exactly one"):
        build_request({"prompt": [1], "text": "x"})
    with pytest.raises(BadRequest, match="unknown fields"):
        build_request({"prompt": [1], "nope": 1})
    with pytest.raises(BadRequest, match="integer token ids"):
        build_request({"prompt": [1, "a"]})
    with pytest.raises(BadRequest, match="max_new_tokens"):
        build_request({"prompt": [1], "max_new_tokens": 0})
    req = build_request({"prompt": [1, 2], "temperature": 0.5, "seed": 3,
                         "stop_tokens": [7], "priority": 2,
                         "deadline_s": 9.0})
    assert isinstance(req, Request)
    assert req.sampling.stop_tokens == (7,)
    assert req.priority == 2 and req.deadline_s == 9.0


def test_ring_histogram_window():
    h = RingHistogram(capacity=4)
    assert h.summary() == {"count": 0, "window": 0}
    for v in (1.0, 2.0, 3.0, 4.0, 100.0, 200.0):   # 1.0, 2.0 evicted
        h.observe(v)
    s = h.summary()
    assert s["count"] == 6 and s["window"] == 4
    assert s["max"] == 200.0
    assert s["p50"] == pytest.approx(np.percentile([3, 4, 100, 200], 50))
    with pytest.raises(ValueError):
        RingHistogram(capacity=0)


def test_metrics_registry_formats():
    m = MetricsRegistry()
    m.inc("requests_finished")
    m.inc("requests_finished", 2)
    m.set_counter("preemptions", 5)
    m.set_gauges({"queue_depth": 3, "slots_free": 1})
    for v in (0.1, 0.2, 0.3):
        m.observe("ttft_s", v)
    snap = m.snapshot()
    assert snap["counters"] == {"requests_finished": 3, "preemptions": 5}
    assert snap["gauges"]["queue_depth"] == 3
    assert snap["histograms"]["ttft_s"]["count"] == 3
    prom = m.to_prometheus()
    assert "serve_requests_finished_total 3" in prom
    assert "# TYPE serve_queue_depth gauge" in prom
    assert 'serve_ttft_s{quantile="0.99"}' in prom
    assert "serve_ttft_s_count 3" in prom
    json.dumps(snap)                               # JSON-serializable
