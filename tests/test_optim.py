"""Optimizer + gradient compression tests."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.optim import (AdamW, AdamWConfig, GradCompressionConfig,
                         compress_decompress, init_compression_state)
from repro.optim.adamw import QBLOCK, _dequantize_blockwise, _quantize_blockwise


def _quadratic_losses(moments_dtype, steps=60):
    """Minimize ‖Wx−y‖² — all moment dtypes should make steady progress."""
    rng = np.random.default_rng(0)
    Wt = jnp.asarray(rng.normal(size=(16, 256)), jnp.float32)
    X = jnp.asarray(rng.normal(size=(256, 64)), jnp.float32)
    Y = Wt @ X
    params = {"w": jnp.zeros((16, 256), jnp.float32)}
    opt = AdamW(AdamWConfig(lr=0.05, warmup_steps=1, total_steps=steps,
                            weight_decay=0.0, moments_dtype=moments_dtype))
    state = opt.init(params)

    def loss_fn(p):
        return jnp.mean((p["w"] @ X - Y) ** 2)

    losses = []
    for s in range(steps):
        loss, g = jax.value_and_grad(loss_fn)(params)
        params, state = opt.update(g, state, params, jnp.asarray(s))
        losses.append(float(loss))
    return losses


@pytest.mark.parametrize("dtype", ["float32", "bfloat16", "int8"])
def test_adamw_converges_all_moment_dtypes(dtype):
    losses = _quadratic_losses(dtype)
    assert losses[-1] < losses[0] * 0.05, (dtype, losses[0], losses[-1])


def test_int8_quant_roundtrip():
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=(8, 512)) * 3.0, jnp.float32)
    q, s = _quantize_blockwise(x)
    assert q.dtype == jnp.int8 and q.shape == (8, 512 // QBLOCK, QBLOCK)
    x2 = _dequantize_blockwise(q, s, x.shape)
    rel = float(jnp.max(jnp.abs(x - x2)) / jnp.max(jnp.abs(x)))
    assert rel < 1.5 / 127


def test_powersgd_error_feedback_unbiased_over_steps():
    """With error feedback, the ACCUMULATED compressed gradient converges
    to the accumulated true gradient — for realistic (decaying-spectrum)
    gradients; a flat spectrum is the documented worst case."""
    rng = np.random.default_rng(2)
    m, n = 256, 512
    U, _ = np.linalg.qr(rng.normal(size=(m, m)))
    V, _ = np.linalg.qr(rng.normal(size=(n, n)))
    s = 1.0 / (1.0 + np.arange(m)) ** 1.5   # power-law singular values
    G = jnp.asarray((U * s) @ V[:m], jnp.float32)
    cfg = GradCompressionConfig(method="powersgd", rank=8, min_size=1)
    state = init_compression_state({"w": G}, cfg)
    acc_true = jnp.zeros_like(G)
    acc_comp = jnp.zeros_like(G)
    for _ in range(10):
        approx, state, stats = compress_decompress({"w": G}, state, cfg)
        acc_true += G
        acc_comp += approx["w"]
    rel = float(jnp.linalg.norm(acc_true - acc_comp)
                / jnp.linalg.norm(acc_true))
    assert rel < 0.1, rel
    assert stats["compressed_bytes"] < stats["dense_bytes"] * 0.15


def test_powersgd_exact_on_lowrank_gradients():
    rng = np.random.default_rng(3)
    U = jnp.asarray(rng.normal(size=(128, 4)), jnp.float32)
    V = jnp.asarray(rng.normal(size=(4, 256)), jnp.float32)
    G = U @ V  # exactly rank 4 < compression rank 8
    cfg = GradCompressionConfig(method="powersgd", rank=8, min_size=1)
    state = init_compression_state({"w": G}, cfg)
    approx, state, _ = compress_decompress({"w": G}, state, cfg)
    rel = float(jnp.linalg.norm(G - approx["w"]) / jnp.linalg.norm(G))
    assert rel < 1e-4


def test_int8_gradient_compression_close():
    rng = np.random.default_rng(4)
    G = jnp.asarray(rng.normal(size=(64, 64)), jnp.float32)
    cfg = GradCompressionConfig(method="int8")
    approx, _, stats = compress_decompress({"w": G}, None, cfg)
    rel = float(jnp.max(jnp.abs(G - approx["w"])) / jnp.max(jnp.abs(G)))
    assert rel < 2.0 / 127
