"""Quantized latent serving: int8 cache quantizer round-trips, the
in-kernel-dequant Pallas kernels against their oracles, engine greedy
parity int8-vs-fp, the "quant" weight-compression method, and the
single-fused-dispatch jaxpr pin with an int8 arena (single device and a
2x4 debug mesh)."""
import dataclasses
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import REGISTRY, LatentConfig, reduced
from repro.core.compress import fake_quant_weight, get_method
from repro.kernels import ops, ref
from repro.kernels import quant as kq
from repro.models import lm, transformer as T
from repro.serve import Engine, SamplingParams


def _cfg(name, **kw):
    cfg = dataclasses.replace(reduced(REGISTRY[name]), dtype="float32")
    return dataclasses.replace(cfg, **kw) if kw else cfg


def _latent_cfg(**kw):
    return _cfg("deepseek-coder-33b", pos_emb="none", qkv_bias=False,
                latent=LatentConfig(enabled=True, compression=0.3), **kw)


def _prompts(seed, lens, vocab):
    rng = np.random.RandomState(seed)
    return [rng.randint(0, vocab, size=L).astype(np.int32) for L in lens]


# ----------------------------------------------------------------------
# quantizer round-trip (deterministic; the hypothesis sweep is below)
# ----------------------------------------------------------------------

def test_quantize_rows_round_trip_error_bound():
    """|c - deq(q)| <= max|c| / 253 per row: half a grid step plus
    rounding slack."""
    rng = np.random.default_rng(0)
    c = jnp.asarray(rng.standard_normal((5, 33, 17)) * 3.0, jnp.float32)
    q, s = kq.quantize_rows(c)
    assert q.dtype == jnp.int8 and s.shape == (5, 33, 1)
    err = jnp.abs(c - kq.dequantize_rows(q, s))
    bound = jnp.max(jnp.abs(c), axis=-1, keepdims=True) / 253.0
    assert bool(jnp.all(err <= bound + 1e-7))


def test_quantize_rows_zero_row_guard():
    c = jnp.zeros((2, 4, 8), jnp.float32)
    q, s = kq.quantize_rows(c)
    assert bool(jnp.all(q == 0)) and bool(jnp.all(s == 0))
    assert bool(jnp.all(kq.dequantize_rows(q, s) == 0))


def test_quantize_rows_nonfinite_guard():
    """One NaN/Inf element must not blank its row: non-finite entries
    are zeroed BEFORE the absmax, the rest of the row survives."""
    c = np.ones((1, 2, 4), np.float32)
    c[0, 0, 1] = np.nan
    c[0, 1, 2] = np.inf
    q, s = kq.quantize_rows(jnp.asarray(c))
    deq = np.asarray(kq.dequantize_rows(q, s))
    assert np.all(np.isfinite(deq))
    np.testing.assert_allclose(deq[0, 0, [0, 2, 3]], 1.0, atol=1e-2)
    assert deq[0, 0, 1] == 0.0 and deq[0, 1, 2] == 0.0


def test_cache_entry_round_trip():
    rng = np.random.default_rng(1)
    ck = jnp.asarray(rng.standard_normal((2, 8, 12)), jnp.float32)
    cv = jnp.asarray(rng.standard_normal((2, 8, 10)), jnp.float32)
    cache = kq.quantize_cache_entry(ck, cv)
    assert kq.is_quantized_cache(cache)
    assert not kq.is_quantized_cache({"c_k": ck, "c_v": cv})
    dk, dv = kq.dequantize_cache_entry(cache)
    assert float(jnp.max(jnp.abs(dk - ck))) <= float(jnp.max(jnp.abs(ck))) / 250
    assert float(jnp.max(jnp.abs(dv - cv))) <= float(jnp.max(jnp.abs(cv))) / 250


# ----------------------------------------------------------------------
# hypothesis property sweep (skipped where hypothesis isn't installed;
# CI installs it)
# ----------------------------------------------------------------------

def test_quantizer_properties():
    hypothesis = pytest.importorskip(
        "hypothesis", reason="hypothesis not installed in this environment")
    from hypothesis import given, settings, strategies as st

    finite = st.floats(min_value=-1e4, max_value=1e4, allow_nan=False,
                       width=32)

    @settings(max_examples=25, deadline=None)
    @given(st.lists(st.lists(finite, min_size=1, max_size=16),
                    min_size=1, max_size=8).filter(
                        lambda rows: len({len(r) for r in rows}) == 1))
    def check(rows):
        c = jnp.asarray(np.array(rows, np.float32))
        q, s = kq.quantize_rows(c)
        # scale is exactly per-row absmax / 127
        np.testing.assert_allclose(
            np.asarray(s)[..., 0],
            np.max(np.abs(np.array(rows, np.float32)), axis=-1) / 127.0,
            rtol=1e-6)
        # int8 range and the per-element error bound
        assert q.dtype == jnp.int8
        err = np.asarray(jnp.abs(c - kq.dequantize_rows(q, s)))
        bound = np.max(np.abs(np.array(rows, np.float32)), axis=-1,
                       keepdims=True) / 253.0
        assert np.all(err <= bound + 1e-5 * (1 + bound))

    check()


# ----------------------------------------------------------------------
# quant kernels vs oracles
# ----------------------------------------------------------------------

def _quant_operands(seed=0, B=2, Hkv=2, R=3, S=96, rk=24, rv=20, Dh=16):
    rng = np.random.default_rng(seed)
    qt = jnp.asarray(rng.standard_normal((B, Hkv, R, rk)), jnp.float32)
    ck, cks = kq.quantize_rows(
        jnp.asarray(rng.standard_normal((B, S, rk)), jnp.float32))
    cv, cvs = kq.quantize_rows(
        jnp.asarray(rng.standard_normal((B, S, rv)), jnp.float32))
    bv = jnp.asarray(rng.standard_normal((Hkv, rv, Dh)), jnp.float32)
    return qt, ck, cks, cv, cvs, bv, Dh


@pytest.mark.parametrize("softcap", [None, 30.0])
def test_decode_grouped_quant_matches_oracle(softcap):
    qt, ck, cks, cv, cvs, bv, Dh = _quant_operands()
    vl = jnp.asarray([50, 96], jnp.int32)
    out = ops.mla_decode_grouped_quant(qt, ck, cks, cv, cvs, bv, vl,
                                       scale=1 / np.sqrt(Dh),
                                       softcap=softcap)
    want = ref.mla_decode_grouped_quant_ref(qt, ck, cks, cv, cvs, bv, vl,
                                            scale=1 / np.sqrt(Dh),
                                            softcap=softcap)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5)


def test_decode_grouped_quant_zero_len_rows():
    qt, ck, cks, cv, cvs, bv, Dh = _quant_operands()
    vl = jnp.asarray([0, 96], jnp.int32)
    out = ops.mla_decode_grouped_quant(qt, ck, cks, cv, cvs, bv, vl,
                                       scale=1 / np.sqrt(Dh))
    assert bool(jnp.all(out[0] == 0))
    assert bool(jnp.all(jnp.isfinite(out)))


@pytest.mark.parametrize("softcap", [None, 30.0])
def test_decode_grouped_ring_quant_matches_oracle(softcap):
    qt, ck, cks, cv, cvs, bv, Dh = _quant_operands(seed=1)
    start = jnp.asarray([10, 40], jnp.int32)
    length = jnp.asarray([60, 96], jnp.int32)
    out = ops.mla_decode_grouped_ring_quant(qt, ck, cks, cv, cvs, bv,
                                            start, length,
                                            scale=1 / np.sqrt(Dh),
                                            softcap=softcap)
    want = ref.mla_decode_grouped_ring_quant_ref(qt, ck, cks, cv, cvs, bv,
                                                 start, length,
                                                 scale=1 / np.sqrt(Dh),
                                                 softcap=softcap)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5)


@pytest.mark.parametrize("window,offsets", [
    (None, False), (48, False), (None, True)])
def test_prefill_quant_matches_oracle(window, offsets):
    rng = np.random.default_rng(2)
    _, ck, cks, cv, cvs, _, Dh = _quant_operands(seed=2)
    B, H, Tq = 2, 4, 64
    qt = jnp.asarray(rng.standard_normal((B, H, Tq, ck.shape[-1])),
                     jnp.float32)
    vl = jnp.asarray([50, 96], jnp.int32)
    qoff = jnp.asarray([5, 0], jnp.int32) if offsets else None
    out = ops.mla_prefill_quant(qt, ck, cks, cv, cvs, vl, qoff,
                                scale=1 / np.sqrt(Dh), window=window)
    want = ref.mla_prefill_quant_ref(qt, ck, cks, cv, cvs, vl, qoff,
                                     scale=1 / np.sqrt(Dh), window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5)


def test_quant_decode_close_to_fp_decode():
    """In-kernel dequant attention stays near the fp-cache result: the
    int8 grid perturbs scores by O(max|c|/127) only."""
    rng = np.random.default_rng(3)
    B, Hkv, R, S, rk, rv, Dh = 2, 2, 2, 64, 16, 16, 8
    qt = jnp.asarray(rng.standard_normal((B, Hkv, R, rk)), jnp.float32)
    ckf = jnp.asarray(rng.standard_normal((B, S, rk)), jnp.float32)
    cvf = jnp.asarray(rng.standard_normal((B, S, rv)), jnp.float32)
    bv = jnp.asarray(rng.standard_normal((Hkv, rv, Dh)), jnp.float32)
    vl = jnp.asarray([64, 40], jnp.int32)
    ck, cks = kq.quantize_rows(ckf)
    cv, cvs = kq.quantize_rows(cvf)
    out = ops.mla_decode_grouped_quant(qt, ck, cks, cv, cvs, bv, vl,
                                       scale=1 / np.sqrt(Dh))
    want = ref.mla_decode_grouped_ref(qt, ckf, cvf, bv, vl,
                                      scale=1 / np.sqrt(Dh))
    assert float(jnp.max(jnp.abs(out - want))) < 0.15


# ----------------------------------------------------------------------
# engine: int8 arena greedy parity + ctor validation + report keys
# ----------------------------------------------------------------------

def _run_engine(cfg, params, prompts, sp, **kw):
    eng = Engine(cfg, params, num_slots=2, max_len=48, **kw)
    reqs = [eng.submit(p, sp) for p in prompts]
    eng.run()
    return [list(map(int, r.output_tokens)) for r in reqs], eng


@pytest.mark.parametrize("mode", ["linear", "paged", "chunked"])
def test_engine_int8_greedy_matches_fp(mode):
    """Acceptance: int8-cache greedy decode produces the same tokens as
    the fp-cache engine on the serving smoke config."""
    cfg = _latent_cfg()
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    prompts = _prompts(0, (3, 11, 6, 9), cfg.vocab_size)
    sp = SamplingParams(max_new_tokens=8)
    kw = {}
    if mode == "paged":
        kw = dict(paged=True, block_size=8)
    elif mode == "chunked":
        kw = dict(token_budget=8, prefill_chunk=4)
    fp_toks, _ = _run_engine(cfg, params, prompts, sp, **kw)
    q_toks, eng = _run_engine(cfg, params, prompts, sp,
                              cache_dtype="int8", **kw)
    assert q_toks == fp_toks
    assert eng.cfg.latent.cache_dtype == "int8"


def test_engine_int8_windowed_ring():
    """Sliding-window layers keep the ring fast path with an int8 ring."""
    cfg = _cfg("h2o-danube-3-4b", pos_emb="none", qkv_bias=False,
               latent=LatentConfig(enabled=True, compression=0.3))
    assert cfg.sliding_window is not None
    params = T.init_params(jax.random.PRNGKey(1), cfg)
    prompts = _prompts(1, (4, 9, 6), cfg.vocab_size)
    sp = SamplingParams(max_new_tokens=6)
    fp_toks, _ = _run_engine(cfg, params, prompts, sp)
    q_toks, _ = _run_engine(cfg, params, prompts, sp, cache_dtype="int8")
    assert q_toks == fp_toks


def test_engine_int8_cache_bytes_shrink():
    """Acceptance: the int8 arena stores >= 2x fewer latent-cache bytes
    than the fp arena and the report says so."""
    cfg = _latent_cfg()
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    fp = Engine(cfg, params, num_slots=2, max_len=48)
    q = Engine(cfg, params, num_slots=2, max_len=48, cache_dtype="int8")
    rep = q.cache_report()
    assert rep["cache_dtype"] == "int8"
    assert rep["fp_slot_bytes"] == fp.cache_report()["slot_bytes"]
    assert rep["fp_slot_bytes"] / rep["slot_bytes"] >= 2.0
    assert rep["compression_vs_dense"] > \
        fp.cache_report()["compression_vs_dense"]
    # live leaves really are int8 + scale siblings
    leaves = jax.tree_util.tree_leaves_with_path(q.arena.cache)
    kinds = {str(path[-1]): leaf.dtype for path, leaf in leaves}
    assert any("c_k" in k and v == jnp.int8 for k, v in kinds.items())
    assert any("ck_scale" in k and v == jnp.float32
               for k, v in kinds.items())


def test_engine_rejects_unsupported_cache_dtype():
    cfg = _latent_cfg()
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    with pytest.raises(ValueError, match="cache_dtype"):
        Engine(cfg, params, cache_dtype="int4")
    rope_cfg = _cfg("deepseek-coder-33b",
                    latent=LatentConfig(enabled=True, compression=0.3))
    rope_params = T.init_params(jax.random.PRNGKey(0), rope_cfg)
    with pytest.raises(ValueError, match="absorbed"):
        Engine(rope_cfg, rope_params, cache_dtype="int8")
    dense_cfg = _cfg("deepseek-coder-33b", pos_emb="none", qkv_bias=False)
    dense_params = T.init_params(jax.random.PRNGKey(0), dense_cfg)
    with pytest.raises(ValueError, match="absorbed"):
        Engine(dense_cfg, dense_params, cache_dtype="int8")


def test_engine_int8_metrics_gauges():
    from repro.serve.metrics import MetricsRegistry
    cfg = _latent_cfg()
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    metrics = MetricsRegistry()
    eng = Engine(cfg, params, num_slots=2, max_len=48, metrics=metrics,
                 cache_dtype="int8")
    eng.submit(_prompts(0, (5,), cfg.vocab_size)[0],
               SamplingParams(max_new_tokens=3))
    eng.run()
    g = metrics.snapshot()["gauges"]
    assert g["cache_bytes_in_use"] == \
        eng.arena.slot_bytes() * eng.arena.num_slots
    assert g["cache_compression_ratio"] == pytest.approx(
        eng.cache_report()["compression_vs_dense"], rel=1e-3)
    prom = metrics.to_prometheus()
    assert "serve_cache_bytes_in_use" in prom
    assert "serve_cache_compression_ratio" in prom


# ----------------------------------------------------------------------
# decode stays ONE fused dispatch with an int8 cache
# ----------------------------------------------------------------------

def _prims(jx, acc):
    """Every primitive, descending into ClosedJaxpr AND raw Jaxpr params
    (shard_map stores a raw Jaxpr, so the shallow walk misses the
    pallas_call nested under it)."""
    for e in jx.eqns:
        acc.add(e.primitive.name)
        for v in e.params.values():
            if hasattr(v, "jaxpr"):
                _prims(v.jaxpr if hasattr(v.jaxpr, "eqns")
                       else v.jaxpr.jaxpr, acc)
            elif hasattr(v, "eqns"):
                _prims(v, acc)
    return acc


def test_int8_decode_single_fused_dispatch():
    cfg = dataclasses.replace(
        _latent_cfg(),
        latent=LatentConfig(enabled=True, compression=0.3,
                            cache_dtype="int8"))
    B = 3
    cache = T.init_cache(cfg, B, 16)
    assert cache["groups"][0]["attn"]["c_k"].dtype == jnp.int8
    cache["pos"] = jnp.array([3, 7, 5], jnp.int32)
    params = T.init_params(jax.random.PRNGKey(2), cfg)
    step = lm.make_engine_step(cfg)
    jaxpr = jax.make_jaxpr(step)(
        params, cache, jnp.zeros((B, 1), jnp.int32),
        jnp.zeros((B, 2), jnp.uint32), jnp.ones((B,), jnp.int32),
        jnp.zeros((B,), jnp.float32), jnp.zeros((B,), jnp.int32),
        jnp.ones((B,), jnp.float32), jnp.ones((B,), bool))
    top = {e.primitive.name for e in jaxpr.jaxpr.eqns}
    allp = _prims(jaxpr.jaxpr, set())
    assert "scan" in top and "argmax" in top
    assert "pallas_call" in allp
    assert jaxpr.out_avals[0].dtype == jnp.int32


# ----------------------------------------------------------------------
# 2x4 mesh: int8 greedy tokens == single device, still per-shard fused
# ----------------------------------------------------------------------

_MESH_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses, json
import numpy as np
import jax, jax.numpy as jnp
from repro.configs import REGISTRY, LatentConfig, reduced
from repro.launch.mesh import make_debug_mesh
from repro.models import lm, transformer as T
from repro.serve import Engine, SamplingParams

cfg = dataclasses.replace(
    reduced(REGISTRY["deepseek-coder-33b"]), dtype="float32")
cfg = dataclasses.replace(cfg, pos_emb="none", qkv_bias=False,
                          num_kv_heads=4,
                          latent=LatentConfig(enabled=True, compression=0.3))
params = T.init_params(jax.random.PRNGKey(0), cfg)
rng = np.random.RandomState(0)
prompts = [rng.randint(0, 250, size=L).astype(np.int32)
           for L in (3, 11, 6, 9)]
sps = [SamplingParams(max_new_tokens=6) for _ in prompts]
mesh = make_debug_mesh(2, 4)

def run(m, cache_dtype):
    eng = Engine(cfg, params, num_slots=4, max_len=32, mesh=m,
                 cache_dtype=cache_dtype)
    reqs = [eng.submit(p, sp) for p, sp in zip(prompts, sps)]
    eng.run()
    return [list(map(int, r.output_tokens)) for r in reqs]

out = {}
out["int8_mesh_equal_single"] = run(mesh, "int8") == run(None, "int8")
out["int8_mesh_equal_fp"] = run(mesh, "int8") == run(mesh, "fp")

qcfg = dataclasses.replace(
    cfg, latent=dataclasses.replace(cfg.latent, cache_dtype="int8"))
B = 4
cache = T.init_cache(qcfg, B, 16)
cache["pos"] = jnp.array([3, 7, 5, 2], jnp.int32)
step = lm.make_engine_step(qcfg)
with mesh:
    jaxpr = jax.make_jaxpr(step)(
        params, cache, jnp.zeros((B, 1), jnp.int32),
        jnp.zeros((B, 2), jnp.uint32), jnp.ones((B,), jnp.int32),
        jnp.zeros((B,), jnp.float32), jnp.zeros((B,), jnp.int32),
        jnp.ones((B,), jnp.float32), jnp.ones((B,), bool))

def prims(jx, acc):
    for e in jx.eqns:
        acc.add(e.primitive.name)
        for v in e.params.values():
            if hasattr(v, "jaxpr"):
                prims(v.jaxpr if hasattr(v.jaxpr, "eqns")
                      else v.jaxpr.jaxpr, acc)
            elif hasattr(v, "eqns"):
                prims(v, acc)
    return acc

top = {e.primitive.name for e in jaxpr.jaxpr.eqns}
allp = prims(jaxpr.jaxpr, set())
out["one_dispatch"] = bool("scan" in top and "argmax" in top)
out["per_shard_kernels"] = bool("shard_map" in allp
                                and "pallas_call" in allp)
print("RESULT:" + json.dumps(out))
"""


@pytest.fixture(scope="module")
def mesh_out():
    env = dict(os.environ,
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    r = subprocess.run([sys.executable, "-c", _MESH_SCRIPT], env=env,
                       capture_output=True, text=True, timeout=1500)
    assert r.returncode == 0, r.stderr[-3000:]
    line = [l for l in r.stdout.splitlines()
            if l.startswith("RESULT:")][-1]
    return json.loads(line[len("RESULT:"):])


@pytest.mark.slow
def test_int8_sharded_tokens_and_dispatch(mesh_out):
    """2x4 mesh int8 == single-device int8 == mesh fp greedy tokens;
    the int8 decode stays one fused dispatch with per-shard kernels."""
    assert mesh_out["int8_mesh_equal_single"]
    assert mesh_out["int8_mesh_equal_fp"]
    assert mesh_out["one_dispatch"]
    assert mesh_out["per_shard_kernels"]


# ----------------------------------------------------------------------
# "quant" weight-compression method
# ----------------------------------------------------------------------

def test_quant_method_registered():
    m = get_method("quant")
    assert m.quantize and m.attention_aware and m.joint_ud
    assert not get_method("latentllm").quantize


def test_fake_quant_weight_error_and_clip():
    rng = np.random.default_rng(4)
    w = jnp.asarray(rng.standard_normal((32, 12)), jnp.float32)
    wq, info = fake_quant_weight(w)
    assert wq.shape == w.shape and wq.dtype == w.dtype
    assert info["rel_err"] < 0.02 and not info["weighted"]
    from repro.core.compress.quant import CLIP_GRID
    assert info["alpha"] in CLIP_GRID
    # a forced clip ratio really clips: values bounded by alpha * amax
    wq_c, info_c5 = fake_quant_weight(w, grid=(0.5,))
    assert info_c5["alpha"] == 0.5
    bound = 0.5 * jnp.max(jnp.abs(w), axis=-2, keepdims=True)
    assert bool(jnp.all(jnp.abs(wq_c) <= bound + 1e-6))
    assert info_c5["rel_err"] > info["rel_err"]
    # weighted metric engages when C matches the leading dim
    x = jnp.asarray(rng.standard_normal((128, 32)), jnp.float32)
    C = x.T @ x / 128
    _, info_c = fake_quant_weight(w, C)
    assert info_c["weighted"]


def test_fake_quant_module_skips_vectors():
    from repro.core.compress import fake_quant_module
    rng = np.random.default_rng(5)
    mod = {"a_q": jnp.asarray(rng.standard_normal((16, 8)), jnp.float32),
           "b_q": jnp.asarray(rng.standard_normal((2, 8, 4)), jnp.float32),
           "bias_q": jnp.asarray(rng.standard_normal((8,)), jnp.float32)}
    out, info = fake_quant_module(mod)
    assert bool(jnp.all(out["bias_q"] == mod["bias_q"]))  # untouched
    assert "bias_q" not in info and "a_q" in info and "b_q" in info
    assert not bool(jnp.all(out["a_q"] == mod["a_q"]))


def test_quant_method_end_to_end_compress():
    """compress_model(method='quant') emits loadable latent params whose
    forward stays finite and close to the latentllm solution."""
    from repro.core.compress import compress_model
    dense_cfg = _cfg("deepseek-coder-33b", pos_emb="none", qkv_bias=False)
    lat_cfg = dataclasses.replace(dense_cfg, latent=LatentConfig(
        enabled=True, compression=0.3))
    params = T.init_params(jax.random.PRNGKey(3), dense_cfg)
    batch = {"tokens": np.random.RandomState(3).randint(
        0, dense_cfg.vocab_size, size=(2, 16)).astype(np.int32)}
    lp, rep = compress_model(params, lat_cfg, batch, method="quant")
    mods = rep["entries"][0]["modules"]
    assert "weight_quant" in mods["attention"]
    assert mods["attention"]["weight_quant"]["a_q"]["rel_err"] < 0.05
    logits, _, _ = T.forward(lp, lat_cfg, tokens=jnp.asarray(batch["tokens"]))
    assert bool(jnp.all(jnp.isfinite(logits)))
