"""Fault-tolerant request lifecycle (ISSUE 7): preemption under cache
pressure, deadlines, cancellation, bounded retries, NaN quarantine,
admission control, drain — driven by the deterministic FaultInjector —
plus the interleaving property test and the 2x4-mesh fault gate."""
import collections
import dataclasses
import json
import os
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro.configs import REGISTRY, LatentConfig, reduced
from repro.models import lm, transformer as T
from repro.serve import (BlockPool, Engine, FaultInjector, MetricsRegistry,
                         PagedLatentArena, Request, RequestState,
                         SamplingParams, TransientStepFault)


def _cfg(name="deepseek-coder-33b", **kw):
    cfg = dataclasses.replace(reduced(REGISTRY[name]), dtype="float32")
    return dataclasses.replace(cfg, **kw) if kw else cfg


# absorbed NoPE latent config: the one paged serving accepts, and the
# linear engine serves it too — one params fixture covers every test
LATENT = _cfg(pos_emb="none", qkv_bias=False,
              latent=LatentConfig(enabled=True, compression=0.3))


@pytest.fixture(scope="module")
def params():
    return T.init_params(jax.random.PRNGKey(0), LATENT)


def _prompts(seed, lens, vocab=250):
    rng = np.random.RandomState(seed)
    return [rng.randint(0, vocab, size=L).astype(np.int32) for L in lens]


def _greedy_refs(params, prompts, steps, max_len=32):
    return [np.asarray(lm.greedy_generate(LATENT, params, p[None],
                                          steps=steps, max_len=max_len))[0]
            for p in prompts]


def _drain(eng, cap=5000):
    n = 0
    while eng.has_work():
        eng.step()
        n += 1
        assert n < cap, "engine failed to make progress"
    return n


def _assert_pool_clean(arena, extra_held=0):
    """After a full drain + tree evict, every pool block must be free —
    the no-leak acceptance check. ``extra_held`` discounts blocks a
    fault injector still hogs."""
    arena.prefix.evict(arena.num_blocks)
    assert arena.pool.num_free + extra_held == arena.num_blocks
    for b in range(arena.num_blocks):
        rc = arena.pool.refcount(b)
        assert arena.pool.is_free(b) == (rc == 0)


# -- fault injector units ----------------------------------------------

def test_fault_injector_deterministic():
    """Same seed -> same schedule (dispatch bursts AND poison masks);
    different seed -> different schedule."""
    def trace(seed):
        fi = FaultInjector(seed, step_fail_p=0.3, fail_burst=2, nan_p=0.2)
        evs = []
        for _ in range(60):
            fi.begin_step(None)
            fails = 0
            while True:
                try:
                    fi.maybe_fail_dispatch()
                    break
                except TransientStepFault:
                    fails += 1
            evs.append((fails, fi.poison_mask(
                4, np.ones((4,), bool)).tolist()))
        return evs

    assert trace(3) == trace(3)
    assert trace(3) != trace(4)


def test_fault_injector_hog_accounting():
    """A scheduled hog grabs EVERY free block, holds it for exactly
    ``hold`` steps, and returns them through the real refcount path."""
    pool = BlockPool(8, 4)
    fi = FaultInjector(0, hog_steps={1: 2})
    fi.begin_step(pool)                      # step 0: nothing scheduled
    assert pool.num_free == 8
    fi.begin_step(pool)                      # step 1: hog fires
    assert pool.num_free == 0 and fi.holding_blocks == 8
    fi.begin_step(pool)                      # step 2: still held
    assert pool.num_free == 0
    fi.begin_step(pool)                      # step 3: hold expired
    assert pool.num_free == 8 and fi.holding_blocks == 0
    assert fi.stats["hogs"] == 1 and fi.stats["hogged_blocks"] == 8


def test_fault_injector_clock():
    fi = FaultInjector(0, skew_steps={2: 10.0})
    t0 = fi.now()
    fi.begin_step(None)
    fi.begin_step(None)
    assert fi.now() - t0 < 5.0
    fi.begin_step(None)                      # step 2: +10s skew
    assert fi.now() - t0 >= 10.0
    fi.sleep(3.0)                            # virtual: no real blocking
    assert fi.now() - t0 >= 13.0


def test_timing_and_stats_use_injected_clock(params):
    """EVERY engine time read routes through the one injected clock:
    the submit/first-token/finish stamps AND the run() throughput
    window. Skew at step 0 fires in begin_step BEFORE the first token
    is emitted (ttft >= 5); skew at step 2 lands before finish
    (latency >= 10); last_stats['seconds'] must see both — a wall-clock
    run() would report milliseconds and break SLO accounting under
    clock faults."""
    fi = FaultInjector(0, skew_steps={0: 5.0, 2: 5.0})
    m = MetricsRegistry()
    eng = Engine(LATENT, params, num_slots=1, max_len=32, faults=fi,
                 metrics=m)
    r = eng.submit(_prompts(9, (6,))[0], SamplingParams(max_new_tokens=5))
    eng.run()
    assert r.state is RequestState.FINISHED
    assert 5.0 <= r.ttft_s < 10.0          # first skew, not the second
    assert r.latency_s >= 10.0             # both skews inside the window
    assert eng.last_stats["seconds"] >= 10.0
    snap = m.snapshot()                    # histograms see skewed time too
    assert snap["histograms"]["ttft_s"]["max"] >= 5.0
    assert snap["histograms"]["e2e_s"]["max"] >= 10.0


# -- input validation (satellite bugfixes) -----------------------------

def test_request_rejects_float_prompt_dtype():
    with pytest.raises(ValueError, match="integer token ids"):
        Request(np.array([0.5, 1.7]))
    with pytest.raises(ValueError, match="integer token ids"):
        Request([0.5, 1.7])
    # integer dtypes of any width are fine
    assert Request(np.array([1, 2], np.int64)).prompt.dtype == np.int32


def test_sampling_params_validation():
    with pytest.raises(ValueError, match="max_new_tokens"):
        SamplingParams(max_new_tokens=0)
    with pytest.raises(ValueError, match="temperature"):
        SamplingParams(temperature=float("nan"))
    with pytest.raises(ValueError, match="temperature"):
        SamplingParams(temperature=float("inf"))
    with pytest.raises(ValueError, match="top_p"):
        SamplingParams(top_p=float("nan"))


def test_submit_rejects_out_of_vocab_tokens(params):
    eng = Engine(LATENT, params, num_slots=1, max_len=16)
    r = eng.submit(np.array([1, LATENT.vocab_size + 5], np.int32))
    assert r.state is RequestState.REJECTED and r.finish_reason == "rejected"
    assert f"[0, {LATENT.vocab_size})" in r.error
    r = eng.submit(np.array([-1, 3], np.int32))
    assert r.state is RequestState.REJECTED
    with pytest.raises(ValueError, match="token ids"):
        Engine(LATENT, params, num_slots=1, max_len=16, strict=True).submit(
            np.array([-1, 3], np.int32))


# -- radix republish (the preemption-publish path) ---------------------

def test_radix_republish_upgrades_same_block():
    """Re-inserting a slot's grown prefix (what preemption publishes
    after the slot decoded into its tail block) must EXTEND the
    existing partial node in place — a second node on the same block
    would pin it with two tree references, unevictable forever."""
    arena = PagedLatentArena(None, num_slots=2, max_len=16, block_size=4,
                             num_blocks=8)
    toks = np.array([1, 2, 3, 4, 5, 6], np.int32)       # full + partial
    slot = arena.acquire()
    assert arena.admit(slot, toks) == 0
    arena.insert(slot, toks)
    assert arena.prefix.num_nodes == 2
    b_tail = int(arena.tables[slot, 1])
    assert arena.pool.refcount(b_tail) == 2              # tree + slot
    # the slot decodes rows 6..7, then preemption republishes [0, 8)
    grown = np.array([1, 2, 3, 4, 5, 6, 7, 8], np.int32)
    arena.insert(slot, grown)
    assert arena.prefix.num_nodes == 2                   # upgraded in place
    assert arena.pool.refcount(b_tail) == 2              # NOT 3
    m, chain = arena.prefix.match(grown)
    assert m == 8 and chain[1] == b_tail
    arena.release(slot)
    assert arena.pool.refcount(b_tail) == 1              # evictable again
    assert arena.prefix.evict(10) == 2
    assert arena.pool.num_free == arena.num_blocks


# -- lifecycle: admission control, cancel, drain -----------------------

def test_admission_queue_bound_and_drain_reject(params):
    eng = Engine(LATENT, params, num_slots=1, max_len=32, max_queue=2)
    ps = _prompts(0, (3, 4, 5, 6))
    a, b = eng.submit(ps[0]), eng.submit(ps[1])
    c = eng.submit(ps[2])
    assert c.state is RequestState.REJECTED and "queue full" in c.error
    eng.begin_drain()
    d = eng.submit(ps[3])
    assert d.state is RequestState.REJECTED and "draining" in d.error
    assert eng.drain() is True
    assert a.state is RequestState.FINISHED
    assert b.state is RequestState.FINISHED
    assert len(eng.rejected) == 2
    # drain reopens admission
    assert eng.submit(ps[0]).state is RequestState.QUEUED


def test_cancel_queued_and_running(params):
    eng = Engine(LATENT, params, num_slots=1, max_len=32)
    ps = _prompts(1, (5, 7))
    r1 = eng.submit(ps[0], SamplingParams(max_new_tokens=10))
    r2 = eng.submit(ps[1], SamplingParams(max_new_tokens=10))
    eng.step()
    assert r1.state is RequestState.RUNNING
    assert eng.cancel(r2)                        # still queued
    assert r2.state is RequestState.CANCELLED and r2.finish_reason == \
        "cancelled"
    eng.step()
    assert eng.cancel(r1)                        # mid-decode
    assert r1.state is RequestState.CANCELLED
    assert not eng.cancel(r1)                    # terminal: exactly once
    assert not eng.has_work()
    assert eng.arena.num_free == eng.arena.num_slots
    assert eng.counters["cancellations"] == 2


def test_deadlines_timeout_via_clock_skew(params):
    """Deadline sweep covers queued AND running requests; the injected
    clock skew makes it deterministic without real waiting."""
    fi = FaultInjector(0, skew_steps={3: 100.0})
    eng = Engine(LATENT, params, num_slots=1, max_len=32, faults=fi)
    ps = _prompts(2, (4, 6))
    r1 = eng.submit(ps[0], SamplingParams(max_new_tokens=20),
                    deadline_s=50.0)             # running when skew hits
    r2 = eng.submit(ps[1], SamplingParams(max_new_tokens=5),
                    ttft_deadline_s=30.0)        # starves behind r1
    _drain(eng)
    assert r1.state is RequestState.TIMEOUT and r1.finish_reason == "timeout"
    assert r2.state is RequestState.TIMEOUT
    assert eng.counters["timeouts"] == 2
    assert eng.arena.num_free == eng.arena.num_slots


def test_callback_exception_fails_only_that_request(params):
    ps = _prompts(3, (4, 6))
    refs = _greedy_refs(params, ps, 4)

    def bomb(req, tok):
        raise RuntimeError("consumer went away")

    eng = Engine(LATENT, params, num_slots=2, max_len=32)
    r1 = eng.submit(ps[0], SamplingParams(max_new_tokens=4), on_token=bomb)
    r2 = eng.submit(ps[1], SamplingParams(max_new_tokens=4))
    _drain(eng)
    assert r1.state is RequestState.ERROR and "on_token" in r1.error
    assert r2.state is RequestState.FINISHED
    np.testing.assert_array_equal(r2.output(), refs[1])


# -- transient failures, retries, quarantine ---------------------------

def test_transient_step_failures_absorbed_bit_identically(params):
    """Injected dispatch faults fire BEFORE the jitted call, so the
    bounded-retry loop replays the identical step: tokens match the
    fault-free run bit for bit."""
    ps = _prompts(4, (3, 11, 6, 9))
    refs = _greedy_refs(params, ps, 6)
    fi = FaultInjector(1, fail_attempts={2: 2, 5: 1})
    eng = Engine(LATENT, params, num_slots=2, max_len=32, faults=fi)
    reqs = [eng.submit(p, SamplingParams(max_new_tokens=6)) for p in ps]
    _drain(eng)
    assert eng.counters["step_retries"] == 3
    assert fi.stats["dispatch_faults"] == 3
    for r, ref in zip(reqs, refs):
        assert r.state is RequestState.FINISHED
        np.testing.assert_array_equal(r.output(), ref)


def test_retry_exhaustion_fails_residents_not_queue(params):
    ps = _prompts(4, (3, 11, 6, 9))
    refs = _greedy_refs(params, ps, 6)
    fi = FaultInjector(1, fail_attempts={1: 10})     # burst outlasts retries
    eng = Engine(LATENT, params, num_slots=2, max_len=32, faults=fi,
                 max_step_retries=2)
    reqs = [eng.submit(p, SamplingParams(max_new_tokens=6)) for p in ps]
    _drain(eng)
    errs = [r for r in reqs if r.state is RequestState.ERROR]
    fins = [r for r in reqs if r.state is RequestState.FINISHED]
    assert len(errs) == 2 and len(fins) == 2         # residents failed,
    for r in errs:                                   # queue survived
        assert "after" in r.error and r.finish_reason == "error"
    for r in fins:
        np.testing.assert_array_equal(r.output(), refs[reqs.index(r)])
    assert eng.counters["step_failures"] == 1


def test_nan_quarantine_isolates_poisoned_slot(params):
    """An injected NaN row fails exactly that request (ERROR); the
    other resident keeps decoding bit-identically — the finite guard
    keeps the poison out of its sampling and its cache position."""
    ps = _prompts(5, (5, 8))
    refs = _greedy_refs(params, ps, 6)
    fi = FaultInjector(1, nan_rows={3: [0]})
    eng = Engine(LATENT, params, num_slots=2, max_len=32, faults=fi)
    reqs = [eng.submit(p, SamplingParams(max_new_tokens=6)) for p in ps]
    _drain(eng)
    states = sorted(r.state.value for r in reqs)
    assert states == ["error", "finished"]
    assert eng.counters["quarantined"] == 1
    ok = next(r for r in reqs if r.state is RequestState.FINISHED)
    np.testing.assert_array_equal(ok.output(), refs[reqs.index(ok)])


# -- preemption + bit-identical resume ---------------------------------

def test_preempt_resume_bit_identical_linear(params):
    """Explicit preemption on the LINEAR arena: the resumed request's
    greedy AND seeded-sampled tokens are bit-identical to an
    uninterrupted run (resume re-prefills prompt + output[:-1] — rows
    recompute bitwise-equal — and restores the pending token + PRNG
    fold on the host)."""
    ps = _prompts(6, (11, 9))
    sps = [SamplingParams(max_new_tokens=8),
           SamplingParams(max_new_tokens=8, temperature=0.9, top_k=16,
                          seed=13)]

    def run(preempt_at):
        eng = Engine(LATENT, params, num_slots=2, max_len=32)
        reqs = [eng.submit(p, sp) for p, sp in zip(ps, sps)]
        steps = 0
        while eng.has_work():
            eng.step()
            steps += 1
            if steps == preempt_at:
                for r in reqs:
                    if r.state is RequestState.RUNNING:
                        assert eng.preempt(r)
        return [tuple(r.output_tokens) for r in reqs], reqs

    ref, _ = run(preempt_at=0)                        # uninterrupted
    got, reqs = run(preempt_at=3)
    assert all(r.num_preemptions == 1 for r in reqs)
    assert all(r.state is RequestState.FINISHED for r in reqs)
    assert got == ref
    greedy_ref = _greedy_refs(params, ps[:1], 8)[0]
    np.testing.assert_array_equal(np.asarray(got[0]), greedy_ref)


def test_pressure_preemption_paged_bit_identical(params):
    """Pool sized BELOW the working set: mid-decode ``try_ensure``
    failures preempt victims instead of raising; preempted requests
    longest-prefix-match their republished chain at re-admission and
    finish bit-identical to uninterrupted greedy. No blocks leak."""
    ps = _prompts(7, (17, 21, 19))
    refs = _greedy_refs(params, ps, 8)
    eng = Engine(LATENT, params, num_slots=3, max_len=32, paged=True,
                 block_size=8, num_blocks=6)          # working set needs 11
    reqs = [eng.submit(p, SamplingParams(max_new_tokens=8)) for p in ps]
    _drain(eng)
    assert eng.counters["pressure_preemptions"] >= 1
    assert eng.counters["resumes"] >= 1
    for r, ref in zip(reqs, refs):
        assert r.state is RequestState.FINISHED, (r.state, r.error)
        np.testing.assert_array_equal(r.output(), ref)
    assert eng.cache_report()["prefix_hit_rate"] > 0  # resume reused blocks
    _assert_pool_clean(eng.arena)


def test_priority_preemption_admission(params):
    """A strictly-higher-priority submit displaces the lowest-priority
    resident (admission-time preemption); equal priority must NOT
    preempt (livelock guard). Both finish bit-identical."""
    ps = _prompts(8, (9, 13))
    refs = _greedy_refs(params, ps, 16)
    eng = Engine(LATENT, params, num_slots=1, max_len=32, paged=True,
                 block_size=8, num_blocks=6)
    lo = eng.submit(ps[0], SamplingParams(max_new_tokens=16))
    eng.step()
    eng.step()
    peer = eng.submit(ps[1], SamplingParams(max_new_tokens=4))  # equal prio
    eng.step()
    assert lo.state is RequestState.RUNNING and lo.num_preemptions == 0
    assert eng.cancel(peer)
    hi = eng.submit(ps[1], SamplingParams(max_new_tokens=4), priority=5)
    order = []
    while eng.has_work():
        eng.step()
        for r in (lo, hi):
            if r.is_terminal and r not in order:
                order.append(r)
    assert order[0] is hi and lo.num_preemptions >= 1
    assert eng.counters["priority_preemptions"] >= 1
    np.testing.assert_array_equal(hi.output(), refs[1][:4])
    np.testing.assert_array_equal(lo.output(), refs[0])
    _assert_pool_clean(eng.arena)


# -- interleaving property test ----------------------------------------

def _lifecycle_drive(eng, ops, seed):
    """Interpret (op, payload) pairs against a live paged engine, then
    drain and check the ISSUE 7 invariants: every submitted request
    reaches a terminal state EXACTLY once, no leaked slots, and the
    BlockPool free-XOR-refcount / tree+slot accounting balances."""
    rng = np.random.RandomState(seed)
    submitted = []
    for op, payload in ops:
        if op == 0:                                   # submit
            L = 1 + payload % 12
            submitted.append(eng.submit(
                rng.randint(0, 50, size=L).astype(np.int32),
                SamplingParams(max_new_tokens=1 + payload % 4)))
        elif op == 1:
            eng.step()
        elif op == 2:                                 # cancel any live
            live = [r for r in submitted if not r.is_terminal]
            if live:
                eng.cancel(live[payload % len(live)])
        elif op == 3:                                 # preempt a resident
            run = [r for r in submitted
                   if r.state is RequestState.RUNNING]
            if run:
                eng.preempt(run[payload % len(run)])
        elif op == 4:                                 # priority + deadline
            submitted.append(eng.submit(
                rng.randint(0, 50, size=1 + payload % 8).astype(np.int32),
                SamplingParams(max_new_tokens=1 + payload % 3),
                priority=1 + payload % 2, deadline_s=120.0))
    assert eng.drain() is True
    assert all(r.is_terminal for r in submitted)
    filed = collections.Counter(r.request_id
                                for r in eng.finished + eng.rejected)
    for r in submitted:
        assert filed[r.request_id] == 1               # terminal exactly once
    assert not eng._active.any()
    assert eng.arena.num_free == eng.arena.num_slots
    nb = eng.arena.num_blocks
    tree = collections.Counter(n.block for n in eng.arena.prefix._walk())
    for b in range(nb):
        rc = eng.arena.pool.refcount(b)
        assert eng.arena.pool.is_free(b) == (rc == 0)
        assert rc == tree[b], (b, rc, tree[b])        # slots hold nothing


@pytest.fixture(scope="module")
def prop_engine(params):
    # pool below the 3-slot worst case (12 blocks) so interleavings hit
    # admission rollback and pressure preemption; low patience keeps
    # pathological schedules bounded
    return Engine(LATENT, params, num_slots=3, max_len=32, paged=True,
                  block_size=8, num_blocks=9, admission_patience=64)


def test_lifecycle_interleavings_random_walk(prop_engine):
    """Always-on seeded fallback for the hypothesis test below."""
    rng = np.random.RandomState(0)
    for round_ in range(4):
        ops = [(int(rng.randint(5)), int(rng.randint(1 << 30)))
               for _ in range(40)]
        _lifecycle_drive(prop_engine, ops, seed=round_)


def test_lifecycle_interleavings_hypothesis(prop_engine):
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=10, deadline=None)
    @given(st.lists(st.tuples(st.integers(0, 4), st.integers(0, 1 << 30)),
                    max_size=30))
    def run(ops):
        _lifecycle_drive(prop_engine, ops, seed=99)

    run()


# -- the fault soak (make soak-faults) ---------------------------------

@pytest.mark.soak
@pytest.mark.parametrize("paged", [False, True])
def test_fault_soak(params, paged):
    """Acceptance: under randomized injected step failures, NaN logits,
    forced pool exhaustion, and clock skew, every request reaches a
    terminal state, mid-decode exhaustion never raises out of step(),
    and nothing leaks."""
    fi = FaultInjector(seed=7, step_fail_p=0.05, fail_burst=1, nan_p=0.004,
                       hog_p=(0.08 if paged else 0.0), hog_hold_steps=3,
                       skew_p=0.02, skew_s=0.5)
    kw = dict(paged=True, block_size=8, num_blocks=10) if paged else {}
    eng = Engine(LATENT, params, num_slots=3, max_len=32, faults=fi,
                 admission_patience=64, **kw)
    rng = np.random.RandomState(7)
    reqs = []
    for i in range(40):
        reqs.append(eng.submit(
            rng.randint(0, 50, size=1 + rng.randint(12)).astype(np.int32),
            SamplingParams(max_new_tokens=1 + rng.randint(6)),
            deadline_s=None if i % 5 else 3600.0))
    _drain(eng, cap=20000)
    held = fi.release_hogs()
    assert all(r.is_terminal for r in reqs)
    by_state = collections.Counter(r.state.value for r in reqs)
    assert by_state["finished"] >= 1
    assert fi.stats["dispatch_faults"] >= 1           # faults really fired
    if paged:
        assert held == 0 or held > 0                  # hogs returned
        _assert_pool_clean(eng.arena)
    assert eng.arena.num_free == eng.arena.num_slots
    filed = collections.Counter(r.request_id
                                for r in eng.finished + eng.rejected)
    assert all(filed[r.request_id] == 1 for r in reqs)


# -- sharded: 2x4 debug mesh fault gate (subprocess) -------------------

_SHARDED_FAULTS = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses, json
import numpy as np
import jax
from repro.configs import REGISTRY, LatentConfig, reduced
from repro.launch.mesh import make_debug_mesh
from repro.models import transformer as T
from repro.serve import Engine, FaultInjector, RequestState, SamplingParams

cfg = dataclasses.replace(reduced(REGISTRY["deepseek-coder-33b"]),
                          dtype="float32", pos_emb="none", qkv_bias=False,
                          num_kv_heads=4,
                          latent=LatentConfig(enabled=True, compression=0.3))
params = T.init_params(jax.random.PRNGKey(0), cfg)
rng = np.random.RandomState(0)
prompts = [rng.randint(0, 250, size=k).astype(np.int32)
           for k in (17, 21, 19, 6)]

def run(mesh=None, paged=False, faults=None, **kw):
    eng = Engine(cfg, params, num_slots=2, max_len=32, mesh=mesh,
                 paged=paged, faults=faults, **kw)
    reqs = [eng.submit(p, SamplingParams(max_new_tokens=8)) for p in prompts]
    n = 0
    while eng.has_work():
        eng.step(); n += 1
        assert n < 3000
    return eng, reqs

# uninterrupted single-device linear greedy = the bit-identity reference
_, ref = run()
ref_toks = [list(map(int, r.output_tokens)) for r in ref]
# sharded paged engine under an undersized pool (concurrent residents
# want 7 blocks, give 6) + injected dispatch faults + a scheduled hog
fi = FaultInjector(seed=5, fail_attempts={3: 2}, hog_steps={4: 3})
eng, got = run(mesh=make_debug_mesh(2, 4), paged=True, faults=fi,
               block_size=8, num_blocks=6)
fi.release_hogs()
eng.arena.prefix.evict(10**9)
print("RESULT:" + json.dumps({
    "equal": ref_toks == [list(map(int, r.output_tokens)) for r in got],
    "terminal": all(r.state is RequestState.FINISHED for r in got),
    "preemptions": int(eng.counters["preemptions"]),
    "retries": int(eng.counters["step_retries"]),
    "pool_clean": eng.arena.pool.num_free == eng.arena.num_blocks,
}))
"""


@pytest.mark.slow
def test_faulted_sharded_engine_matches_single_device():
    """Acceptance (2x4 mesh): with preemptions forced by an undersized
    pool, injected transient dispatch faults, and a block hog, the
    sharded paged engine still finishes every request FINISHED with
    tokens bit-identical to an uninterrupted single-device linear run,
    leaking nothing."""
    env = dict(os.environ,
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    r = subprocess.run([sys.executable, "-c", _SHARDED_FAULTS], env=env,
                       capture_output=True, text=True, timeout=1500)
    assert r.returncode == 0, r.stderr[-3000:]
    line = [l for l in r.stdout.splitlines() if l.startswith("RESULT:")][-1]
    out = json.loads(line[len("RESULT:"):])
    assert out["equal"] and out["terminal"]
    assert out["preemptions"] >= 1
    assert out["retries"] >= 1
    assert out["pool_clean"]
