"""Paper appendix variants: App. I sparse decompositions, App. F.3
RoPE-aware joint QK."""
import numpy as np
import jax.numpy as jnp

from repro.core.precond import activation_stats, psd_sqrt
from repro.core.sparse import (lowrank_plus_sparse_fista,
                               lowrank_plus_sparse_hard, sparse_only,
                               weighted_loss)
from repro.core.joint_qk import joint_qk_svd, _rope_rotation
from repro.core.svd import weighted_svd


def _setup(seed=0, d=48, dp=40, l=512):
    rng = np.random.default_rng(seed)
    W = jnp.asarray(rng.normal(size=(dp, d)) / np.sqrt(d), jnp.float32)
    Cd = 0.9 ** np.abs(np.subtract.outer(np.arange(d), np.arange(d)))
    X = jnp.asarray(np.linalg.cholesky(Cd + 1e-9 * np.eye(d))
                    @ rng.normal(size=(d, l)), jnp.float32)
    C, _ = activation_stats(X)
    return W, C


def test_sparse_only_monotone_and_sparsity():
    W, C = _setup()
    k = W.size // 4
    s = sparse_only(W, C, k, iters=15)
    assert s.nnz() <= k
    ls = s.losses
    assert ls[-1] <= ls[0] * (1 + 1e-4)
    # better than naive magnitude-only truncation under the metric
    naive = jnp.where(jnp.abs(W) >= jnp.sort(jnp.abs(W).reshape(-1))[-k],
                      W, 0.0)
    assert weighted_loss(W, s.reconstruct(), C) \
        <= weighted_loss(W, naive, C) * 1.001


def test_hardshrink_beats_plain_lowrank_at_same_budget():
    """Fig. 13: low-rank+sparse (hard) <= pure low-rank at equal params."""
    W, C = _setup()
    dp, d = W.shape
    r = 8
    k = 200
    lrs = lowrank_plus_sparse_hard(W, C, r, k, iters=6)
    P = psd_sqrt(C)
    # pure low-rank with the same r (strictly fewer params => only need <=)
    lr = weighted_svd(W, P, r, junction="left")
    assert weighted_loss(W, lrs.reconstruct(), C) \
        <= weighted_loss(W, lr.reconstruct(), C) * 1.001


def test_fista_converges():
    W, C = _setup(seed=3)
    f = lowrank_plus_sparse_fista(W, C, r=8, lam=1e-3, iters=15)
    assert f.losses[-1] <= f.losses[0] * (1 + 1e-4)
    assert np.isfinite(f.losses[-1])


def test_sparse_alone_competitive_with_lowrank_plus_sparse():
    """Fig. 14's observation at matched parameter budget."""
    W, C = _setup(seed=5)
    dp, d = W.shape
    r, k = 6, 150
    budget = r * (dp + d) + k
    s = sparse_only(W, C, budget, iters=15)
    lrs = lowrank_plus_sparse_hard(W, C, r, k, iters=6)
    # sparse-alone at the same budget is at least comparable (<= 1.2x)
    assert weighted_loss(W, s.reconstruct(), C) \
        <= weighted_loss(W, lrs.reconstruct(), C) * 1.2


def test_rope_rotation_orthogonal_and_composes():
    R1 = _rope_rotation(16, 1, 1e4)
    R3 = _rope_rotation(16, 3, 1e4)
    np.testing.assert_allclose(np.asarray(R1 @ R1.T), np.eye(16), atol=1e-5)
    np.testing.assert_allclose(np.asarray(R1 @ R1 @ R1), np.asarray(R3),
                               atol=1e-5)


def test_rope_aware_qk_improves_windowed_loss():
    """App. F.3 / Fig. 12: optimizing over the RoPE offset window lowers
    the rotation-averaged attention loss vs rope-ignorant HOSVD."""
    rng = np.random.default_rng(7)
    d, dh, H, Hk, l = 48, 8, 4, 2, 384
    r = 14
    Wq = jnp.asarray(rng.normal(size=(H, dh, d)) / np.sqrt(d), jnp.float32)
    Wk = jnp.asarray(rng.normal(size=(Hk, dh, d)) / np.sqrt(d), jnp.float32)
    Cd = 0.9 ** np.abs(np.subtract.outer(np.arange(d), np.arange(d)))
    X = jnp.asarray(np.linalg.cholesky(Cd + 1e-9 * np.eye(d))
                    @ rng.normal(size=(d, l)), jnp.float32)
    C, _ = activation_stats(X)
    P = psd_sqrt(C)
    window = 4
    plain = joint_qk_svd(Wq, Wk, P, r, r, iters=6)
    aware = joint_qk_svd(Wq, Wk, P, r, r, iters=6, rope_window=window)

    def windowed_loss(jqk):
        total = 0.0
        for o in range(window + 1):
            R = _rope_rotation(dh, o, 1e4)
            for i in range(H):
                g = i // (H // Hk)
                G = (R.T @ Wq[i]).T @ Wk[g]
                Gh = (R.T @ (jqk.B_q[i] @ jqk.A_q)).T @ (jqk.B_k[g] @ jqk.A_k)
                Rm = (G - Gh) @ psd_sqrt(C)
                total += float(jnp.sum((psd_sqrt(C).T @ Rm) ** 2))
        return total

    l_plain = windowed_loss(plain)
    l_aware = windowed_loss(aware)
    assert l_aware <= l_plain * 1.02, (l_aware, l_plain)
