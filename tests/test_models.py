"""Per-arch smoke tests: reduced config, one forward + one train step on
CPU, asserting output shapes and no NaNs; decode-vs-forward consistency."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ASSIGNED, REGISTRY, LatentConfig, reduced
from repro.models import lm, transformer as T
from repro.optim import AdamW, AdamWConfig


def _cfg(name, **kw):
    cfg = dataclasses.replace(reduced(REGISTRY[name]), dtype="float32")
    return dataclasses.replace(cfg, **kw) if kw else cfg


def _batch(cfg, key, B=2, S=32):
    if cfg.input_mode == "embeddings":
        return {"frames": jax.random.normal(key, (B, S, cfg.d_model),
                                            jnp.float32),
                "labels": jax.random.randint(key, (B, S), 0, cfg.vocab_size)}
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    return {"tokens": toks, "labels": toks}


@pytest.mark.parametrize("name", ASSIGNED)
def test_forward_and_train_step(name):
    cfg = _cfg(name)
    key = jax.random.PRNGKey(0)
    params = T.init_params(key, cfg)
    batch = _batch(cfg, key)
    logits, _, aux = T.forward(params, cfg, tokens=batch.get("tokens"),
                               frames=batch.get("frames"))
    assert logits.shape == (2, 32, cfg.vocab_size)
    assert not bool(jnp.isnan(logits).any())

    opt = AdamW(AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=10))
    step = lm.make_train_step(cfg, opt, remat=False)
    opt_state = opt.init(params)
    params2, opt_state, metrics = step(params, opt_state, batch,
                                       jnp.zeros((), jnp.int32))
    assert not bool(jnp.isnan(metrics["loss"]))
    # params actually moved
    moved = any(
        bool(jnp.any(a != b))
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(params2)))
    assert moved


@pytest.mark.parametrize("name", ASSIGNED)
def test_decode_matches_forward(name):
    cfg = _cfg(name)
    if cfg.num_experts:
        cfg = dataclasses.replace(cfg, capacity_factor=64.0)  # dropless
    key = jax.random.PRNGKey(1)
    params = T.init_params(key, cfg)
    B, S = 2, 24
    batch = _batch(cfg, key, B, S)
    logits_full, _, _ = T.forward(params, cfg, tokens=batch.get("tokens"),
                                  frames=batch.get("frames"))
    prefill = lm.make_prefill_step(cfg, max_len=S + 4)
    decode = lm.make_decode_step(cfg)
    if cfg.input_mode == "embeddings":
        cache, _ = prefill(params, {"frames": batch["frames"][:, :-1]})
        logits_dec, cache = decode(params, cache,
                                   {"frames": batch["frames"][:, -1:]})
    else:
        cache, _ = prefill(params, {"tokens": batch["tokens"][:, :-1]})
        logits_dec, cache = decode(params, cache,
                                   {"tokens": batch["tokens"][:, -1:]})
    assert int(cache["pos"]) == S
    err = float(jnp.max(jnp.abs(logits_dec - logits_full[:, -1])))
    ref = float(jnp.max(jnp.abs(logits_full[:, -1]))) + 1e-6
    assert err / ref < 1e-4, (name, err, ref)


@pytest.mark.parametrize("name", ["deepseek-coder-33b", "qwen1.5-110b",
                                  "mamba2-2.7b", "gemma2-27b"])
def test_latent_model_runs(name):
    cfg = _cfg(name)
    cfg = dataclasses.replace(
        cfg, latent=LatentConfig(enabled=True, compression=0.3))
    key = jax.random.PRNGKey(2)
    params = T.init_params(key, cfg)
    batch = _batch(cfg, key)
    logits, _, _ = T.forward(params, cfg, tokens=batch["tokens"])
    assert not bool(jnp.isnan(logits).any())
    prefill = lm.make_prefill_step(cfg, max_len=40)
    decode = lm.make_decode_step(cfg)
    cache, _ = prefill(params, {"tokens": batch["tokens"]})
    l1, _ = decode(params, cache, {"tokens": batch["tokens"][:, :1]})
    assert not bool(jnp.isnan(l1).any())


def test_sliding_window_masks_old_tokens():
    """SWA: tokens beyond the window do not influence the output."""
    cfg = dataclasses.replace(_cfg("h2o-danube-3-4b"), sliding_window=8)
    key = jax.random.PRNGKey(3)
    params = T.init_params(key, cfg)
    S = 24
    t1 = jax.random.randint(key, (1, S), 0, cfg.vocab_size)
    # change tokens far outside the window of the last position
    t2 = t1.at[0, :4].set((t1[0, :4] + 7) % cfg.vocab_size)
    l1, _, _ = T.forward(params, cfg, tokens=t1)
    l2, _, _ = T.forward(params, cfg, tokens=t2)
    # the last position's logits see only the last 8 tokens (depth-limited
    # leakage via the residual stream across layers is expected; with 2
    # layers the receptive field is 2*window — keep S > 2*window + 4)
    cfg1 = dataclasses.replace(cfg, num_layers=1)
    params1 = T.init_params(key, cfg1)
    l1, _, _ = T.forward(params1, cfg1, tokens=t1)
    l2, _, _ = T.forward(params1, cfg1, tokens=t2)
    assert float(jnp.max(jnp.abs(l1[:, -1] - l2[:, -1]))) < 1e-5


def test_gemma2_softcaps_bound_logits():
    cfg = _cfg("gemma2-27b")
    key = jax.random.PRNGKey(4)
    params = T.init_params(key, cfg)
    toks = jax.random.randint(key, (2, 16), 0, cfg.vocab_size)
    logits, _, _ = T.forward(params, cfg, tokens=toks)
    assert float(jnp.max(jnp.abs(logits))) <= cfg.final_logit_softcap + 1e-3


def test_loss_mask_ignores_padding():
    """Satellite fix: loss_fn honors batch["mask"] — padded tail
    positions contribute nothing (causality keeps the unmasked prefix's
    logits identical), and an all-ones mask is the plain mean."""
    cfg = _cfg("deepseek-coder-33b")
    key = jax.random.PRNGKey(11)
    params = T.init_params(key, cfg)
    B, S, pad = 2, 16, 8
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    junk = jax.random.randint(jax.random.PRNGKey(12), (B, pad), 0,
                              cfg.vocab_size)
    padded = jnp.concatenate([toks, junk], axis=1)
    mask = jnp.concatenate([jnp.ones((B, S)), jnp.zeros((B, pad))], axis=1)

    ref, _ = lm.loss_fn(params, cfg, {"tokens": toks, "labels": toks},
                        remat=False)
    ones, _ = lm.loss_fn(params, cfg, {"tokens": toks, "labels": toks,
                                       "mask": jnp.ones((B, S))},
                         remat=False)
    masked, _ = lm.loss_fn(params, cfg, {"tokens": padded, "labels": padded,
                                         "mask": mask}, remat=False)
    unmasked, _ = lm.loss_fn(params, cfg, {"tokens": padded,
                                           "labels": padded}, remat=False)
    # ones == plain mean (up to the weighted-sum reduction order)
    assert abs(float(ones) - float(ref)) < 1e-5
    assert abs(float(masked) - float(ref)) < 1e-5    # padding excluded
    assert abs(float(unmasked) - float(ref)) > 1e-4  # the bug it fixes
