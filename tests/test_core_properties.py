"""Hypothesis property tests on the compression invariants (paper math)."""
import numpy as np
import jax.numpy as jnp
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="hypothesis not installed in this environment")
from hypothesis import given, settings, strategies as st

from repro.core.joint_qk import JointQK, attention_map_loss, joint_qk_svd
from repro.core.joint_vo import joint_vo_hosvd, split_vo, vo_output_loss
from repro.core.mlp_ud import joint_ud, local_ud, mlp_output_loss
from repro.core.precond import activation_stats, preconditioner, psd_pinv, psd_sqrt
from repro.core.svd import JUNCTIONS, activation_loss, weighted_svd

SETTINGS = dict(max_examples=8, deadline=None)


def _data(seed, d=32, dp=24, l=256, decay=0.85):
    rng = np.random.default_rng(seed)
    W = jnp.asarray(rng.normal(size=(dp, d)) / np.sqrt(d), jnp.float32)
    Cd = decay ** np.abs(np.subtract.outer(np.arange(d), np.arange(d)))
    X = jnp.asarray(np.linalg.cholesky(Cd + 1e-9 * np.eye(d))
                    @ rng.normal(size=(d, l)), jnp.float32)
    return W, X


@given(seed=st.integers(0, 10_000), r=st.integers(4, 20))
@settings(**SETTINGS)
def test_junction_invariance_and_block_identity_savings(seed, r):
    """All junctions give the SAME loss; block identity saves exactly r²."""
    W, X = _data(seed)
    C, _ = activation_stats(X)
    P = psd_sqrt(C)
    losses, params = {}, {}
    for j in JUNCTIONS:
        lr = weighted_svd(W, P, r, junction=j)
        losses[j] = activation_loss(W, lr, P)
        params[j] = lr.num_params()
    base = losses["left"]
    for j in JUNCTIONS:
        assert losses[j] <= base * 1.001 + 1e-5
        assert losses[j] >= base * 0.999 - 1e-5
    assert params["left"] - params["block_identity"] == r * r


@given(seed=st.integers(0, 10_000), r=st.integers(4, 16))
@settings(**SETTINGS)
def test_eckart_young_optimality(seed, r):
    """The truncated-SVD loss is <= any random rank-r factorization."""
    W, X = _data(seed)
    C, _ = activation_stats(X)
    P = psd_sqrt(C)
    lr = weighted_svd(W, P, r, junction="left")
    opt = activation_loss(W, lr, P)
    rng = np.random.default_rng(seed + 1)
    for _ in range(3):
        B = jnp.asarray(rng.normal(size=(W.shape[0], r)), jnp.float32)
        A = jnp.asarray(rng.normal(size=(r, W.shape[1])), jnp.float32)
        rnd = float(jnp.sum(((W - B @ A) @ P) ** 2))
        assert opt <= rnd + 1e-6


@given(seed=st.integers(0, 10_000))
@settings(**SETTINGS)
def test_rootcov_is_optimal_preconditioner(seed):
    """True activation loss under rootcov <= every other Tab. 1 variant."""
    W, X = _data(seed)
    C, _ = activation_stats(X)
    r = 12

    def true_loss(kind):
        P = preconditioner(kind, X=X, C=C)
        lr = weighted_svd(W, P, r, junction="left")
        R = (W - lr.reconstruct()) @ X
        return float(jnp.sum(R * R))

    best = true_loss("rootcov")
    for kind in ("identity", "hessian", "l1", "l2", "cov"):
        assert best <= true_loss(kind) * 1.001 + 1e-5, kind


@given(seed=st.integers(0, 10_000))
@settings(**SETTINGS)
def test_hosvd_monotone_and_beats_local(seed):
    rng = np.random.default_rng(seed)
    d, dh, H, Hk, l = 48, 8, 4, 2, 384
    r = 16
    Wq = jnp.asarray(rng.normal(size=(H, dh, d)) / np.sqrt(d), jnp.float32)
    Wk = jnp.asarray(rng.normal(size=(Hk, dh, d)) / np.sqrt(d), jnp.float32)
    Cd = 0.85 ** np.abs(np.subtract.outer(np.arange(d), np.arange(d)))
    X = jnp.asarray(np.linalg.cholesky(Cd + 1e-9 * np.eye(d))
                    @ rng.normal(size=(d, l)), jnp.float32)
    C, _ = activation_stats(X)
    P = psd_sqrt(C)
    jqk = joint_qk_svd(Wq, Wk, P, r, r, iters=6)
    ls = jqk.losses
    assert all(ls[i + 1] <= ls[i] * (1 + 1e-3) + 1e-6 for i in range(len(ls) - 1))
    lrq = weighted_svd(Wq.reshape(H * dh, d), P, r, junction="left")
    lrk = weighted_svd(Wk.reshape(Hk * dh, d), P, r, junction="left")
    local = JointQK(A_q=lrq.A, A_k=lrk.A,
                    B_q=lrq.B.reshape(H, dh, r), B_k=lrk.B.reshape(Hk, dh, r))
    assert attention_map_loss(Wq, Wk, jqk, X) \
        <= attention_map_loss(Wq, Wk, local, X) * 1.01


@given(seed=st.integers(0, 10_000))
@settings(max_examples=4, deadline=None)
def test_joint_ud_beats_local_for_relu(seed):
    rng = np.random.default_rng(seed)
    d, di, l, r = 32, 128, 512, 12
    Wu = jnp.asarray(rng.normal(size=(di, d)) / np.sqrt(d), jnp.float32)
    Wd = jnp.asarray(rng.normal(size=(d, di)) / np.sqrt(di), jnp.float32)
    Cd = 0.85 ** np.abs(np.subtract.outer(np.arange(d), np.arange(d)))
    X = jnp.asarray(np.linalg.cholesky(Cd + 1e-9 * np.eye(d))
                    @ rng.normal(size=(d, l)), jnp.float32)
    loc = local_ud(Wu, Wd, X, r, r, act="relu")
    jnt = joint_ud(Wu, Wd, X, r, r, act="relu", iters=4)
    assert mlp_output_loss(Wu, Wd, jnt, X, "relu") \
        <= mlp_output_loss(Wu, Wd, loc, X, "relu") * 1.02


def test_gqa_reduces_to_mha():
    """With Hk == Hq, the GQA path equals plain MHA (pairing identity)."""
    rng = np.random.default_rng(7)
    d, dh, H, l, r = 32, 8, 4, 256, 12
    Wq = jnp.asarray(rng.normal(size=(H, dh, d)) / np.sqrt(d), jnp.float32)
    Wk = jnp.asarray(rng.normal(size=(H, dh, d)) / np.sqrt(d), jnp.float32)
    X = jnp.asarray(rng.normal(size=(d, l)), jnp.float32)
    C, _ = activation_stats(X)
    P = psd_sqrt(C)
    a = joint_qk_svd(Wq, Wk, P, r, r, iters=4)
    # identical call but with explicitly repeated KV heads must agree
    b = joint_qk_svd(Wq, jnp.asarray(Wk), P, r, r, iters=4)
    assert np.allclose(np.abs(a.A_q), np.abs(b.A_q), atol=1e-4)


def test_vo_split_and_joint_both_reduce_error():
    rng = np.random.default_rng(11)
    d, dh, Hq, Hk, l = 32, 8, 4, 2, 384
    r = 16
    Wv = jnp.asarray(rng.normal(size=(Hk, dh, d)) / np.sqrt(d), jnp.float32)
    Wo = jnp.asarray(rng.normal(size=(d, Hq * dh)) / np.sqrt(Hq * dh),
                     jnp.float32)
    X = jnp.asarray(rng.normal(size=(d, l)), jnp.float32)
    C, _ = activation_stats(X)
    P = psd_sqrt(C)
    sp = split_vo(Wv, Wo, P, r, r, C=C)
    jo = joint_vo_hosvd(Wv, Wo, P, r, r, iters=4)
    l_sp = vo_output_loss(Wv, Wo, sp, X)
    l_jo = vo_output_loss(Wv, Wo, jo, X)
    # baseline: truncate V/O to rank r via plain SVD without activation info
    assert np.isfinite(l_sp) and np.isfinite(l_jo)
    ls = jo.losses
    assert all(ls[i + 1] <= ls[i] * (1 + 1e-3) + 1e-6
               for i in range(len(ls) - 1))
