"""Sampling invariants: seed determinism, temperature→0 == greedy,
top-k/top-p support constraints, and the sampled generate/decode heads."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import REGISTRY, reduced
from repro.models import lm, transformer as T
from repro.models import sampling as smp
from repro.serve import SamplingParams


def _logits(key, rows, vocab=64, spread=4.0):
    return jax.random.normal(key, (rows, vocab)) * spread


def _keys(n, seed=0):
    return smp.fold_keys(smp.make_keys(np.full(n, seed)), np.arange(n))


def test_same_seed_identical_different_seed_differs():
    logits = _logits(jax.random.PRNGKey(0), 32)
    a = smp.sample_logits(logits, _keys(32, seed=7), temperature=1.0)
    b = smp.sample_logits(logits, _keys(32, seed=7), temperature=1.0)
    c = smp.sample_logits(logits, _keys(32, seed=8), temperature=1.0)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert not np.array_equal(np.asarray(a), np.asarray(c))


def test_temperature_zero_is_bit_identical_greedy():
    logits = _logits(jax.random.PRNGKey(1), 16)
    toks = smp.sample_logits(logits, _keys(16), temperature=0.0,
                             top_k=3, top_p=0.5)
    np.testing.assert_array_equal(
        np.asarray(toks), np.asarray(jnp.argmax(logits, axis=-1)))


def test_mixed_per_row_params_greedy_rows_exact():
    """One dispatch, per-row params: temperature-0 rows stay argmax even
    when their neighbours sample."""
    logits = _logits(jax.random.PRNGKey(2), 8)
    temp = np.array([0, 1, 0, 2, 0, 0.5, 0, 1], np.float32)
    toks = np.asarray(smp.sample_logits(logits, _keys(8), temperature=temp))
    greedy = np.asarray(jnp.argmax(logits, axis=-1))
    np.testing.assert_array_equal(toks[temp == 0], greedy[temp == 0])


def test_top_k_support():
    """Every sampled token lies in the per-row top-k logit set."""
    row = _logits(jax.random.PRNGKey(3), 1)
    logits = jnp.tile(row, (256, 1))
    k = 5
    toks = np.asarray(smp.sample_logits(logits, _keys(256, seed=3),
                                        temperature=1.5, top_k=k))
    allowed = set(np.argsort(-np.asarray(row)[0])[:k].tolist())
    assert set(toks.tolist()) <= allowed
    assert len(set(toks.tolist())) > 1  # actually sampling, not argmax


def test_top_p_mass_invariant():
    """Every sampled token lies in the nucleus: the smallest
    probability-sorted prefix whose mass reaches p (threshold ties
    included)."""
    row = _logits(jax.random.PRNGKey(4), 1, spread=2.0)
    logits = jnp.tile(row, (512, 1))
    p = 0.7
    probs = np.asarray(jax.nn.softmax(row, axis=-1))[0]
    order = np.argsort(-probs)
    csum = np.cumsum(probs[order])
    thresh = probs[order][np.searchsorted(csum, p)]
    nucleus = set(np.nonzero(probs >= thresh)[0].tolist())
    toks = np.asarray(smp.sample_logits(logits, _keys(512, seed=4),
                                        temperature=1.0, top_p=p))
    assert set(toks.tolist()) <= nucleus


def test_sampling_params_validation():
    with pytest.raises(ValueError):
        SamplingParams(temperature=-0.1)
    with pytest.raises(ValueError):
        SamplingParams(top_p=0.0)
    with pytest.raises(ValueError):
        SamplingParams(top_k=-1)
    with pytest.raises(ValueError):
        SamplingParams(max_new_tokens=0)


# ----------------------------------------------------------------------
# sampled generation heads
# ----------------------------------------------------------------------

def _cfg(name="deepseek-coder-33b"):
    return dataclasses.replace(reduced(REGISTRY[name]), dtype="float32")


def test_generate_step_sampling_seeded():
    """make_generate_step with temperature>0: same seed reproduces the
    sequence; different seed changes it; temperature=0 stays the old
    greedy path bit-identically."""
    cfg = _cfg()
    key = jax.random.PRNGKey(5)
    params = T.init_params(key, cfg)
    prompt = jax.random.randint(key, (2, 6), 0, cfg.vocab_size)
    kw = dict(steps=10, max_len=20)
    s1 = lm.greedy_generate(cfg, params, prompt, temperature=0.9, seed=11, **kw)
    s2 = lm.greedy_generate(cfg, params, prompt, temperature=0.9, seed=11, **kw)
    s3 = lm.greedy_generate(cfg, params, prompt, temperature=0.9, seed=12, **kw)
    np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))
    assert not np.array_equal(np.asarray(s1), np.asarray(s3))
    g_new = lm.greedy_generate(cfg, params, prompt, temperature=0.0, **kw)
    g_ref = lm.greedy_generate(cfg, params, prompt, **kw)
    np.testing.assert_array_equal(np.asarray(g_new), np.asarray(g_ref))


def test_generate_sampled_scan_matches_loop():
    """The sampled scan path and the per-token Python loop share the
    same key schedule — bit-identical tokens."""
    cfg = _cfg()
    key = jax.random.PRNGKey(6)
    params = T.init_params(key, cfg)
    prompt = jax.random.randint(key, (2, 5), 0, cfg.vocab_size)
    kw = dict(steps=8, max_len=16, temperature=0.8, top_k=24, seed=3)
    g_scan = lm.greedy_generate(cfg, params, prompt, use_scan=True, **kw)
    g_loop = lm.greedy_generate(cfg, params, prompt, use_scan=False, **kw)
    np.testing.assert_array_equal(np.asarray(g_scan), np.asarray(g_loop))


def test_decode_step_sample_fused():
    """make_decode_step(sample=True) fuses token selection; greedy rows
    match the logits+argmax two-step reference."""
    cfg = _cfg()
    key = jax.random.PRNGKey(7)
    params = T.init_params(key, cfg)
    prompt = jax.random.randint(key, (3, 4), 0, cfg.vocab_size)
    cache, logits = lm.make_prefill_step(cfg, max_len=8)(
        params, {"tokens": prompt})
    tok = jnp.argmax(logits, axis=-1)[:, None]
    ref_logits, _ = lm.make_decode_step(cfg)(params, cache, {"tokens": tok})
    toks, _ = lm.make_decode_step(cfg, sample=True)(
        params, cache, {"tokens": tok}, _keys(3), jnp.zeros((3,)))
    np.testing.assert_array_equal(
        np.asarray(toks), np.asarray(jnp.argmax(ref_logits, axis=-1)))


def test_top_k_tie_overflow_regression():
    """Satellite fix: with several logits tied at the k-th value, the
    old `scaled < kth` threshold kept EVERY tied candidate (more than k
    could survive). The strict rank mask keeps exactly k, ties broken
    by vocab index."""
    V, k, n_tied = 32, 4, 8
    row = np.zeros((V,), np.float32)
    row[:n_tied] = 5.0                         # 8-way tie for the top
    logits = jnp.tile(jnp.asarray(row), (512, 1))
    toks = np.asarray(smp.sample_logits(logits, _keys(512, seed=9),
                                        temperature=1.0, top_k=k))
    assert set(toks.tolist()) <= set(range(k))   # exactly k survivors
    assert len(set(toks.tolist())) > 1           # still sampling inside


def test_top_k_tie_with_top_p_support():
    """The rank-based sorted-space mask keeps top-p consistent with
    top-k under ties: the joint filter never exceeds k candidates."""
    V = 16
    row = np.full((V,), 2.0, np.float32)       # everything tied
    logits = jnp.tile(jnp.asarray(row), (256, 1))
    toks = np.asarray(smp.sample_logits(logits, _keys(256, seed=4),
                                        temperature=1.0, top_k=3,
                                        top_p=0.9))
    assert set(toks.tolist()) <= {0, 1, 2}
