"""CacheLayout slot arithmetic vs a brute-force write simulation.

The oracle SIMULATES the serving write stream: token at absolute
position p lands in slot ``p % n`` (ring) / ``p`` (linear, if it fits),
then asks which slots hold live tokens and at which absolute positions.
``_cache_validity`` / ``_cache_abs_positions`` (the layer-facing names,
now thin delegates to ``CacheLayout``) must agree for every
(cache_len, window, position) — ring wrap, window edge, and the
pre-wrap prefix included — and must not overflow int32 at large
absolute positions (the retired ``BIG_WINDOW`` sentinel trap)."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.models.cache_layout import CacheLayout
from repro.models.layers import _cache_abs_positions, _cache_validity

INT32_MAX = 2**31 - 1


def _simulate(cur, n, window):
    """Ground truth by replaying the writes with python ints.

    Returns (abs_pos, valid): abs_pos[t] = absolute position held by
    slot t (-1 = never written), valid[t] = holds a token inside the
    window (linear: any written token)."""
    slot_pos = [-1] * n
    for p in range(cur + 1):
        idx = p % n if window is not None else p
        if idx < n:
            slot_pos[idx] = p
    valid = [
        sp >= 0 and (window is None or cur - sp < window)
        for sp in slot_pos
    ]
    return np.array(slot_pos), np.array(valid)


def _cases():
    for n in (1, 3, 4, 8):
        for window in (None, n, n + 1, 2 * n + 3):
            for cur in list(range(0, 3 * n + 2)) + [7 * n + 1]:
                yield n, window, cur


@pytest.mark.parametrize("shape", ["shared", "per_row"])
def test_validity_and_abs_positions_match_write_simulation(shape):
    """Sweep ring wrap / window edge / pre-wrap prefix; shared (S,) and
    per-row (B, S) position shapes must agree with the simulation."""
    for n, window, cur in _cases():
        sim_pos, sim_valid = _simulate(cur, n, window)
        if shape == "shared":
            positions = jnp.asarray([cur], jnp.int32)
            expect_v, expect_p = sim_valid, sim_pos
        else:
            positions = jnp.asarray([[cur], [max(cur - 1, 0)]], jnp.int32)
            p2, v2 = _simulate(max(cur - 1, 0), n, window)
            expect_v = np.stack([sim_valid, v2])
            expect_p = np.stack([sim_pos, p2])
        got_v = np.asarray(_cache_validity(positions, n, window))
        np.testing.assert_array_equal(
            got_v, expect_v, err_msg=f"validity n={n} w={window} cur={cur}")
        got_p = np.asarray(_cache_abs_positions(positions, n, window))
        # abs positions only contracted where valid (unwritten ring slots
        # report a negative "previous lap" position; linear report slot)
        np.testing.assert_array_equal(
            np.where(expect_v, got_p, -1), np.where(expect_v, expect_p, -1),
            err_msg=f"abs_pos n={n} w={window} cur={cur}")


def test_ring_state_is_exactly_the_valid_segment():
    """The (start, length) descriptor the ring kernels mask with must
    name exactly the slots the validity mask keeps."""
    for n, window, cur in _cases():
        layout = CacheLayout(n, window)
        positions = jnp.asarray([cur], jnp.int32)
        start, length = layout.ring_state(positions)
        start, length = int(start), int(length)
        seg = np.zeros(n, bool)
        for i in range(length):
            seg[(start + i) % n] = True
        np.testing.assert_array_equal(
            seg, np.asarray(layout.validity(positions)),
            err_msg=f"ring_state n={n} w={window} cur={cur}")


def test_write_index_wraps_only_for_rings():
    lin = CacheLayout(8)
    ring = CacheLayout(8, window=8)
    pos = jnp.asarray([3, 9, 17], jnp.int32)
    np.testing.assert_array_equal(np.asarray(lin.write_index(pos)), [3, 9, 17])
    np.testing.assert_array_equal(np.asarray(ring.write_index(pos)), [3, 1, 1])


def test_large_positions_do_not_overflow_int32():
    """Regression for the `pos - window` / `(pos // n) * n + slot`
    overflow traps (the retired BIG_WINDOW sentinel): at positions a few
    tokens below int32 max, validity and abs positions must match the
    python-bigint closed form exactly."""
    n, w = 16, 16
    for cur in (INT32_MAX - 3, INT32_MAX - n, 2**30 + 5):
        positions = jnp.asarray([cur], jnp.int32)
        got_v = np.asarray(_cache_validity(positions, n, w))
        got_p = np.asarray(_cache_abs_positions(positions, n, w))
        expect_p = np.array([cur - ((cur - t) % n) for t in range(n)])
        expect_v = (expect_p >= 0) & (cur - expect_p < w)
        np.testing.assert_array_equal(got_v, expect_v)
        np.testing.assert_array_equal(got_p, expect_p)
        assert got_v.all()  # a full ring this deep is entirely live
        # linear layouts too: valid_len prefix must saturate, not wrap
        start, length = CacheLayout(n).ring_state(positions)
        assert int(length) == n and int(start) == 0


def test_fill_index_padding_never_clobbers_short_rows():
    """Right-padded admission: each row writes only its own trailing
    window; padding gets the OOB sentinel (dropped by the scatter)."""
    layout = CacheLayout(4, window=4)
    S = 8
    positions = jnp.arange(S, dtype=jnp.int32)
    lengths = jnp.asarray([2, 8, 5], jnp.int32)
    idx = np.asarray(layout.fill_index(positions, lengths))
    assert idx.shape == (3, S)
    # row 0: tokens 0,1 live at slots 0,1; everything else dropped
    np.testing.assert_array_equal(idx[0], [0, 1, 4, 4, 4, 4, 4, 4])
    # row 1: full chunk, only the trailing 4 tokens (4..7) are kept
    np.testing.assert_array_equal(idx[1], [4, 4, 4, 4, 0, 1, 2, 3])
    # row 2: tokens 1..4 kept (trailing window of a 5-token prompt)
    np.testing.assert_array_equal(idx[2], [4, 1, 2, 3, 0, 4, 4, 4])


def test_make_clamps_ring_length_to_window():
    assert CacheLayout.make(128).cache_len == 128
    assert CacheLayout.make(128, window=16).cache_len == 16
    assert CacheLayout.make(8, window=16).cache_len == 8
    assert not CacheLayout.make(128).is_ring
    assert CacheLayout.make(128, window=16).is_ring


# ----------------------------------------------------------------------
# hypothesis property sweep (skipped when hypothesis is unavailable; the
# deterministic sweeps above cover the same invariants)
# ----------------------------------------------------------------------

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    SETTINGS = dict(max_examples=60, deadline=None)

    @given(n=st.integers(1, 12), extra=st.integers(0, 9),
           cur=st.integers(0, 200))
    @settings(**SETTINGS)
    def test_hypothesis_ring_validity_matches_simulation(n, extra, cur):
        window = n + extra  # arenas always size n = min(max_len, window)
        sim_pos, sim_valid = _simulate(cur, n, window)
        positions = jnp.asarray([cur], jnp.int32)
        np.testing.assert_array_equal(
            np.asarray(_cache_validity(positions, n, window)), sim_valid)
        got_p = np.asarray(_cache_abs_positions(positions, n, window))
        np.testing.assert_array_equal(
            np.where(sim_valid, got_p, -1), np.where(sim_valid, sim_pos, -1))

    @given(n=st.integers(1, 12), extra=st.integers(0, 9),
           cur=st.integers(0, 200))
    @settings(**SETTINGS)
    def test_hypothesis_ring_state_matches_validity(n, extra, cur):
        layout = CacheLayout(n, n + extra)
        positions = jnp.asarray([cur], jnp.int32)
        start, length = layout.ring_state(positions)
        seg = np.zeros(n, bool)
        for i in range(int(length)):
            seg[(int(start) + i) % n] = True
        np.testing.assert_array_equal(
            seg, np.asarray(layout.validity(positions)))
