"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps, interpret mode."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.kernels import ops, ref

RNG = np.random.default_rng(0)


def _tol(dtype):
    return dict(atol=2e-2, rtol=2e-2) if dtype == jnp.bfloat16 \
        else dict(atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("M,d,r,N", [
    (128, 256, 128, 128),
    (256, 384, 256, 512),
    (64, 136, 96, 72),      # non-128-aligned shapes
])
def test_latent_matmul(M, d, r, N, dtype):
    x = jnp.asarray(RNG.normal(size=(M, d)), dtype)
    a2t = jnp.asarray(RNG.normal(size=(d - r, r)) / np.sqrt(d - r), dtype)
    b = jnp.asarray(RNG.normal(size=(r, N)) / np.sqrt(r), dtype)
    perm = RNG.permutation(d)
    y_k = ops.latent_matmul(x, a2t, b, jnp.asarray(perm), interpret=True)
    y_r = ref.latent_matmul_ref(x, a2t, b, perm)
    np.testing.assert_allclose(np.asarray(y_k, np.float32),
                               np.asarray(y_r, np.float32), **_tol(dtype))


def test_latent_matmul_identity_only():
    """r == d degenerates to plain y = x @ b (A = I)."""
    M, d, N = 64, 128, 96
    x = jnp.asarray(RNG.normal(size=(M, d)), jnp.float32)
    a2t = jnp.zeros((0, d), jnp.float32)
    b = jnp.asarray(RNG.normal(size=(d, N)), jnp.float32)
    y = ops.latent_matmul(x, a2t, b, interpret=True)
    np.testing.assert_allclose(np.asarray(y), np.asarray(x @ b), rtol=2e-5,
                               atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,H,S,rk,rv,bs", [
    (2, 8, 256, 64, 48, 128),
    (1, 4, 512, 32, 32, 512),
    (3, 16, 384, 128, 64, 128),
])
def test_mla_decode(B, H, S, rk, rv, bs, dtype):
    qt = jnp.asarray(RNG.normal(size=(B, H, rk)), dtype)
    ck = jnp.asarray(RNG.normal(size=(B, S, rk)), dtype)
    cv = jnp.asarray(RNG.normal(size=(B, S, rv)), dtype)
    vl = jnp.asarray(RNG.integers(1, S, size=(B,)), jnp.int32)
    u_k = ops.mla_decode(qt, ck, cv, vl, scale=0.125, interpret=True)
    u_r = ref.mla_decode_ref(qt, ck, cv, vl, scale=0.125)
    np.testing.assert_allclose(np.asarray(u_k, np.float32),
                               np.asarray(u_r, np.float32), **_tol(dtype))


@pytest.mark.parametrize("B,S,H,P,G,N,chunk", [
    (2, 64, 4, 16, 2, 8, 16),
    (1, 128, 8, 8, 1, 16, 32),
    (2, 96, 4, 16, 4, 8, 32),   # S not a multiple of 64; G == H/1
])
def test_ssd_scan(B, S, H, P, G, N, chunk):
    if S % chunk:
        pytest.skip("kernel requires chunk-divisible S (model pads)")
    x = jnp.asarray(RNG.normal(size=(B, S, H, P)) * 0.5, jnp.float32)
    dt = jnp.asarray(RNG.uniform(0.01, 0.2, size=(B, S, H)), jnp.float32)
    A = jnp.asarray(-RNG.uniform(0.5, 2.0, size=(H,)), jnp.float32)
    Bm = jnp.asarray(RNG.normal(size=(B, S, G, N)) * 0.3, jnp.float32)
    Cm = jnp.asarray(RNG.normal(size=(B, S, G, N)) * 0.3, jnp.float32)
    y_k, st_k = ops.ssd_scan(x, dt, A, Bm, Cm, chunk=chunk, interpret=True)
    y_r, st_r = ref.ssd_scan_ref(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_r),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(st_k), np.asarray(st_r),
                               atol=1e-4, rtol=1e-4)


def test_ssd_kernel_matches_model_layer():
    """The kernel agrees with the chunked-scan used inside the model."""
    from repro.models.layers import _ssd_chunked
    B, S, H, P, G, N = 2, 64, 4, 16, 2, 8
    x = jnp.asarray(RNG.normal(size=(B, S, H, P)) * 0.5, jnp.float32)
    dt = jnp.asarray(RNG.uniform(0.01, 0.2, size=(B, S, H)), jnp.float32)
    A = jnp.asarray(-RNG.uniform(0.5, 2.0, size=(H,)), jnp.float32)
    Bm = jnp.asarray(RNG.normal(size=(B, S, G, N)) * 0.3, jnp.float32)
    Cm = jnp.asarray(RNG.normal(size=(B, S, G, N)) * 0.3, jnp.float32)
    y_m, st_m = _ssd_chunked(x, dt, A, Bm, Cm, 16)
    y_k, st_k = ops.ssd_scan(x, dt, A, Bm, Cm, chunk=16, interpret=True)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_m),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(st_k), np.asarray(st_m),
                               atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("B,H,S,rk,rv", [
    (2, 8, 100, 64, 48),    # S with no pow2 divisor <= pref
    (1, 4, 97, 32, 32),     # prime S -> single odd block
    (3, 16, 384, 128, 64),
])
def test_mla_decode_auto_block(B, H, S, rk, rv):
    """Arbitrary cache lengths work: the kernel picks a dividing block."""
    qt = jnp.asarray(RNG.normal(size=(B, H, rk)), jnp.float32)
    ck = jnp.asarray(RNG.normal(size=(B, S, rk)), jnp.float32)
    cv = jnp.asarray(RNG.normal(size=(B, S, rv)), jnp.float32)
    vl = jnp.asarray(RNG.integers(1, S, size=(B,)), jnp.int32)
    u_k = ops.mla_decode(qt, ck, cv, vl, scale=0.125, interpret=True)
    u_r = ref.mla_decode_ref(qt, ck, cv, vl, scale=0.125)
    np.testing.assert_allclose(np.asarray(u_k), np.asarray(u_r),
                               atol=2e-5, rtol=2e-5)


def test_mla_decode_empty_cache_no_nan():
    """Regression: valid_len == 0 must yield zeros, not 0/0 NaNs."""
    B, H, S, rk, rv = 3, 4, 128, 32, 32
    qt = jnp.asarray(RNG.normal(size=(B, H, rk)), jnp.float32)
    ck = jnp.asarray(RNG.normal(size=(B, S, rk)), jnp.float32)
    cv = jnp.asarray(RNG.normal(size=(B, S, rv)), jnp.float32)
    vl = jnp.asarray([0, 5, 0], jnp.int32)
    u = ops.mla_decode(qt, ck, cv, vl, scale=0.125, interpret=True)
    assert not bool(jnp.isnan(u).any())
    np.testing.assert_array_equal(np.asarray(u[0]), 0.0)
    np.testing.assert_array_equal(np.asarray(u[2]), 0.0)
    np.testing.assert_allclose(
        np.asarray(u), np.asarray(ref.mla_decode_ref(qt, ck, cv, vl,
                                                     scale=0.125)),
        atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,G,R,S,rk,rv,Dh,softcap", [
    (2, 2, 4, 256, 64, 48, 16, None),
    (1, 4, 1, 100, 32, 32, 32, None),   # MHA (R=1), odd S
    (2, 2, 2, 128, 16, 16, 16, 30.0),   # softcapped
])
def test_mla_decode_grouped(B, G, R, S, rk, rv, Dh, softcap, dtype):
    """Grouped kernel (fused value decompression) matches the oracle and
    the per-head kernel + host-side einsum path."""
    qt = jnp.asarray(RNG.normal(size=(B, G, R, rk)), dtype)
    ck = jnp.asarray(RNG.normal(size=(B, S, rk)), dtype)
    cv = jnp.asarray(RNG.normal(size=(B, S, rv)), dtype)
    bv = jnp.asarray(RNG.normal(size=(G, rv, Dh)) / np.sqrt(rv), dtype)
    vl = jnp.asarray(RNG.integers(1, S, size=(B,)), jnp.int32)
    y_k = ops.mla_decode_grouped(qt, ck, cv, bv, vl, scale=0.125,
                                 softcap=softcap, interpret=True)
    y_r = ref.mla_decode_grouped_ref(qt, ck, cv, bv, vl, scale=0.125,
                                     softcap=softcap)
    np.testing.assert_allclose(np.asarray(y_k, np.float32),
                               np.asarray(y_r, np.float32), **_tol(dtype))
    if softcap is None:
        u = ops.mla_decode(qt.reshape(B, G * R, rk), ck, cv, vl,
                           scale=0.125, interpret=True)
        y_p = jnp.einsum("bgrv,gvd->bgrd",
                         u.reshape(B, G, R, rv).astype(jnp.float32),
                         bv.astype(jnp.float32))
        np.testing.assert_allclose(np.asarray(y_k, np.float32),
                                   np.asarray(y_p), **_tol(dtype))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,H,T,rk,rv", [
    (2, 4, 128, 64, 48),
    (1, 8, 97, 32, 32),     # odd (prime) sequence length
    (3, 2, 100, 16, 16),    # no pow2 divisor
])
def test_mla_prefill(B, H, T, rk, rv, dtype):
    """Flash prefill vs dense oracle: causal masking + ragged valid_len
    (including a fully padded row -> zero output, no NaN)."""
    qt = jnp.asarray(RNG.normal(size=(B, H, T, rk)), dtype)
    ck = jnp.asarray(RNG.normal(size=(B, T, rk)), dtype)
    cv = jnp.asarray(RNG.normal(size=(B, T, rv)), dtype)
    vl = jnp.asarray(RNG.integers(0, T + 1, size=(B,)), jnp.int32)
    u_k = ops.mla_prefill(qt, ck, cv, vl, scale=0.125, interpret=True)
    u_r = ref.mla_prefill_ref(qt, ck, cv, vl, scale=0.125)
    assert not bool(jnp.isnan(u_k).any())
    np.testing.assert_allclose(np.asarray(u_k, np.float32),
                               np.asarray(u_r, np.float32), **_tol(dtype))


def test_mla_prefill_causal_masks_future():
    """Token t's output is unchanged by edits to keys/values after t."""
    B, H, T, rk, rv = 1, 2, 64, 16, 16
    qt = jnp.asarray(RNG.normal(size=(B, H, T, rk)), jnp.float32)
    ck = jnp.asarray(RNG.normal(size=(B, T, rk)), jnp.float32)
    cv = jnp.asarray(RNG.normal(size=(B, T, rv)), jnp.float32)
    vl = jnp.full((B,), T, jnp.int32)
    u1 = ops.mla_prefill(qt, ck, cv, vl, scale=0.125, interpret=True)
    t = 20
    ck2 = ck.at[:, t + 1:].add(3.0)
    cv2 = cv.at[:, t + 1:].add(3.0)
    u2 = ops.mla_prefill(qt, ck2, cv2, vl, scale=0.125, interpret=True)
    np.testing.assert_allclose(np.asarray(u1[:, :, :t + 1]),
                               np.asarray(u2[:, :, :t + 1]),
                               atol=1e-6, rtol=1e-6)
    assert float(jnp.max(jnp.abs(u1[:, :, t + 1:] - u2[:, :, t + 1:]))) > 1e-3


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,H,S,rk,rv", [
    (2, 8, 256, 64, 48),
    (1, 4, 100, 32, 32),    # odd cache length
    (3, 16, 384, 128, 64),
])
def test_mla_decode_ring(B, H, S, rk, rv, dtype):
    """Per-head ring decode vs oracle: live slots are a wrapped
    (start, length) segment, including fully-wrapped and empty rows."""
    qt = jnp.asarray(RNG.normal(size=(B, H, rk)), dtype)
    ck = jnp.asarray(RNG.normal(size=(B, S, rk)), dtype)
    cv = jnp.asarray(RNG.normal(size=(B, S, rv)), dtype)
    start = jnp.asarray(RNG.integers(0, S, size=(B,)), jnp.int32)
    length = jnp.asarray(RNG.integers(0, S + 1, size=(B,)), jnp.int32)
    u_k = ops.mla_decode_ring(qt, ck, cv, start, length, scale=0.125,
                              interpret=True)
    u_r = ref.mla_decode_ring_ref(qt, ck, cv, start, length, scale=0.125)
    assert not bool(jnp.isnan(u_k).any())
    np.testing.assert_allclose(np.asarray(u_k, np.float32),
                               np.asarray(u_r, np.float32), **_tol(dtype))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,G,R,S,rk,rv,Dh,softcap", [
    (2, 2, 4, 256, 64, 48, 16, None),
    (1, 4, 1, 100, 32, 32, 32, None),   # MHA (R=1), odd S
    (2, 2, 2, 128, 16, 16, 16, 30.0),   # softcapped (gemma2-style)
])
def test_mla_decode_grouped_ring(B, G, R, S, rk, rv, Dh, softcap, dtype):
    """Grouped ring decode (fused value decompression) vs the oracle,
    and vs the prefix kernel when the ring degenerates (start == 0)."""
    qt = jnp.asarray(RNG.normal(size=(B, G, R, rk)), dtype)
    ck = jnp.asarray(RNG.normal(size=(B, S, rk)), dtype)
    cv = jnp.asarray(RNG.normal(size=(B, S, rv)), dtype)
    bv = jnp.asarray(RNG.normal(size=(G, rv, Dh)) / np.sqrt(rv), dtype)
    start = jnp.asarray(RNG.integers(0, S, size=(B,)), jnp.int32)
    length = jnp.asarray(RNG.integers(1, S + 1, size=(B,)), jnp.int32)
    y_k = ops.mla_decode_grouped_ring(qt, ck, cv, bv, start, length,
                                      scale=0.125, softcap=softcap,
                                      interpret=True)
    y_r = ref.mla_decode_grouped_ring_ref(qt, ck, cv, bv, start, length,
                                          scale=0.125, softcap=softcap)
    np.testing.assert_allclose(np.asarray(y_k, np.float32),
                               np.asarray(y_r, np.float32), **_tol(dtype))
    # start == 0 ring == valid_len prefix kernel, bit for bit
    zeros = jnp.zeros((B,), jnp.int32)
    y_ring = ops.mla_decode_grouped_ring(qt, ck, cv, bv, zeros, length,
                                         scale=0.125, softcap=softcap,
                                         interpret=True)
    y_pref = ops.mla_decode_grouped(qt, ck, cv, bv, length, scale=0.125,
                                    softcap=softcap, interpret=True)
    np.testing.assert_array_equal(np.asarray(y_ring), np.asarray(y_pref))


@pytest.mark.parametrize("B,H,T,rk,rv,window", [
    (2, 4, 128, 64, 48, 32),
    (1, 8, 97, 32, 32, 7),     # odd (prime) length, tiny window
    (3, 2, 100, 16, 16, 100),  # window covers everything == plain causal
])
def test_mla_prefill_windowed(B, H, T, rk, rv, window):
    """Windowed flash prefill vs the dense oracle (causal + sliding
    window + ragged valid_len), incl. the window-covers-all case."""
    qt = jnp.asarray(RNG.normal(size=(B, H, T, rk)), jnp.float32)
    ck = jnp.asarray(RNG.normal(size=(B, T, rk)), jnp.float32)
    cv = jnp.asarray(RNG.normal(size=(B, T, rv)), jnp.float32)
    vl = jnp.asarray(RNG.integers(0, T + 1, size=(B,)), jnp.int32)
    u_k = ops.mla_prefill(qt, ck, cv, vl, scale=0.125, window=window,
                          interpret=True)
    u_r = ref.mla_prefill_ref(qt, ck, cv, vl, scale=0.125, window=window)
    assert not bool(jnp.isnan(u_k).any())
    np.testing.assert_allclose(np.asarray(u_k), np.asarray(u_r),
                               atol=2e-5, rtol=2e-5)
    if window >= T:
        u_c = ops.mla_prefill(qt, ck, cv, vl, scale=0.125, interpret=True)
        np.testing.assert_array_equal(np.asarray(u_k), np.asarray(u_c))


def test_mla_prefill_window_masks_old_keys():
    """Token t's output is unchanged by edits to keys/values more than
    window-1 behind it (the sliding-window block pruning is sound)."""
    B, H, T, rk, rv, w = 1, 2, 64, 16, 16, 8
    qt = jnp.asarray(RNG.normal(size=(B, H, T, rk)), jnp.float32)
    ck = jnp.asarray(RNG.normal(size=(B, T, rk)), jnp.float32)
    cv = jnp.asarray(RNG.normal(size=(B, T, rv)), jnp.float32)
    vl = jnp.full((B,), T, jnp.int32)
    u1 = ops.mla_prefill(qt, ck, cv, vl, scale=0.125, window=w,
                         interpret=True)
    t = 40
    ck2 = ck.at[:, :t - w + 1].add(3.0)   # only keys outside t's window
    cv2 = cv.at[:, :t - w + 1].add(3.0)
    u2 = ops.mla_prefill(qt, ck2, cv2, vl, scale=0.125, window=w,
                         interpret=True)
    np.testing.assert_allclose(np.asarray(u1[:, :, t:]),
                               np.asarray(u2[:, :, t:]),
                               atol=1e-6, rtol=1e-6)
    assert float(jnp.max(jnp.abs(u1[:, :, :t - w + 1]
                                 - u2[:, :, :t - w + 1]))) > 1e-3


def _absorbed_latent_cfg():
    import dataclasses
    from repro.configs import REGISTRY, reduced, LatentConfig
    cfg = dataclasses.replace(
        reduced(REGISTRY["mamba2-2.7b"]), dtype="float32")
    return dataclasses.replace(
        cfg, family="dense", num_heads=4, num_kv_heads=2, head_dim=16,
        d_ff=128, pos_emb="none", qkv_bias=False,
        latent=LatentConfig(enabled=True, compression=0.3))


def test_latent_prefill_uses_kernel_and_matches(monkeypatch):
    """layers.latent_attention_fwd serving prefill goes through the
    mla_prefill kernel (no (…, S, T) score einsum) and matches the
    training-path (blocked dense) output."""
    from repro.core.ranks import latent_ranks
    from repro.models import layers as L

    cfg = _absorbed_latent_cfg()
    rk = latent_ranks(cfg)
    key = jax.random.PRNGKey(0)
    p = L.init_latent_attention(key, cfg, rk["r_q"], rk["r_k"], rk["r_v"],
                                rk["r_o"])
    B, S = 2, 20
    x = jax.random.normal(key, (B, S, cfg.d_model), jnp.float32)
    y_train, _ = L.latent_attention_fwd(p, x, cfg, positions=jnp.arange(S))

    calls = []
    real = ops.mla_prefill
    monkeypatch.setattr(L.kops, "mla_prefill",
                        lambda *a, **k: (calls.append(1), real(*a, **k))[1])
    cache = L.init_latent_attention_cache(cfg, B, S + 4, rk["r_k"],
                                          rk["r_v"])
    y_serve, new_cache = L.latent_attention_fwd(
        p, x, cfg, positions=jnp.arange(S), cache=cache)
    assert calls, "serving prefill did not dispatch the mla_prefill kernel"
    np.testing.assert_allclose(np.asarray(y_serve), np.asarray(y_train),
                               atol=1e-4, rtol=1e-4)
    # the cache now holds the latents; decode continues consistently
    y_dec, _ = L.latent_attention_fwd(
        p, jax.random.normal(key, (B, 1, cfg.d_model), jnp.float32), cfg,
        positions=jnp.asarray([S]), cache=new_cache)
    assert not bool(jnp.isnan(y_dec).any())


def test_latent_decode_uses_grouped_kernel_and_matches(monkeypatch):
    """The absorbed decode branch dispatches mla_decode_grouped, and a
    prefill+decode over the cache reproduces the uncached forward at the
    last position."""
    from repro.core.ranks import latent_ranks
    from repro.models import layers as L

    cfg = _absorbed_latent_cfg()
    rk = latent_ranks(cfg)
    key = jax.random.PRNGKey(2)
    p = L.init_latent_attention(key, cfg, rk["r_q"], rk["r_k"], rk["r_v"],
                                rk["r_o"])
    B, S = 2, 17
    x = jax.random.normal(key, (B, S + 1, cfg.d_model), jnp.float32)
    y_full, _ = L.latent_attention_fwd(p, x, cfg, positions=jnp.arange(S + 1))

    calls = []
    real = ops.mla_decode_grouped
    monkeypatch.setattr(L.kops, "mla_decode_grouped",
                        lambda *a, **k: (calls.append(1), real(*a, **k))[1])
    cache = L.init_latent_attention_cache(cfg, B, S + 1, rk["r_k"],
                                          rk["r_v"])
    _, cache = L.latent_attention_fwd(p, x[:, :S], cfg,
                                      positions=jnp.arange(S), cache=cache)
    y_dec, _ = L.latent_attention_fwd(p, x[:, S:], cfg,
                                      positions=jnp.asarray([S]),
                                      cache=cache)
    assert calls, "absorbed decode did not dispatch mla_decode_grouped"
    np.testing.assert_allclose(np.asarray(y_dec[:, 0]),
                               np.asarray(y_full[:, -1]),
                               atol=1e-4, rtol=1e-4)


def test_mla_decode_full_matches_layer():
    """ops.mla_decode_full == layers.latent_attention_fwd absorbed decode."""
    import dataclasses
    from repro.configs import REGISTRY, reduced, LatentConfig
    from repro.core.ranks import latent_ranks
    from repro.models import layers as L

    cfg = dataclasses.replace(
        reduced(REGISTRY["mamba2-2.7b"]), dtype="float32")
    # build a NoPE attention config so absorption applies
    cfg = dataclasses.replace(
        cfg, family="dense", num_heads=4, num_kv_heads=2, head_dim=16,
        d_ff=128, pos_emb="none", qkv_bias=False,
        latent=LatentConfig(enabled=True, compression=0.3))
    rk = latent_ranks(cfg)
    key = jax.random.PRNGKey(0)
    p = L.init_latent_attention(key, cfg, rk["r_q"], rk["r_k"], rk["r_v"],
                                rk["r_o"])
    B, S = 2, 16
    cache = L.init_latent_attention_cache(cfg, B, S, rk["r_k"], rk["r_v"])
    # pre-fill some latents
    pre = jax.random.normal(key, (B, 10, cfg.d_model), jnp.float32)
    _, cache = L.latent_attention_fwd(
        p, pre, cfg, positions=jnp.arange(10), cache=cache)
    x = jax.random.normal(key, (B, 1, cfg.d_model), jnp.float32)
    y_layer, new_cache = L.latent_attention_fwd(
        p, x, cfg, positions=jnp.asarray([10]), cache=cache)
    y_kernel = ops.mla_decode_full(p, x, cfg, new_cache,
                                   jnp.full((B,), 11, jnp.int32))
    np.testing.assert_allclose(np.asarray(y_kernel), np.asarray(y_layer),
                               atol=1e-4, rtol=1e-4)
