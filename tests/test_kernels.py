"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps, interpret mode."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.kernels import ops, ref

RNG = np.random.default_rng(0)


def _tol(dtype):
    return dict(atol=2e-2, rtol=2e-2) if dtype == jnp.bfloat16 \
        else dict(atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("M,d,r,N", [
    (128, 256, 128, 128),
    (256, 384, 256, 512),
    (64, 136, 96, 72),      # non-128-aligned shapes
])
def test_latent_matmul(M, d, r, N, dtype):
    x = jnp.asarray(RNG.normal(size=(M, d)), dtype)
    a2t = jnp.asarray(RNG.normal(size=(d - r, r)) / np.sqrt(d - r), dtype)
    b = jnp.asarray(RNG.normal(size=(r, N)) / np.sqrt(r), dtype)
    perm = RNG.permutation(d)
    y_k = ops.latent_matmul(x, a2t, b, jnp.asarray(perm), interpret=True)
    y_r = ref.latent_matmul_ref(x, a2t, b, perm)
    np.testing.assert_allclose(np.asarray(y_k, np.float32),
                               np.asarray(y_r, np.float32), **_tol(dtype))


def test_latent_matmul_identity_only():
    """r == d degenerates to plain y = x @ b (A = I)."""
    M, d, N = 64, 128, 96
    x = jnp.asarray(RNG.normal(size=(M, d)), jnp.float32)
    a2t = jnp.zeros((0, d), jnp.float32)
    b = jnp.asarray(RNG.normal(size=(d, N)), jnp.float32)
    y = ops.latent_matmul(x, a2t, b, interpret=True)
    np.testing.assert_allclose(np.asarray(y), np.asarray(x @ b), rtol=2e-5,
                               atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,H,S,rk,rv,bs", [
    (2, 8, 256, 64, 48, 128),
    (1, 4, 512, 32, 32, 512),
    (3, 16, 384, 128, 64, 128),
])
def test_mla_decode(B, H, S, rk, rv, bs, dtype):
    qt = jnp.asarray(RNG.normal(size=(B, H, rk)), dtype)
    ck = jnp.asarray(RNG.normal(size=(B, S, rk)), dtype)
    cv = jnp.asarray(RNG.normal(size=(B, S, rv)), dtype)
    vl = jnp.asarray(RNG.integers(1, S, size=(B,)), jnp.int32)
    u_k = ops.mla_decode(qt, ck, cv, vl, scale=0.125, interpret=True)
    u_r = ref.mla_decode_ref(qt, ck, cv, vl, scale=0.125)
    np.testing.assert_allclose(np.asarray(u_k, np.float32),
                               np.asarray(u_r, np.float32), **_tol(dtype))


@pytest.mark.parametrize("B,S,H,P,G,N,chunk", [
    (2, 64, 4, 16, 2, 8, 16),
    (1, 128, 8, 8, 1, 16, 32),
    (2, 96, 4, 16, 4, 8, 32),   # S not a multiple of 64; G == H/1
])
def test_ssd_scan(B, S, H, P, G, N, chunk):
    if S % chunk:
        pytest.skip("kernel requires chunk-divisible S (model pads)")
    x = jnp.asarray(RNG.normal(size=(B, S, H, P)) * 0.5, jnp.float32)
    dt = jnp.asarray(RNG.uniform(0.01, 0.2, size=(B, S, H)), jnp.float32)
    A = jnp.asarray(-RNG.uniform(0.5, 2.0, size=(H,)), jnp.float32)
    Bm = jnp.asarray(RNG.normal(size=(B, S, G, N)) * 0.3, jnp.float32)
    Cm = jnp.asarray(RNG.normal(size=(B, S, G, N)) * 0.3, jnp.float32)
    y_k, st_k = ops.ssd_scan(x, dt, A, Bm, Cm, chunk=chunk, interpret=True)
    y_r, st_r = ref.ssd_scan_ref(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_r),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(st_k), np.asarray(st_r),
                               atol=1e-4, rtol=1e-4)


def test_ssd_kernel_matches_model_layer():
    """The kernel agrees with the chunked-scan used inside the model."""
    from repro.models.layers import _ssd_chunked
    B, S, H, P, G, N = 2, 64, 4, 16, 2, 8
    x = jnp.asarray(RNG.normal(size=(B, S, H, P)) * 0.5, jnp.float32)
    dt = jnp.asarray(RNG.uniform(0.01, 0.2, size=(B, S, H)), jnp.float32)
    A = jnp.asarray(-RNG.uniform(0.5, 2.0, size=(H,)), jnp.float32)
    Bm = jnp.asarray(RNG.normal(size=(B, S, G, N)) * 0.3, jnp.float32)
    Cm = jnp.asarray(RNG.normal(size=(B, S, G, N)) * 0.3, jnp.float32)
    y_m, st_m = _ssd_chunked(x, dt, A, Bm, Cm, 16)
    y_k, st_k = ops.ssd_scan(x, dt, A, Bm, Cm, chunk=16, interpret=True)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_m),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(st_k), np.asarray(st_m),
                               atol=1e-4, rtol=1e-4)


def test_mla_decode_full_matches_layer():
    """ops.mla_decode_full == layers.latent_attention_fwd absorbed decode."""
    import dataclasses
    from repro.configs import REGISTRY, reduced, LatentConfig
    from repro.core.ranks import latent_ranks
    from repro.models import layers as L

    cfg = dataclasses.replace(
        reduced(REGISTRY["mamba2-2.7b"]), dtype="float32")
    # build a NoPE attention config so absorption applies
    cfg = dataclasses.replace(
        cfg, family="dense", num_heads=4, num_kv_heads=2, head_dim=16,
        d_ff=128, pos_emb="none", qkv_bias=False,
        latent=LatentConfig(enabled=True, compression=0.3))
    rk = latent_ranks(cfg)
    key = jax.random.PRNGKey(0)
    p = L.init_latent_attention(key, cfg, rk["r_q"], rk["r_k"], rk["r_v"],
                                rk["r_o"])
    B, S = 2, 16
    cache = L.init_latent_attention_cache(cfg, B, S, rk["r_k"], rk["r_v"])
    # pre-fill some latents
    pre = jax.random.normal(key, (B, 10, cfg.d_model), jnp.float32)
    _, cache = L.latent_attention_fwd(
        p, pre, cfg, positions=jnp.arange(10), cache=cache)
    x = jax.random.normal(key, (B, 1, cfg.d_model), jnp.float32)
    y_layer, new_cache = L.latent_attention_fwd(
        p, x, cfg, positions=jnp.asarray([10]), cache=cache)
    y_kernel = ops.mla_decode_full(p, x, cfg, new_cache,
                                   jnp.full((B,), 11, jnp.int32))
    np.testing.assert_allclose(np.asarray(y_kernel), np.asarray(y_layer),
                               atol=1e-4, rtol=1e-4)
