"""Sliding-window (ring-cache) serving through the Engine.

Acceptance (ISSUE 5): a gemma2/danube-style tiny config serves through
the Engine on the absorbed RING-kernel path — no ref-einsum fallback
(jaxpr-checked pallas_call), decode stays ONE fused dispatch, and
streamed tokens are bit-identical to the lockstep ``greedy_generate``
reference on a single device AND on a 2x4 fake-device mesh (the sharded
pass runs in a subprocess so the 8-device XLA flag never leaks)."""
import dataclasses
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import REGISTRY, LatentConfig, reduced
from repro.models import lm, transformer as T
from repro.serve import Engine, Request, SamplingParams
from repro.serve.arena import arena_cache_bytes


def _cfg(name, **kw):
    cfg = dataclasses.replace(reduced(REGISTRY[name]), dtype="float32")
    return dataclasses.replace(cfg, **kw) if kw else cfg


def _absorbed_gemma2(**kw):
    """gemma2-style tiny config (local/global alternation, softcaps,
    window 16) on the absorbed path: NoPE + latent compression."""
    return _cfg("gemma2-27b", pos_emb="none", qkv_bias=False,
                latent=LatentConfig(enabled=True, compression=0.3), **kw)


def _prompts(seed, lens, vocab):
    rng = np.random.RandomState(seed)
    return [rng.randint(0, vocab, size=L).astype(np.int32) for L in lens]


def _prims(jx, acc):
    for e in jx.eqns:
        acc.add(e.primitive.name)
        for v in e.params.values():
            if hasattr(v, "eqns"):
                _prims(v, acc)
            elif hasattr(v, "jaxpr"):
                _prims(v.jaxpr, acc)
    return acc


@pytest.mark.parametrize("name,kw", [
    ("gemma2_absorbed", {}),          # ring kernels (local) + linear (global)
    ("gemma2_rope_dense", {}),        # rope einsum ring path, mixed layers
    ("danube_rope_dense", {}),        # every layer windowed
    ("danube_rope_latent", {}),       # windowed latent, decompress-then-rope
])
def test_windowed_engine_streams_lockstep_tokens(name, kw):
    """Acceptance: ragged windowed requests — including prompts LONGER
    than the window, which wrap the ring during admission — decode in
    the slot arena bit-identically to lockstep greedy_generate, and the
    streamed on_token sequence equals the final outputs."""
    cfg = {
        "gemma2_absorbed": lambda: _absorbed_gemma2(),
        "gemma2_rope_dense": lambda: _cfg("gemma2-27b"),
        "danube_rope_dense": lambda: _cfg("h2o-danube-3-4b"),
        "danube_rope_latent": lambda: _cfg(
            "h2o-danube-3-4b",
            latent=LatentConfig(enabled=True, compression=0.3)),
    }[name]()
    assert any(d.window is not None
               for d in T.group_spec(cfg)[0]), "config must be windowed"
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    # window is 16 reduced: 18 forces a 32-token admission bucket > window
    # (the ragged ring-fill regression) and 18+6 > 16 wraps during decode
    prompts = _prompts(0, (3, 18, 6, 11), cfg.vocab_size)
    streamed = {}
    eng = Engine(cfg, params, num_slots=2, max_len=40)
    reqs = [eng.submit(p, SamplingParams(max_new_tokens=6),
                       on_token=lambda r, t: streamed.setdefault(
                           r.request_id, []).append(t))
            for p in prompts]
    eng.run()
    for p, r in zip(prompts, reqs):
        assert r.finished and r.finish_reason == "length"
        ref = np.asarray(lm.greedy_generate(cfg, params, p[None], steps=6,
                                            max_len=40))[0]
        np.testing.assert_array_equal(r.output(), ref)
        assert streamed[r.request_id] == r.output_tokens


def test_windowed_absorbed_decode_uses_ring_kernel_not_einsum():
    """Acceptance (jaxpr-checked): the engine step for a windowed
    absorbed config is ONE fused dispatch whose attention runs inside
    pallas_call ring kernels — the ref-einsum fallback would leave no
    pallas_call in the jaxpr."""
    cfg = _absorbed_gemma2()
    params = T.init_params(jax.random.PRNGKey(1), cfg)
    B = 3
    cache = T.init_cache(cfg, B, 32)
    cache["pos"] = jnp.array([3, 18, 5], jnp.int32)   # ragged, one wrapped
    step = lm.make_engine_step(cfg)
    jaxpr = jax.make_jaxpr(step)(
        params, cache, jnp.zeros((B, 1), jnp.int32),
        jnp.zeros((B, 2), jnp.uint32), jnp.ones((B,), jnp.int32),
        jnp.zeros((B,), jnp.float32), jnp.zeros((B,), jnp.int32),
        jnp.ones((B,), jnp.float32), jnp.ones((B,), bool))
    top = {e.primitive.name for e in jaxpr.jaxpr.eqns}
    assert "scan" in top and "argmax" in top      # one fused dispatch
    allp = _prims(jaxpr.jaxpr, set())
    assert "pallas_call" in allp, \
        "windowed absorbed decode fell off the ring-kernel path"


def test_windowed_absorbed_dispatches_ring_kernel(monkeypatch):
    """The layer really calls the (start, length) ring kernel — and the
    linear-prefix kernel still serves the global (window=None) layers."""
    from repro.models import layers as L
    from repro.kernels import ops
    cfg = _absorbed_gemma2()
    params = T.init_params(jax.random.PRNGKey(2), cfg)
    calls = {"ring": 0, "prefix": 0}
    real_ring = ops.mla_decode_grouped_ring
    real_pref = ops.mla_decode_grouped
    monkeypatch.setattr(
        L.kops, "mla_decode_grouped_ring",
        lambda *a, **k: (calls.__setitem__("ring", calls["ring"] + 1),
                         real_ring(*a, **k))[1])
    monkeypatch.setattr(
        L.kops, "mla_decode_grouped",
        lambda *a, **k: (calls.__setitem__("prefix", calls["prefix"] + 1),
                         real_pref(*a, **k))[1])
    # the counters tick at trace time: the engine's first step traces the
    # decode head with the patch active (pallas interpret cannot run
    # under disable_jit, so the traced-through call is the check)
    eng = Engine(cfg, params, num_slots=1, max_len=24)
    eng.run([Request(np.arange(5, dtype=np.int32),
                     SamplingParams(max_new_tokens=2))])
    assert calls["ring"] > 0, "no ring-kernel dispatch on windowed layers"
    assert calls["prefix"] > 0, "global layers should keep the prefix kernel"


def test_windowed_cache_report_uses_window_length():
    """Satellite: the latent-vs-dense ratio for windowed configs is
    honest — the dense base is a ring of the WINDOW length, strictly
    smaller than a max_len-long dense cache, and a dense windowed config
    reports ratio exactly 1.0."""
    max_len = 32
    cfg = _absorbed_gemma2()
    params = T.init_params(jax.random.PRNGKey(3), cfg)
    rep = Engine(cfg, params, num_slots=2, max_len=max_len).cache_report()
    assert 0 < rep["ratio"] < 1
    dense_cfg = dataclasses.replace(cfg, latent=LatentConfig(enabled=False))
    # the report's dense base must honour the window...
    assert rep["dense_slot_bytes"] == \
        arena_cache_bytes(dense_cfg, 2, max_len) // 2
    # ...i.e. be strictly below the same model with its windows removed
    nowin = dataclasses.replace(dense_cfg, sliding_window=None)
    assert rep["dense_slot_bytes"] < arena_cache_bytes(nowin, 2, max_len) // 2
    # and a dense windowed engine is its own base: ratio exactly 1.0
    drep = Engine(dense_cfg, T.init_params(jax.random.PRNGKey(4), dense_cfg),
                  num_slots=2, max_len=max_len).cache_report()
    assert drep["ratio"] == 1.0


def test_windowed_slot_recycling_mixed_sampling():
    """Churn greedy + sampled windowed requests through a 2-slot arena:
    everything drains with slots recycling, the run is deterministic
    (same traffic -> same tokens), and greedy rows stay bit-identical to
    the lockstep reference. (Sampled rows are NOT asserted stable across
    different admission-bucket compositions: the absorbed prefill's
    surrounding einsums are only value-stable — ~1 ulp — across batch
    sizes, a pre-existing property of the linear fast path too; greedy
    argmax is robust to it.)"""
    cfg = _absorbed_gemma2()
    params = T.init_params(jax.random.PRNGKey(5), cfg)
    prompts = _prompts(5, (4, 9, 17, 6, 3), cfg.vocab_size)
    sps = [SamplingParams(max_new_tokens=4) if i % 2 == 0 else
           SamplingParams(temperature=0.8 + 0.1 * i, top_k=8, seed=i,
                          max_new_tokens=4)
           for i in range(len(prompts))]

    def run():
        eng = Engine(cfg, params, num_slots=2, max_len=40)
        reqs = [eng.submit(p, sp) for p, sp in zip(prompts, sps)]
        peak = 0
        while eng.step():
            peak = max(peak, int(eng._active.sum()))
            assert eng.arena.num_free + int(eng._active.sum()) == 2
        assert peak == 2 and all(r.finished for r in reqs)
        return [tuple(r.output_tokens) for r in reqs]

    a = run()
    assert a == run()   # deterministic under identical traffic
    for i in (0, 2, 4):  # greedy rows == lockstep
        ref = np.asarray(lm.greedy_generate(cfg, params, prompts[i][None],
                                            steps=4, max_len=40))[0]
        np.testing.assert_array_equal(np.asarray(a[i]), ref)


_SHARDED_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses
import json
import numpy as np
import jax, jax.numpy as jnp
from repro.configs import REGISTRY, LatentConfig, reduced
from repro.launch.mesh import make_debug_mesh
from repro.models import lm, transformer as T
from repro.serve import Engine, SamplingParams

def _cfg(name, **kw):
    cfg = dataclasses.replace(reduced(REGISTRY[name]), dtype="float32")
    return dataclasses.replace(cfg, **kw) if kw else cfg

out = {}
mesh = make_debug_mesh(2, 4)
rng = np.random.RandomState(0)
# 18 > window=16 exercises ring wrap + the 32-bucket ragged admission
prompts = [rng.randint(0, 250, size=L).astype(np.int32)
           for L in (3, 18, 6, 11)]

# num_kv_heads=4 divides the model axis -> per-shard RING Pallas kernels
cfg = _cfg("gemma2-27b", pos_emb="none", qkv_bias=False, num_kv_heads=4,
           latent=LatentConfig(enabled=True, compression=0.3))
params = T.init_params(jax.random.PRNGKey(0), cfg)

def run_engine(m, sps):
    eng = Engine(cfg, params, num_slots=4, max_len=40, mesh=m)
    reqs = [eng.submit(p, sp) for p, sp in zip(prompts, sps)]
    eng.run()
    return [list(map(int, r.output_tokens)) for r in reqs]

greedy = [SamplingParams(max_new_tokens=6) for _ in prompts]
sampled = [SamplingParams(temperature=0.8 + 0.1 * i,
                          top_k=(0, 16, 0, 8)[i], seed=10 + i,
                          max_new_tokens=6) for i in range(len(prompts))]
out["greedy_equal"] = run_engine(None, greedy) == run_engine(mesh, greedy)
out["sampled_equal"] = run_engine(None, sampled) == run_engine(mesh, sampled)
lockstep = [list(map(int, np.asarray(lm.greedy_generate(
    cfg, params, p[None], steps=6, max_len=40))[0])) for p in prompts]
out["greedy_equals_lockstep"] = run_engine(mesh, greedy) == lockstep

# the sharded windowed decode step: ONE fused dispatch, per-shard
# ring kernels (shard_map + pallas_call), no ref-einsum fallback
B = 4
cache = T.init_cache(cfg, B, 40)
cache["pos"] = jnp.array([3, 18, 6, 11], jnp.int32)
step = lm.make_engine_step(cfg)
with mesh:
    jaxpr = jax.make_jaxpr(step)(
        params, cache, jnp.zeros((B, 1), jnp.int32),
        jnp.zeros((B, 2), jnp.uint32), jnp.ones((B,), jnp.int32),
        jnp.zeros((B,), jnp.float32), jnp.zeros((B,), jnp.int32),
        jnp.ones((B,), jnp.float32), jnp.ones((B,), bool))

def prims(jx, acc):
    for e in jx.eqns:
        acc.add(e.primitive.name)
        for v in e.params.values():
            if hasattr(v, "eqns"):
                prims(v, acc)
            elif hasattr(v, "jaxpr"):
                prims(v.jaxpr, acc)
    return acc

top = {e.primitive.name for e in jaxpr.jaxpr.eqns}
allp = prims(jaxpr.jaxpr, set())
out["one_dispatch"] = bool("scan" in top and "argmax" in top)
out["per_shard_ring_kernels"] = bool("shard_map" in allp
                                     and "pallas_call" in allp)
print("RESULT:" + json.dumps(out))
"""


@pytest.fixture(scope="module")
def sharded_window_out():
    env = dict(os.environ,
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..", "src"))
    r = subprocess.run([sys.executable, "-c", _SHARDED_SCRIPT], env=env,
                       capture_output=True, text=True, timeout=1500)
    assert r.returncode == 0, r.stderr[-3000:]
    line = [l for l in r.stdout.splitlines() if l.startswith("RESULT:")][-1]
    return json.loads(line[len("RESULT:"):])


@pytest.mark.slow
def test_sharded_windowed_engine_bit_identical(sharded_window_out):
    """Acceptance: 2x4 mesh == single device == lockstep greedy_generate
    for a windowed absorbed config, greedy AND seeded sampling."""
    assert sharded_window_out["greedy_equal"]
    assert sharded_window_out["sampled_equal"]
    assert sharded_window_out["greedy_equals_lockstep"]


@pytest.mark.slow
def test_sharded_windowed_decode_fused_ring_kernels(sharded_window_out):
    """Acceptance: under the mesh the windowed decode step stays ONE
    fused dispatch with per-shard ring Pallas kernels (shard_map +
    pallas_call in the jaxpr — no ref-einsum fallback)."""
    assert sharded_window_out["one_dispatch"]
    assert sharded_window_out["per_shard_ring_kernels"]
