"""Paged latent cache + radix prefix reuse: pool refcounts, tree
match/insert/evict, copy-on-write isolation, paged-vs-linear greedy
bit-identity with a nonzero prefix hit rate, the single-fused-dispatch
paged decode, and the 2x4-mesh subprocess gate."""
import collections
import dataclasses
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import REGISTRY, LatentConfig, reduced
from repro.models import lm, transformer as T
from repro.models.cache_layout import PagedCacheLayout
from repro.serve import (BlockPool, Engine, PagedLatentArena,
                         RadixPrefixCache, SamplingParams)


def _cfg(name, **kw):
    cfg = dataclasses.replace(reduced(REGISTRY[name]), dtype="float32")
    return dataclasses.replace(cfg, **kw) if kw else cfg


def _latent_cfg(**kw):
    return _cfg("deepseek-coder-33b", pos_emb="none", qkv_bias=False,
                latent=LatentConfig(enabled=True, compression=0.3), **kw)


def _shared_prefix_prompts(seed, prefix_len, suffix_lens, vocab):
    rng = np.random.RandomState(seed)
    shared = rng.randint(0, vocab, size=prefix_len).astype(np.int32)
    return [np.concatenate([shared,
                            rng.randint(0, vocab, size=k).astype(np.int32)])
            for k in suffix_lens]


# -- block pool --------------------------------------------------------

def test_block_pool_alloc_refcount_free():
    pool = BlockPool(num_blocks=4, block_size=8)
    a, b = pool.alloc(), pool.alloc()
    assert a != b and pool.refcount(a) == 1 and pool.blocks_in_use == 2
    assert pool.incref(a) == 2
    assert pool.decref(a) == 1 and not pool.is_free(a)
    assert pool.decref(a) == 0 and pool.is_free(a)
    assert pool.num_free == 3
    # exhaust: alloc returns None, never a sentinel id
    got = {b} | {pool.alloc() for _ in range(3)}
    assert got == {0, 1, 2, 3} and pool.alloc() is None


def test_block_pool_misuse_raises():
    pool = BlockPool(num_blocks=2, block_size=4)
    blk = pool.alloc()
    pool.decref(blk)
    with pytest.raises(ValueError, match="decref of free"):
        pool.decref(blk)                     # double free
    with pytest.raises(ValueError, match="incref of free"):
        pool.incref(blk)
    with pytest.raises(ValueError, match="out of range"):
        pool.refcount(2)                     # the sentinel id is not a block
    with pytest.raises(ValueError):
        BlockPool(0, 4)


# -- radix prefix cache ------------------------------------------------

def test_radix_match_insert_partial():
    pool = BlockPool(num_blocks=8, block_size=4)
    tree = RadixPrefixCache(pool)
    toks = [1, 2, 3, 4, 5, 6, 7, 8, 9, 10]        # 2 full chunks + tail 2
    blocks = [pool.alloc() for _ in range(3)]
    assert tree.insert(toks, blocks) == 3
    assert all(pool.refcount(b) == 2 for b in blocks)  # slot + tree
    m, chain = tree.match(toks)
    assert m == 10 and chain == blocks
    # diverging suffix matches only the shared full chunks
    m, chain = tree.match([1, 2, 3, 4, 5, 6, 7, 8, 99, 100, 101])
    assert m == 8 and chain == blocks[:2]
    m, chain = tree.match([9, 9, 9])
    assert m == 0 and chain == []
    # re-inserting the same path creates nothing and moves no refcounts
    assert tree.insert(toks, blocks) == 0
    assert all(pool.refcount(b) == 2 for b in blocks)


def test_radix_evict_lru_respects_refcounts():
    pool = BlockPool(num_blocks=8, block_size=2)
    tree = RadixPrefixCache(pool)
    held = [pool.alloc() for _ in range(2)]       # a "live slot's" chain
    tree.insert([1, 2, 3, 4], held)
    loose = [pool.alloc() for _ in range(2)]
    tree.insert([7, 8, 9, 10], loose)
    for b in loose:                               # tree is the only holder
        pool.decref(b)
    tree.match([1, 2, 3, 4])                      # refresh LRU on held path
    assert tree.num_evictable == 2
    # only the refcount-1 chain is evictable, leaves first
    assert tree.evict(10) == 2
    assert all(pool.is_free(b) for b in loose)
    assert all(pool.refcount(b) == 2 for b in held)
    assert tree.num_nodes == 2


# -- paged arena accounting (cfg=None: no device pool) -----------------

def test_paged_arena_admit_share_cow_release():
    arena = PagedLatentArena(None, num_slots=2, max_len=16, block_size=4)
    toks = np.arange(10)                          # blocks: 4 + 4 + 2
    s0 = arena.acquire()
    assert arena.admit(s0, toks) == 0             # cold: nothing cached
    arena.insert(s0, toks)
    chain0 = [int(b) for b in arena.tables[s0, :3]]

    # same prompt again: shares both full blocks, copy-on-writes the
    # partial tail (match capped at L-1 = 9 -> mid-block -> CoW)
    s1 = arena.acquire()
    assert arena.admit(s1, toks) == 9
    t1 = [int(b) for b in arena.tables[s1, :3]]
    assert t1[:2] == chain0[:2] and t1[2] != chain0[2]
    assert arena.pool.refcount(chain0[0]) == 3    # slot0 + tree + slot1
    assert arena.pool.refcount(chain0[2]) == 2    # tree + s0 only (CoW'd)

    arena.release(s0)
    arena.release(s1)
    with pytest.raises(ValueError, match="double release"):
        arena.release(s0)
    # tree keeps the prompt resident for future hits
    assert arena.blocks_in_use == 3
    m, _ = arena.prefix.match(toks)
    assert m == 10


def test_paged_arena_rejects_ring_and_misaligned():
    with pytest.raises(ValueError, match="multiple of block_size"):
        PagedLatentArena(None, num_slots=1, max_len=20, block_size=8)
    cfg = _cfg("gemma2-27b", pos_emb="none", qkv_bias=False,
               latent=LatentConfig(enabled=True, compression=0.3))
    with pytest.raises(ValueError, match="full-attention"):
        PagedLatentArena(cfg, num_slots=1, max_len=32, block_size=8)
    with pytest.raises(ValueError, match="absorbed"):
        Engine(_cfg("deepseek-coder-33b",
                    latent=LatentConfig(enabled=True, compression=0.3)),
               None, paged=True)                  # rope -> rejected
    with pytest.raises(ValueError, match="latent"):
        Engine(_cfg("opt-125m"), None, paged=True)


# -- property tests: refcount / eviction invariants --------------------

def _check_invariants(arena):
    """free XOR referenced; refcount == tree holders + live-slot holders;
    no live slot table ever points at a freed (evicted) block."""
    nb = arena.num_blocks
    tree_holds = collections.Counter(n.block for n in arena.prefix._walk())
    slot_holds = collections.Counter(
        int(b) for s in range(arena.num_slots) if s not in arena._free_set
        for b in arena.tables[s] if b != nb)
    for b in range(nb):
        rc = arena.pool.refcount(b)
        assert arena.pool.is_free(b) == (rc == 0)
        assert rc == tree_holds[b] + slot_holds[b], \
            (b, rc, dict(tree_holds), dict(slot_holds))


def _drive(arena, ops, vocab=3):
    """Interpret (op, payload) pairs against an accounting-only arena,
    checking invariants after every operation. A tiny vocab forces heavy
    prefix sharing; a small pool forces eviction and admit rollback."""
    rng = np.random.RandomState(1234)
    live = []
    for op, payload in ops:
        if op == 0 and arena.num_free:               # admit
            L = 1 + payload % (arena.max_len - arena.block_size)
            toks = rng.randint(0, vocab, size=L)
            slot = arena.acquire()
            base = arena.admit(slot, toks)
            if base is None:                         # rollback path
                arena.release(slot)
            else:
                assert 0 <= base <= L - 1
                arena.insert(slot, toks)
                live.append((slot, L))
        elif op == 1 and live:                       # release
            slot, _ = live.pop(payload % len(live))
            arena.release(slot)
        elif op == 2:                                # evict
            arena.prefix.evict(1 + payload % 3)
        elif op == 3 and live:                       # decode grows a row
            slot, L = live[payload % len(live)]
            try:
                arena.ensure(slot, min(L, arena.max_len - 1))
            except RuntimeError:
                pass                                 # tiny pool exhausted
        _check_invariants(arena)


def test_paged_invariants_random_walk():
    """Always-on seeded fallback for the hypothesis test below: 400 ops
    against a pool deliberately too small for the worst case, so admit
    rollback and mid-decode eviction both fire."""
    rng = np.random.RandomState(0)
    arena = PagedLatentArena(None, num_slots=3, max_len=32, block_size=4,
                             num_blocks=12)
    ops = [(int(rng.randint(4)), int(rng.randint(1 << 30)))
           for _ in range(400)]
    _drive(arena, ops)


def test_paged_invariants_hypothesis():
    hyp = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=50, deadline=None)
    @given(st.lists(st.tuples(st.integers(0, 3), st.integers(0, 1 << 30)),
                    max_size=80))
    def run(ops):
        _drive(PagedLatentArena(None, num_slots=3, max_len=32, block_size=4,
                                num_blocks=12), ops)

    run()


# -- engine acceptance: bit-identity + strictly fewer prefill tokens ---

def test_paged_engine_matches_linear_greedy():
    """Acceptance: on shared-prefix traffic the paged engine emits
    BIT-IDENTICAL greedy tokens to the linear arena while computing
    strictly fewer prefill tokens (prefix_hit_rate > 0)."""
    cfg = _latent_cfg()
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    prompts = _shared_prefix_prompts(0, 20, (3, 5, 7, 4), cfg.vocab_size)

    def traffic(eng):
        reqs = [eng.submit(p, SamplingParams(max_new_tokens=6))
                for p in prompts]
        eng.run()
        return [tuple(r.output_tokens) for r in reqs]

    lin = Engine(cfg, params, num_slots=2, max_len=48)
    pag = Engine(cfg, params, num_slots=2, max_len=48, paged=True,
                 block_size=8)
    assert traffic(pag) == traffic(lin)

    rep = pag.cache_report()
    total = sum(p.size for p in prompts)
    assert rep["prefix_hit_rate"] > 0
    assert rep["prefill_tokens_computed"] < total      # linear computes all
    assert rep["prefill_tokens_computed"] \
        + rep["prefill_tokens_saved"] == total
    assert rep["prefix_hit_requests"] >= 1
    assert 0 < rep["blocks_in_use"] <= rep["num_blocks"]
    # the second identical wave is near-fully cached (all but the last
    # prompt token, which is always recomputed to seed sampling)
    assert traffic(pag) == traffic(lin)
    assert pag.cache_report()["prefix_hit_rate"] > rep["prefix_hit_rate"]


def test_paged_engine_matches_linear_sampled():
    """Seeded sampling goes through the same gather/scatter: tokens must
    match the linear arena exactly (keys are per-request, fold index is
    the generated-token count — slot/base placement never leaks in)."""
    cfg = _latent_cfg()
    params = T.init_params(jax.random.PRNGKey(1), cfg)
    prompts = _shared_prefix_prompts(1, 12, (2, 6, 3), cfg.vocab_size)
    sp = [SamplingParams(max_new_tokens=5),
          SamplingParams(temperature=0.8, top_k=16, seed=7, max_new_tokens=5),
          SamplingParams(temperature=1.1, top_p=0.9, seed=8, max_new_tokens=5)]

    def traffic(eng):
        reqs = [eng.submit(p, s) for p, s in zip(prompts, sp)]
        eng.run()
        return [tuple(r.output_tokens) for r in reqs]

    lin = Engine(cfg, params, num_slots=2, max_len=32)
    pag = Engine(cfg, params, num_slots=2, max_len=32, paged=True,
                 block_size=8)
    assert traffic(pag) == traffic(lin)
    assert pag.cache_report()["prefix_hit_rate"] > 0


def test_paged_engine_step_is_single_fused_dispatch():
    """Acceptance (jaxpr-checked): the paged decode step traces block
    gather + model forward + per-slot sampling + one-row scatter into
    ONE jaxpr — paging never splits the fused serving step."""
    cfg = _latent_cfg()
    params = T.init_params(jax.random.PRNGKey(2), cfg)
    layout = PagedCacheLayout(32, 8, 12)
    pool = T.init_cache(cfg, 12, 8)
    pool.pop("pos")
    step = lm.make_paged_engine_step(cfg, layout)
    B = 2
    jaxpr = jax.make_jaxpr(step)(
        params, pool, jnp.zeros((B, 4), jnp.int32),
        jnp.array([9, 17], jnp.int32), jnp.zeros((B, 1), jnp.int32),
        jnp.zeros((B, 2), jnp.uint32), jnp.ones((B,), jnp.int32),
        jnp.zeros((B,), jnp.float32), jnp.zeros((B,), jnp.int32),
        jnp.ones((B,), jnp.float32), jnp.ones((B,), bool))

    def prims(jx, acc):
        for e in jx.eqns:
            acc.add(e.primitive.name)
            for v in e.params.values():
                if hasattr(v, "jaxpr"):
                    sub = v.jaxpr if hasattr(v.jaxpr, "eqns") else v
                    prims(sub, acc)
        return acc

    allp = prims(jaxpr.jaxpr, set())
    assert "scan" in allp                 # the layer stack
    assert "argmax" in allp               # token selection, same jaxpr
    assert "random_fold_in" in allp       # per-slot PRNG streams
    assert "gather" in allp               # pool -> contiguous view
    assert "scatter" in allp              # one-row writeback
    assert jaxpr.out_avals[0].dtype == jnp.int32


# -- sharded: 2x4 debug mesh (subprocess keeps the flag contained) -----

_SHARDED = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses, json
import numpy as np
import jax
from repro.configs import REGISTRY, LatentConfig, reduced
from repro.launch.mesh import make_debug_mesh
from repro.models import transformer as T
from repro.serve import Engine, SamplingParams

cfg = dataclasses.replace(reduced(REGISTRY["deepseek-coder-33b"]),
                          dtype="float32", pos_emb="none", qkv_bias=False,
                          num_kv_heads=4,
                          latent=LatentConfig(enabled=True, compression=0.3))
params = T.init_params(jax.random.PRNGKey(0), cfg)
rng = np.random.RandomState(0)
shared = rng.randint(0, 250, size=20).astype(np.int32)
prompts = [np.concatenate([shared, rng.randint(0, 250, size=k)
                           .astype(np.int32)]) for k in (3, 5, 7)]

def traffic(eng):
    reqs = [eng.submit(p, SamplingParams(max_new_tokens=5)) for p in prompts]
    eng.run()
    return [list(map(int, r.output_tokens)) for r in reqs]

mesh = make_debug_mesh(2, 4)
ref = traffic(Engine(cfg, params, num_slots=2, max_len=48))
pag = Engine(cfg, params, num_slots=2, max_len=48, mesh=mesh, paged=True,
             block_size=8)
got = traffic(pag)
rep = pag.cache_report()
print("RESULT:" + json.dumps({
    "equal": ref == got,
    "hit_rate": rep["prefix_hit_rate"],
    "blocks_in_use": rep["blocks_in_use"],
}))
"""


@pytest.mark.slow
def test_paged_engine_sharded_matches_single_device():
    """A 2x4 (data, model) mesh paged engine matches the single-device
    LINEAR engine bit-exactly on shared-prefix greedy traffic, with a
    nonzero prefix hit rate (pool sharded via serve_cache_specs)."""
    env = dict(os.environ,
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    r = subprocess.run([sys.executable, "-c", _SHARDED], env=env,
                       capture_output=True, text=True, timeout=1500)
    assert r.returncode == 0, r.stderr[-3000:]
    line = [l for l in r.stdout.splitlines() if l.startswith("RESULT:")][-1]
    out = json.loads(line[len("RESULT:"):])
    assert out["equal"]
    assert out["hit_rate"] > 0
    assert out["blocks_in_use"] > 0
