"""End-to-end behaviour tests: train a small OPT-family model, compress it
with the paper's method and every baseline, verify the paper's ordering
claims on held-out perplexity, then serve the latent model."""
import dataclasses
import math

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import REGISTRY, LatentConfig, reduced
from repro.core.compress import Compressor
from repro.data import DataConfig, TokenDataset
from repro.models import lm, transformer as T
from repro.optim import AdamW, AdamWConfig


@pytest.fixture(scope="module")
def trained_model():
    cfg = dataclasses.replace(
        reduced(REGISTRY["opt-125m"], layers=2, d_model=96),
        dtype="float32",
        latent=LatentConfig(enabled=False, compression=0.4))
    key = jax.random.PRNGKey(0)
    params = T.init_params(key, cfg)
    data = TokenDataset(DataConfig(seq_len=128, global_batch=8, seed=0,
                                   n_tokens=300_000))
    opt = AdamW(AdamWConfig(lr=3e-3, warmup_steps=10, total_steps=120))
    opt_state = opt.init(params)
    step = jax.jit(lm.make_train_step(cfg, opt, remat=False),
                   donate_argnums=(0, 1))
    for s in range(120):
        batch = jax.tree.map(jnp.asarray, data.batch_at(s))
        params, opt_state, m = step(params, opt_state, batch,
                                    jnp.asarray(s, jnp.int32))
    eval_batches = [jax.tree.map(jnp.asarray, data.batch_at(1000 + i))
                    for i in range(4)]
    return cfg, params, eval_batches, float(m["loss"])


def _ppl(cfg, params, batches):
    es = jax.jit(lm.make_eval_step(cfg))
    nll = np.mean([float(es(params, b)) for b in batches])
    return math.exp(min(nll, 20.0))


def test_training_converged(trained_model):
    cfg, params, batches, final_loss = trained_model
    assert final_loss < 3.2, final_loss
    assert _ppl(cfg, params, batches) < 25.0


def test_paper_ordering_on_trained_model(trained_model):
    """Tab. 2 claim: plain << asvd(l2) <= asvd(rootcov) <= latentllm."""
    cfg, params, batches, _ = trained_model
    lat_cfg = dataclasses.replace(
        cfg, latent=dataclasses.replace(cfg.latent, enabled=True))
    calib = batches[0]
    ppl = {}
    for method in ("plain", "asvd_l2", "asvd_rootcov", "latentllm"):
        lp, _ = Compressor(params, cfg, method=method) \
            .calibrate(calib).compress()
        ppl[method] = _ppl(lat_cfg, lp, batches)
    assert ppl["latentllm"] <= ppl["asvd_rootcov"] * 1.05
    assert ppl["asvd_rootcov"] < ppl["plain"]
    assert ppl["latentllm"] < ppl["plain"]
    assert ppl["asvd_l2"] <= ppl["plain"] * 1.02  # diag-l2 >= plain, near tie ok
    # compressed model stays usable (within 2.5x of dense ppl at 40%)
    dense = _ppl(cfg, params, batches)
    assert ppl["latentllm"] < dense * 2.5, (ppl, dense)


def test_latent_model_serves(trained_model):
    cfg, params, batches, _ = trained_model
    lat_cfg = dataclasses.replace(
        cfg, latent=dataclasses.replace(cfg.latent, enabled=True))
    # multi-batch streaming calibration through the new entry point
    lp, _ = Compressor(params, cfg, method="latentllm") \
        .calibrate(batches[:2]).compress()
    prompt = batches[0]["tokens"][:2, :16]
    gen = lm.greedy_generate(lat_cfg, lp, prompt, steps=8, max_len=32)
    assert gen.shape == (2, 8)
    assert not bool(jnp.any(gen < 0))


def test_latent_cache_smaller_than_dense(trained_model):
    cfg, params, batches, _ = trained_model
    lat_cfg = dataclasses.replace(
        cfg, latent=dataclasses.replace(cfg.latent, enabled=True))
    dense_cache = jax.eval_shape(lambda: T.init_cache(cfg, 2, 64))
    lat_cache = jax.eval_shape(lambda: T.init_cache(lat_cfg, 2, 64))

    def nbytes(t):
        return sum(np.prod(l.shape) * l.dtype.itemsize
                   for l in jax.tree.leaves(t))

    assert nbytes(lat_cache) < nbytes(dense_cache)
