"""Unified token-budget scheduler: chunked prefill interleaved with
decode (ISSUE 9).

Acceptance: an Engine built with ``prefill_chunk``/``token_budget``
splits prompt prefill into bounded carry-in chunks across steps while
resident rows keep decoding — and the emitted tokens stay BIT-IDENTICAL
to the unchunked engine (greedy and seeded sampling; linear, windowed
ring, and paged caches; single device and a 2x4 fake-device mesh run in
a subprocess). Decode remains ONE fused dispatch per step (jaxpr- and
call-count-pinned), per-step chunk spend honours the token budget, and
admission-policy violations (``max_new_tokens <= 0``, ``top_p`` outside
(0, 1]) come back REJECTED instead of poisoning a batch."""
import dataclasses
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import REGISTRY, LatentConfig, reduced
from repro.models import lm, transformer as T
from repro.serve import Engine, SamplingParams
from repro.serve.metrics import MetricsRegistry


def _cfg(name, **kw):
    cfg = dataclasses.replace(reduced(REGISTRY[name]), dtype="float32")
    cfg = dataclasses.replace(cfg, pos_emb="none", qkv_bias=False,
                              latent=LatentConfig(enabled=True,
                                                  compression=0.3))
    return dataclasses.replace(cfg, **kw) if kw else cfg


def _prompts(seed, lens, vocab):
    rng = np.random.RandomState(seed)
    return [rng.randint(0, vocab, size=L).astype(np.int32) for L in lens]


def _traffic(vocab):
    """Mixed greedy + seeded sampled traffic with prompts both shorter
    and longer than the chunk size (23 and 30 need 4+ chunks at 7)."""
    prompts = _prompts(0, (23, 9, 17, 30, 5), vocab)
    sps = [SamplingParams(max_new_tokens=6),
           SamplingParams(max_new_tokens=5, temperature=0.9, top_k=7,
                          seed=3),
           SamplingParams(max_new_tokens=6, temperature=0.7, top_p=0.9,
                          seed=11),
           SamplingParams(max_new_tokens=4),
           SamplingParams(max_new_tokens=6, temperature=1.1, seed=5)]
    return prompts, sps


def _run(cfg, params, paged=False, **kw):
    eng = Engine(cfg, params, num_slots=3, max_len=48, paged=paged, **kw)
    prompts, sps = _traffic(cfg.vocab_size)
    reqs = [eng.submit(p, sp) for p, sp in zip(prompts, sps)]
    eng.run()
    assert all(r.finished and r.finish_reason == "length" for r in reqs), \
        [(r.finish_reason, r.error) for r in reqs]
    return [list(r.output_tokens) for r in reqs], eng


@pytest.mark.parametrize("name,paged", [
    ("deepseek-coder-33b", False),   # linear latent cache
    ("gemma2-27b", False),           # windowed ring + global alternation
    ("deepseek-coder-33b", True),    # paged pool + radix prefix reuse
])
def test_chunked_tokens_bit_identical(name, paged):
    """Acceptance: chunked == unchunked token-for-token, greedy AND
    seeded, with chunk size 7 against prompts up to 30 tokens (the ring
    case wraps: window 16 < prompt 30) under a 3-slot arena that forces
    decode/prefill interleaving."""
    cfg = _cfg(name)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    plain, _ = _run(cfg, params, paged=paged)
    chunked, eng = _run(cfg, params, paged=paged,
                        prefill_chunk=7, token_budget=16)
    assert chunked == plain
    assert eng.counters["prefill_chunks"] > len(plain), \
        "multi-chunk prompts must take several dispatches"


def test_chunk_budget_and_cap_honored():
    """Per-step chunk spend never exceeds ``token_budget`` minus the
    resident decode spend, and no single row advances more than
    ``prefill_chunk`` tokens per step (shares start at 1.0 and only
    shrink, so the configured values are hard ceilings)."""
    cfg = _cfg("deepseek-coder-33b")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    budget, chunk = 12, 5
    eng = Engine(cfg, params, num_slots=3, max_len=48,
                 prefill_chunk=chunk, token_budget=budget)
    prompts, sps = _traffic(cfg.vocab_size)
    reqs = [eng.submit(p, sp) for p, sp in zip(prompts, sps)]
    spent0 = 0
    while True:
        decode_rows = int(eng._active.sum())
        pos0 = {r.request_id: r.prefill_pos for r in reqs}
        more = eng.step()
        spent1 = int(eng.counters["prefill_chunk_tokens"])
        assert spent1 - spent0 <= max(0, budget - decode_rows)
        for r in reqs:
            assert r.prefill_pos - pos0[r.request_id] <= chunk
        spent0 = spent1
        if not more:
            break
    assert all(r.finished for r in reqs)
    rep = eng.scheduler_report()
    assert rep["chunked"] and rep["prefill_chunks"] > 0
    assert rep["prefill_chunk_tokens"] == sum(p.size for p in prompts)
    assert rep["prefill_backlog_tokens"] == 0 and rep["prefilling"] == 0


def test_decode_stays_single_fused_dispatch():
    """Jaxpr + call-count pin: chunking changes ADMISSION only — the
    decode head is the same ONE fused scan dispatch per step (never two
    decode dispatches because chunks rode along)."""
    cfg = _cfg("deepseek-coder-33b")
    params = T.init_params(jax.random.PRNGKey(1), cfg)
    B = 3
    cache = T.init_cache(cfg, B, 32)
    cache["pos"] = jnp.array([3, 18, 5], jnp.int32)
    step = lm.make_engine_step(cfg)
    jaxpr = jax.make_jaxpr(step)(
        params, cache, jnp.zeros((B, 1), jnp.int32),
        jnp.zeros((B, 2), jnp.uint32), jnp.ones((B,), jnp.int32),
        jnp.zeros((B,), jnp.float32), jnp.zeros((B,), jnp.int32),
        jnp.ones((B,), jnp.float32), jnp.ones((B,), bool))
    top = {e.primitive.name for e in jaxpr.jaxpr.eqns}
    assert "scan" in top and "argmax" in top   # one fused dispatch

    eng = Engine(cfg, params, num_slots=3, max_len=48,
                 prefill_chunk=4, token_budget=8)
    calls = {"n": 0}
    real = eng._dispatch

    def counting(fn, poison):
        calls["n"] += 1
        return real(fn, poison)

    eng._dispatch = counting
    prompts, sps = _traffic(cfg.vocab_size)
    reqs = [eng.submit(p, sp) for p, sp in zip(prompts, sps)]
    steps = 0
    while eng.step():
        steps += 1
        assert calls["n"] <= steps, "more than one decode dispatch a step"
    assert all(r.finished for r in reqs)


def test_chunked_requires_absorbed_latent():
    """The carry-in chunk head rides the absorbed latent path; a config
    off that path must fail at construction, not mid-step."""
    dense = dataclasses.replace(
        reduced(REGISTRY["deepseek-coder-33b"]), dtype="float32")
    params = T.init_params(jax.random.PRNGKey(0), dense)
    with pytest.raises(ValueError, match="absorbed"):
        Engine(dense, params, num_slots=2, max_len=32, prefill_chunk=4)
    for bad in (dict(token_budget=0), dict(prefill_chunk=0)):
        with pytest.raises(ValueError):
            Engine(_cfg("deepseek-coder-33b"), params, num_slots=2,
                   max_len=32, **bad)


def test_admission_rejects_degenerate_sampling():
    """Satellite: ``max_new_tokens <= 0`` and ``top_p`` outside (0, 1]
    are REJECTED at admission with the reason in ``.error`` (the server
    maps these to HTTP 400) — never dispatched."""
    cfg = _cfg("deepseek-coder-33b")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    eng = Engine(cfg, params, num_slots=2, max_len=32)
    prompt = np.arange(4, dtype=np.int32)
    # SamplingParams validates at construction; the engine check is
    # defense in depth against params smuggled past it (deserialized
    # requests, future front-ends) — so smuggle them the same way
    for field, bad, frag in [("max_new_tokens", 0, "max_new_tokens"),
                             ("max_new_tokens", -3, "max_new_tokens"),
                             ("top_p", 0.0, "top_p"),
                             ("top_p", -0.5, "top_p"),
                             ("top_p", 1.5, "top_p")]:
        sp = SamplingParams()
        object.__setattr__(sp, field, bad)   # frozen dataclass
        r = eng.submit(prompt, sp)
        assert r.finished and r.finish_reason == "rejected"
        assert frag in r.error
    assert not eng.has_work()
    ok = eng.submit(prompt, SamplingParams(max_new_tokens=2))
    eng.run()
    assert ok.finish_reason == "length"


def test_scheduler_gauges_and_queue_wait_metrics():
    """Satellite: the registry carries ``prefill_backlog_tokens`` and
    ``decode_batch_occupancy`` gauges plus a ``queue_wait_s`` histogram,
    in both the JSON snapshot and the Prometheus exposition."""
    cfg = _cfg("deepseek-coder-33b")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    metrics = MetricsRegistry()
    eng = Engine(cfg, params, num_slots=2, max_len=48, metrics=metrics,
                 prefill_chunk=6, token_budget=10)
    prompts, sps = _traffic(cfg.vocab_size)
    reqs = [eng.submit(p, sp) for p, sp in zip(prompts, sps)]
    saw_backlog = saw_occupancy = 0.0
    while eng.step():
        g = metrics.snapshot()["gauges"]
        saw_backlog = max(saw_backlog, g["prefill_backlog_tokens"])
        saw_occupancy = max(saw_occupancy, g["decode_batch_occupancy"])
    assert all(r.finished for r in reqs)
    assert saw_backlog > 0 and 0 < saw_occupancy <= 1.0
    snap = metrics.snapshot()
    assert snap["gauges"]["prefill_backlog_tokens"] == 0.0
    assert snap["histograms"]["queue_wait_s"]["count"] == len(reqs)
    prom = metrics.to_prometheus()
    for name in ("serve_prefill_backlog_tokens",
                 "serve_decode_batch_occupancy",
                 "serve_queue_wait_s"):
        assert name in prom


def test_ttft_risk_rows_win_chunk_budget():
    """SLO-aware shaping, the ordering half: a request past half its
    TTFT deadline takes the whole (tiny) chunk budget ahead of an
    older, higher-id-agnostic peer — and the boost counter ticks."""
    cfg = _cfg("deepseek-coder-33b")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    clock = {"t": 0.0}
    eng = Engine(cfg, params, num_slots=2, max_len=48,
                 prefill_chunk=4, token_budget=4)
    eng._now = lambda: clock["t"]   # the ONE injectable engine clock
    prompts = _prompts(1, (20, 20), cfg.vocab_size)
    calm = eng.submit(prompts[0], SamplingParams(max_new_tokens=2))
    rush = eng.submit(prompts[1], SamplingParams(max_new_tokens=2),
                      ttft_deadline_s=10.0)
    clock["t"] = 6.0          # rush is past half its TTFT deadline
    eng.step()                # both admitted; budget 4 -> ONE row chunks
    assert rush.prefill_pos > 0, "at-risk row must win the budget"
    assert calm.prefill_pos == 0
    assert eng.counters["ttft_risk_boosts"] > 0
    eng.run()
    assert calm.finished and rush.finished


def test_slo_backoff_shrinks_prefill_share():
    """SLO-aware shaping, the feedback half: when chunk-carrying steps
    run slower than ``slo_drift_factor``x the chunk-free decode
    baseline (forced here via the injectable clock), the prefill share
    halves toward its 1/8 floor and the backoff counter ticks."""
    cfg = _cfg("deepseek-coder-33b")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    clock = {"t": 0.0, "dt": 0.01}

    def now():
        clock["t"] += clock["dt"]
        return clock["t"]

    eng = Engine(cfg, params, num_slots=2, max_len=64,
                 prefill_chunk=4, token_budget=8, slo_drift_factor=2.0)
    eng._now = now
    # resident decode first: builds the chunk-free EMA baseline
    short = eng.submit(_prompts(2, (4,), cfg.vocab_size)[0],
                       SamplingParams(max_new_tokens=30))
    for _ in range(6):
        eng.step()
    assert eng._decode_ema is not None and eng._prefill_share == 1.0
    clock["dt"] = 10.0        # every later step now "takes" ~30 s
    long = eng.submit(_prompts(3, (40,), cfg.vocab_size)[0],
                      SamplingParams(max_new_tokens=2))
    shares = []
    while eng.step():
        shares.append(eng._prefill_share)
    assert short.finished and long.finished
    assert eng.counters["slo_backoffs"] > 0
    assert min(shares) < 1.0 and min(shares) >= 0.125


def test_mid_prefill_cancel_and_drain():
    """Lifecycle under chunking: cancelling a request whose prefill is
    mid-flight frees its slot the same step, and the engine drains."""
    cfg = _cfg("deepseek-coder-33b")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    eng = Engine(cfg, params, num_slots=2, max_len=48,
                 prefill_chunk=4, token_budget=4)
    long = eng.submit(_prompts(4, (30,), cfg.vocab_size)[0],
                      SamplingParams(max_new_tokens=3))
    eng.step()
    assert 0 < long.prefill_pos < 30     # mid-prefill resident
    assert eng.lifecycle_report()["prefilling"] == 1
    eng.cancel(long)
    assert long.finish_reason == "cancelled"
    assert eng.lifecycle_report()["prefilling"] == 0
    assert eng.arena.num_free == 2
    ok = eng.submit(_prompts(5, (6,), cfg.vocab_size)[0],
                    SamplingParams(max_new_tokens=2))
    eng.run()
    assert ok.finish_reason == "length"


_SHARDED_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses
import json
import numpy as np
import jax
from repro.configs import REGISTRY, LatentConfig, reduced
from repro.launch.mesh import make_debug_mesh
from repro.models import transformer as T
from repro.serve import Engine, SamplingParams

def _cfg(name, **kw):
    cfg = dataclasses.replace(reduced(REGISTRY[name]), dtype="float32")
    return dataclasses.replace(cfg, **kw) if kw else cfg

out = {}
mesh = make_debug_mesh(2, 4)
rng = np.random.RandomState(0)
prompts = [rng.randint(0, 250, size=L).astype(np.int32)
           for L in (23, 9, 30, 5)]
sps = [SamplingParams(max_new_tokens=5),
       SamplingParams(max_new_tokens=4, temperature=0.9, top_k=7, seed=3),
       SamplingParams(max_new_tokens=5),
       SamplingParams(max_new_tokens=4, temperature=1.1, seed=5)]

# num_kv_heads=4 divides the model axis -> sharded latent arena
cfg = _cfg("deepseek-coder-33b", pos_emb="none", qkv_bias=False,
           num_kv_heads=4,
           latent=LatentConfig(enabled=True, compression=0.3))
params = T.init_params(jax.random.PRNGKey(0), cfg)

def run_engine(m, **kw):
    eng = Engine(cfg, params, num_slots=3, max_len=48, mesh=m, **kw)
    reqs = [eng.submit(p, sp) for p, sp in zip(prompts, sps)]
    eng.run()
    assert all(r.finished and r.finish_reason == "length" for r in reqs), \
        [(r.finish_reason, r.error) for r in reqs]
    return [list(map(int, r.output_tokens)) for r in reqs]

plain = run_engine(None)
out["chunked_equals_plain_1dev"] = \
    run_engine(None, prefill_chunk=7, token_budget=12) == plain
out["chunked_mesh_equals_plain"] = \
    run_engine(mesh, prefill_chunk=7, token_budget=12) == plain
print("RESULT:" + json.dumps(out))
"""


@pytest.fixture(scope="module")
def sharded_chunked_out():
    env = dict(os.environ,
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    r = subprocess.run([sys.executable, "-c", _SHARDED_SCRIPT], env=env,
                       capture_output=True, text=True, timeout=1500)
    assert r.returncode == 0, r.stderr[-3000:]
    line = [l for l in r.stdout.splitlines()
            if l.startswith("RESULT:")][-1]
    return json.loads(line[len("RESULT:"):])


@pytest.mark.slow
def test_sharded_chunked_bit_identical(sharded_chunked_out):
    """Acceptance: under a 2x4 mesh the chunked scheduler (ONE jitted
    carry head with fixed arena shardings) streams the same tokens as
    the unchunked single-device engine, greedy AND seeded."""
    assert sharded_chunked_out["chunked_equals_plain_1dev"]
    assert sharded_chunked_out["chunked_mesh_equals_plain"]
