"""Elastic manager: failure detection, stragglers, feasible re-mesh."""
from repro.distributed.elastic import ElasticConfig, ElasticManager


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


def _mgr(n=8, **kw):
    clock = FakeClock()
    m = ElasticManager([f"node{i}" for i in range(n)],
                       ElasticConfig(**kw), clock=clock)
    return m, clock


def test_failure_detection_and_eviction():
    m, clock = _mgr(4, heartbeat_timeout_s=10)
    clock.t = 5.0
    for n in ("node0", "node1", "node2"):
        m.heartbeat(n)
    clock.t = 20.0
    for n in ("node0", "node1", "node2"):
        m.heartbeat(n)
    assert m.failed_nodes() == ["node3"]
    actions = m.tick()
    assert actions["failed"] == ["node3"] and actions.get("remesh")
    assert m.healthy_count() == 3
    gen = m.generation
    # idempotent: already-evicted nodes don't bump the generation again
    m.tick()
    assert m.generation == gen


def test_straggler_detection_needs_persistence():
    m, clock = _mgr(4, straggler_factor=2.0)
    for step in range(4):
        clock.t += 1
        for i in range(4):
            t = 10.0 if i == 3 else 1.0   # node3 is 10x slower
            m.heartbeat(f"node{i}", step_time=t)
    assert m.stragglers() == ["node3"]
    actions = m.tick()
    assert "node3" not in [n for n, st in m.nodes.items() if st.healthy] \
        or actions["stragglers"] == ["node3"]


def test_feasible_mesh_shrinks_with_survivors():
    m, clock = _mgr(8)
    assert m.feasible_mesh(chips_per_node=32, model_parallel=16) == (16, 16)
    m.evict(["node6", "node7"])   # 6 nodes -> 192 chips
    assert m.feasible_mesh(32, 16) == (8, 16)
    m.evict([f"node{i}" for i in range(6)])
    assert m.feasible_mesh(32, 16) is None


def test_join_bumps_generation():
    m, _ = _mgr(2)
    g = m.generation
    m.join("node_new")
    assert m.generation == g + 1 and m.healthy_count() == 3
