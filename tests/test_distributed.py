"""Distribution tests on 8 fake CPU devices.

Run in a SUBPROCESS so the 8-device XLA flag never leaks into the other
tests (smoke tests must see 1 device)."""
import json
import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import dataclasses
import numpy as np
import jax, jax.numpy as jnp
from repro.configs import REGISTRY, reduced, LatentConfig
from repro.distributed import sharding as shd
from repro.launch.mesh import make_debug_mesh
from repro.models import lm, transformer as T
from repro.optim import AdamW, AdamWConfig
from repro.checkpoint import CheckpointManager

out = {}
mesh = make_debug_mesh(2, 4)
cfg = dataclasses.replace(reduced(REGISTRY["deepseek-coder-33b"]),
                          dtype="float32")
key = jax.random.PRNGKey(0)
params = T.init_params(key, cfg)
opt = AdamW(AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=4))
opt_state = opt.init(params)
toks = jax.random.randint(key, (8, 32), 0, cfg.vocab_size)
batch = {"tokens": toks, "labels": toks}

pspecs = shd.param_specs(jax.eval_shape(lambda: params), mesh)
pshard = shd.to_named(mesh, pspecs)
bspecs = shd.batch_specs(mesh, jax.eval_shape(lambda: batch))
bshard = shd.to_named(mesh, bspecs)

step_fn = lm.make_train_step(cfg, opt, remat=True)
with mesh:
    jf = jax.jit(step_fn, in_shardings=(pshard, None, bshard, None),
                 out_shardings=(pshard, None, None))
    params_s = jax.device_put(params, pshard)
    p1, o1, m1 = jf(params_s, opt_state, batch, jnp.zeros((), jnp.int32))
    loss_sharded = float(m1["loss"])

# single-device reference
p1r, o1r, m1r = step_fn(params, opt_state, batch, jnp.zeros((), jnp.int32))
out["loss_sharded"] = loss_sharded
out["loss_ref"] = float(m1r["loss"])
out["param_allclose"] = bool(all(
    np.allclose(np.asarray(a), np.asarray(b), atol=5e-4)
    for a, b in zip(jax.tree.leaves(jax.device_get(p1)),
                    jax.tree.leaves(p1r))))

# checkpoint under mesh A, restore under mesh B (elastic re-mesh)
ck = CheckpointManager("/tmp/ck_elastic_test", keep=1)
ck.save(0, jax.device_get(p1), {"step": 0})
mesh_b = make_debug_mesh(4, 2)
pspecs_b = shd.param_specs(jax.eval_shape(lambda: params), mesh_b)
pshard_b = shd.to_named(mesh_b, pspecs_b)
restored, _ = ck.restore(params, shardings=pshard_b)
out["remesh_ok"] = bool(all(
    np.allclose(np.asarray(a), np.asarray(b), atol=0)
    for a, b in zip(jax.tree.leaves(jax.device_get(restored)),
                    jax.tree.leaves(jax.device_get(p1)))))
print("RESULT:" + json.dumps(out))
"""


@pytest.mark.slow
def test_sharded_train_step_matches_single_device():
    env = dict(os.environ,
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..", "src"))
    r = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                       capture_output=True, text=True, timeout=900)
    assert r.returncode == 0, r.stderr[-3000:]
    line = [l for l in r.stdout.splitlines() if l.startswith("RESULT:")][-1]
    out = json.loads(line[len("RESULT:"):])
    assert abs(out["loss_sharded"] - out["loss_ref"]) < 5e-3
    assert out["param_allclose"]
    assert out["remesh_ok"]
