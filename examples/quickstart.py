"""Quickstart: the paper's pipeline in ~60 lines.

1. build a small OPT-architecture model (the paper's testbed family),
2. train it briefly on byte-level text,
3. compress it with LatentLLM (attention-aware joint tensor compression),
4. compare held-out perplexity against the ASVD baselines,
5. generate from the latent model (compressed KV cache).

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import REGISTRY, LatentConfig, reduced
from repro.core.compress import CompressionPlan, Compressor
from repro.data import DataConfig, TokenDataset, tokenizer
from repro.models import lm, transformer as T
from repro.optim import AdamW, AdamWConfig


def main():
    cfg = dataclasses.replace(
        reduced(REGISTRY["opt-125m"], layers=2, d_model=96),
        dtype="float32", latent=LatentConfig(enabled=False, compression=0.3))
    key = jax.random.PRNGKey(0)
    params = T.init_params(key, cfg)

    data = TokenDataset(DataConfig(seq_len=128, global_batch=8))
    opt = AdamW(AdamWConfig(lr=3e-3, warmup_steps=10, total_steps=150))
    opt_state = opt.init(params)
    step = jax.jit(lm.make_train_step(cfg, opt, remat=False),
                   donate_argnums=(0, 1))
    print("training a small OPT-family byte-LM ...")
    for s in range(150):
        batch = jax.tree.map(jnp.asarray, data.batch_at(s))
        params, opt_state, m = step(params, opt_state, batch,
                                    jnp.asarray(s, jnp.int32))
        if s % 50 == 0:
            print(f"  step {s:4d} loss {float(m['loss']):.3f}")

    evals = [jax.tree.map(jnp.asarray, data.batch_at(9000 + i))
             for i in range(4)]
    es = jax.jit(lm.make_eval_step(cfg))

    def ppl(c, p):
        return math.exp(np.mean([float(jax.jit(lm.make_eval_step(c))(p, b))
                                 for b in evals]))

    print(f"dense ppl: {ppl(cfg, params):.2f}")
    # streaming calibration: stats accumulate across several small batches
    calib = [jax.tree.map(jnp.asarray, data.batch_at(555 + i))
             for i in range(3)]
    lat_cfg = dataclasses.replace(
        cfg, latent=dataclasses.replace(cfg.latent, enabled=True))
    for method in ("plain", "asvd_rootcov", "latentllm"):
        lp, _ = Compressor(params, cfg, method=method) \
            .calibrate(calib).compress()
        print(f"{method:14s} ppl at 30% size reduction: "
              f"{ppl(lat_cfg, lp):.2f}")

    plan = CompressionPlan.from_config(cfg, method="latentllm")
    lp, report = Compressor(params, cfg, plan=plan).calibrate(calib).compress()
    print(plan.summary(cfg, report))
    prompt = jnp.asarray(tokenizer.encode("the latent model says "))[None]
    gen = lm.greedy_generate(lat_cfg, lp, prompt, steps=40,
                             max_len=prompt.shape[1] + 48)
    print("latent generation:", repr(tokenizer.decode(np.asarray(gen[0]))))


if __name__ == "__main__":
    main()
