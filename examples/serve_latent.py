"""Serve a LatentLLM-compressed model under mixed-length request traffic.

Shows the inference payoff behind the Engine API: latent KV arena slots
(c_k/c_v of rank r_k/r_v per token) vs dense slots, with continuous
batching over ragged prompts and per-request sampling params — including
sliding-window models (gemma2-style), whose windowed layers serve from
ring arena slots of the WINDOW length and keep the absorbed ring-kernel
decode path.

Run:  PYTHONPATH=src python examples/serve_latent.py
"""
import dataclasses

import jax
import numpy as np

from repro.configs import REGISTRY, LatentConfig, reduced
from repro.launch import serve
from repro.models import transformer as T
from repro.serve import Engine, SamplingParams


def cli_traffic():
    """The thin CLI: mixed-length synthetic traffic, dense vs latent."""
    common = ["--arch", "opt-125m", "--reduced", "--batch", "6",
              "--prompt-len", "32", "--gen-len", "12", "--num-slots", "3"]
    print("== dense model ==")
    serve.main(common)
    print("\n== latent model (30% size reduction) ==")
    serve.main(common + ["--latent", "0.3"])


def engine_api():
    """The Engine API directly: per-request sampling over ragged prompts."""
    print("\n== Engine API: mixed sampling params in one decode batch ==")
    cfg = dataclasses.replace(reduced(REGISTRY["opt-125m"]), dtype="float32")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.RandomState(0)
    eng = Engine(cfg, params, num_slots=2, max_len=48)
    reqs = [
        eng.submit(rng.randint(0, 256, size=5), SamplingParams(
            max_new_tokens=8)),                               # greedy
        eng.submit(rng.randint(0, 256, size=17), SamplingParams(
            temperature=0.8, top_k=40, seed=1, max_new_tokens=8)),
        eng.submit(rng.randint(0, 256, size=11), SamplingParams(
            temperature=1.2, top_p=0.9, seed=2, max_new_tokens=8)),
    ]
    eng.run()
    for r in reqs:
        print(f"  req {r.request_id}: prompt={r.prompt.size} "
              f"T={r.sampling.temperature} -> {r.output_tokens} "
              f"({r.finish_reason})")
    print(f"  {eng.last_stats['tok_per_s']:.1f} tok/s, "
          f"{eng.last_stats['steps']} fused steps")


def windowed_traffic():
    """Sliding-window serving: a gemma2-style config (local/global layer
    alternation, softcaps) with prompts LONGER than the window — the
    ring arena slots wrap, decode runs the (start, length) ring kernels,
    and the cache line shows ring slots sized to the window."""
    print("\n== sliding-window model (gemma2, ring latent cache) ==")
    serve.main(["--arch", "gemma2-27b", "--reduced", "--batch", "6",
                "--prompt-len", "24", "--gen-len", "12", "--num-slots", "3",
                "--latent", "0.3"])

    print("\n== Engine API: windowed absorbed ring-kernel decode ==")
    cfg = dataclasses.replace(reduced(REGISTRY["gemma2-27b"]),
                              dtype="float32", pos_emb="none",
                              qkv_bias=False,
                              latent=LatentConfig(enabled=True,
                                                  compression=0.3))
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.RandomState(0)
    eng = Engine(cfg, params, num_slots=2, max_len=48)
    reqs = [eng.submit(rng.randint(0, 256, size=n),
                       SamplingParams(max_new_tokens=8))
            for n in (5, 21, 9)]      # 21 > window: wraps the ring
    eng.run()
    rings = [l.cache_len for l in eng.arena.layouts[0]
             if l is not None and l.is_ring]
    print(f"  ring slot lengths: {rings} (window="
          f"{cfg.sliding_window}, max_len=48)")
    for r in reqs:
        print(f"  req {r.request_id}: prompt={r.prompt.size} -> "
              f"{r.output_tokens} ({r.finish_reason})")


def paged_prefix_reuse():
    """Paged latent cache + radix prefix reuse: requests sharing a
    few-shot-template-style prefix prefill only their uncached suffix.
    Greedy tokens stay bit-identical to the linear arena; the hit rate
    climbs as the radix tree fills."""
    print("\n== paged Engine: shared-prefix block reuse ==")
    cfg = dataclasses.replace(reduced(REGISTRY["deepseek-coder-33b"]),
                              dtype="float32", pos_emb="none",
                              qkv_bias=False,
                              latent=LatentConfig(enabled=True,
                                                  compression=0.3))
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.RandomState(0)
    template = rng.randint(0, 256, size=20).astype(np.int32)  # shared prefix
    prompts = [np.concatenate([template,
                               rng.randint(0, 256, size=n).astype(np.int32)])
               for n in (3, 5, 7, 4)]
    eng = Engine(cfg, params, num_slots=2, max_len=48, paged=True,
                 block_size=8)
    reqs = [eng.submit(p, SamplingParams(max_new_tokens=6)) for p in prompts]
    eng.run()
    rep = eng.cache_report()
    for r in reqs:
        print(f"  req {r.request_id}: prompt={r.prompt.size} -> "
              f"{r.output_tokens} ({r.finish_reason})")
    print(f"  prefix_hit_rate={rep['prefix_hit_rate']:.2%} "
          f"({rep['prefill_tokens_saved']} of "
          f"{rep['prefill_tokens_saved'] + rep['prefill_tokens_computed']} "
          f"prompt toks served from cache), "
          f"blocks={rep['blocks_in_use']}/{rep['num_blocks']}")

    # the CLI flag drives the same path end to end
    print("\n== serve CLI: --paged ==")
    serve.main(["--arch", "deepseek-coder-33b", "--reduced", "--latent",
                "0.3", "--batch", "6", "--prompt-len", "24", "--gen-len",
                "8", "--num-slots", "2", "--paged", "--block-size", "8"])


def main():
    cli_traffic()
    windowed_traffic()
    engine_api()
    paged_prefix_reuse()


if __name__ == "__main__":
    main()
