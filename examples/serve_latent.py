"""Serve a LatentLLM-compressed model with batched requests.

Shows the inference payoff: latent KV cache (c_k/c_v of rank r_k/r_v per
token) vs the dense cache, and the absorbed-MLA decode path.

Run:  PYTHONPATH=src python examples/serve_latent.py
"""
from repro.launch import serve


def main():
    print("== dense model ==")
    serve.main(["--arch", "opt-125m", "--reduced", "--batch", "4",
                "--prompt-len", "32", "--gen-len", "16"])
    print("\n== latent model (30% size reduction) ==")
    serve.main(["--arch", "opt-125m", "--reduced", "--latent", "0.3",
                "--batch", "4", "--prompt-len", "32", "--gen-len", "16"])


if __name__ == "__main__":
    main()
