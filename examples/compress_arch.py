"""Compress ANY assigned architecture (reduced config) with any registered
method and inspect the rank allocation, parameter savings, and logit
fidelity — ablations need no source edits.

Run:  PYTHONPATH=src python examples/compress_arch.py --arch gemma2-27b
      PYTHONPATH=src python examples/compress_arch.py \\
          --arch zamba2-7b --method asvd_rootcov --compression 0.4 --spare-ends
"""
import argparse
import dataclasses

import jax
import jax.numpy as jnp

from repro.configs import ASSIGNED, REGISTRY, LatentConfig, reduced
from repro.core.compress import CompressionPlan, Compressor, available_methods
from repro.core.ranks import latent_ranks
from repro.models import transformer as T


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deepseek-coder-33b", choices=ASSIGNED)
    ap.add_argument("--method", default="latentllm",
                    choices=available_methods())
    ap.add_argument("--compression", type=float, default=0.3)
    ap.add_argument("--spare-ends", action="store_true",
                    help="non-uniform schedule: compress first/last block at "
                         "the base ratio, the middle 1.5x harder")
    args = ap.parse_args()

    cfg = dataclasses.replace(
        reduced(REGISTRY[args.arch]), dtype="float32",
        latent=LatentConfig(enabled=False, compression=args.compression,
                            method=args.method))
    full = dataclasses.replace(
        REGISTRY[args.arch],
        latent=LatentConfig(enabled=True, compression=args.compression))
    print(f"arch={args.arch}  method={args.method}  "
          f"target size reduction={args.compression:.0%}")
    print("full-config latent ranks:", latent_ranks(full))

    if args.spare_ends:
        plan = CompressionPlan.spare_ends(method=args.method,
                                          compression=args.compression)
    else:
        plan = CompressionPlan.from_config(cfg)

    key = jax.random.PRNGKey(0)
    params = T.init_params(key, cfg)
    toks = jax.random.randint(key, (4, 64), 0, cfg.vocab_size)
    batch = {"tokens": toks}
    if cfg.input_mode == "embeddings":
        batch = {"frames": jax.random.normal(key, (4, 64, cfg.d_model),
                                             jnp.float32)}
    logits_ref, _, _ = T.forward(params, cfg, **batch)

    lp, rep = Compressor(params, cfg, plan=plan).calibrate(batch).compress()
    lat_cfg = dataclasses.replace(
        cfg, latent=dataclasses.replace(cfg.latent, enabled=True))
    logits_lat, _, _ = T.forward(lp, lat_cfg, **batch)
    mse = float(jnp.mean((logits_lat - logits_ref) ** 2))
    var = float(jnp.var(logits_ref))
    n_dense = sum(x.size for x in jax.tree.leaves(params))
    n_lat = sum(x.size for x in jax.tree.leaves(lp))
    print(plan.summary(cfg, rep))
    print(f"compressed {rep['blocks']} blocks; "
          f"params {n_dense:,} -> {n_lat:,} "
          f"(stored dense-functional; block-identity accounting in "
          f"benchmarks/table3)")
    print(f"logit MSE/var: {mse / var:.4f}")


if __name__ == "__main__":
    main()
