"""Compress ANY assigned architecture (reduced config) with LatentLLM and
inspect the rank allocation, parameter savings, and logit fidelity.

Run:  PYTHONPATH=src python examples/compress_arch.py --arch gemma2-27b
"""
import argparse
import dataclasses

import jax
import jax.numpy as jnp

from repro.configs import ASSIGNED, REGISTRY, LatentConfig, reduced
from repro.core.compress import compress_model
from repro.core.ranks import latent_ranks
from repro.models import transformer as T


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deepseek-coder-33b", choices=ASSIGNED)
    ap.add_argument("--compression", type=float, default=0.3)
    args = ap.parse_args()

    cfg = dataclasses.replace(
        reduced(REGISTRY[args.arch]), dtype="float32",
        latent=LatentConfig(enabled=False, compression=args.compression))
    full = dataclasses.replace(
        REGISTRY[args.arch],
        latent=LatentConfig(enabled=True, compression=args.compression))
    print(f"arch={args.arch}  target size reduction={args.compression:.0%}")
    print("full-config latent ranks:", latent_ranks(full))

    key = jax.random.PRNGKey(0)
    params = T.init_params(key, cfg)
    toks = jax.random.randint(key, (4, 64), 0, cfg.vocab_size)
    batch = {"tokens": toks}
    if cfg.input_mode == "embeddings":
        batch = {"frames": jax.random.normal(key, (4, 64, cfg.d_model),
                                             jnp.float32)}
    logits_ref, _, _ = T.forward(params, cfg, **batch)

    lp, rep = compress_model(params, cfg, batch, method="latentllm")
    lat_cfg = dataclasses.replace(
        cfg, latent=dataclasses.replace(cfg.latent, enabled=True))
    logits_lat, _, _ = T.forward(lp, lat_cfg, **batch)
    mse = float(jnp.mean((logits_lat - logits_ref) ** 2))
    var = float(jnp.var(logits_ref))
    n_dense = sum(x.size for x in jax.tree.leaves(params))
    n_lat = sum(x.size for x in jax.tree.leaves(lp))
    print(f"compressed {rep['blocks']} blocks; "
          f"params {n_dense:,} -> {n_lat:,} "
          f"(stored dense-functional; block-identity accounting in "
          f"benchmarks/table3)")
    print(f"logit MSE/var: {mse / var:.4f}")


if __name__ == "__main__":
    main()
