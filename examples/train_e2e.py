"""End-to-end training driver: ~100M-parameter OPT-family model for a few
hundred steps through the production code path (sharded train_step,
checkpointing, deterministic data, cosine schedule).

On this CPU container the same driver runs a reduced model by default;
pass --full to train the true opt-125m config (~125M params — slow on
CPU, the flag exists for real hardware).

Run:  PYTHONPATH=src python examples/train_e2e.py [--steps 300] [--full]
"""
import argparse
import sys

from repro.launch import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--full", action="store_true")
    args, rest = ap.parse_known_args()
    argv = ["--arch", "opt-125m", "--steps", str(args.steps),
            "--batch", "8", "--seq-len", "256",
            "--ckpt-dir", "/tmp/repro_e2e_ckpt", "--ckpt-every", "100"]
    if not args.full:
        argv.append("--reduced")
    params, losses = train.main(argv + rest)
    assert losses[-1] < losses[0], "training must reduce the loss"
    print(f"e2e OK: loss {losses[0]:.3f} -> {losses[-1]:.3f}; "
          f"checkpoint in /tmp/repro_e2e_ckpt")


if __name__ == "__main__":
    main()
