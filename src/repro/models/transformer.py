"""Model assembly: group-structured scan-over-layers decoder LM.

Every architecture is expressed as a repeated *group* of block descriptors:
  dense            -> [attn+mlp] × L
  gemma2           -> [attn(local)+mlp, attn(global)+mlp] × L/2
  llama4-maverick  -> [attn+mlp, attn+moe] × L/2
  phi3.5-moe       -> [attn+moe] × L
  mamba2           -> [ssd] × L
  zamba2           -> ([ssd]×6 + shared-attn) × 13 (+ 3 trailing ssd)

Stacked group params scan with ``lax.scan``; the compiled HLO contains ONE
group body regardless of depth (compile-time and remat friendly).
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig, dtype_of
from repro.core.ranks import latent_ranks
from repro.distributed.constraints import constrain, constrain_bsd, constrain_bsf
from repro.models import layers as L
from repro.models.cache_layout import CacheLayout

Params = Dict[str, Any]

# The old `BIG_WINDOW = 1 << 30` "no window" sentinel is gone: sentinel
# windows turn `pos - window` into an int32 overflow trap near large
# positions. Window-ness is now carried explicitly by CacheLayout
# (models/cache_layout.py), whose arithmetic is overflow-safe.


@dataclasses.dataclass(frozen=True)
class BlockDesc:
    kind: str  # attn | ssd | shared_attn
    window: Optional[int] = None
    moe: bool = False


def group_spec(cfg: ModelConfig) -> Tuple[List[BlockDesc], int, List[BlockDesc]]:
    """(group descriptors, n_groups, trailing descriptors)."""
    if cfg.family == "ssm":
        return [BlockDesc("ssd")], cfg.num_layers, []
    if cfg.family == "hybrid":
        per = cfg.hybrid_attn_period
        n, rem = divmod(cfg.num_layers, per)
        group = [BlockDesc("ssd")] * per + [BlockDesc("shared_attn")]
        return group, n, [BlockDesc("ssd")] * rem
    if cfg.local_global_period:
        assert cfg.local_global_period == 2
        group = [BlockDesc("attn", window=cfg.sliding_window),
                 BlockDesc("attn", window=None)]
        return group, cfg.num_layers // 2, []
    if cfg.num_experts and cfg.moe_layer_period > 1:
        group = [BlockDesc("attn", window=cfg.sliding_window, moe=False),
                 BlockDesc("attn", window=cfg.sliding_window, moe=True)]
        return group, cfg.num_layers // cfg.moe_layer_period, []
    moe = bool(cfg.num_experts)
    return [BlockDesc("attn", window=cfg.sliding_window, moe=moe)], cfg.num_layers, []


# ----------------------------------------------------------------------
# block init / apply
# ----------------------------------------------------------------------

def init_block(key, cfg: ModelConfig, desc: BlockDesc) -> Params:
    ks = jax.random.split(key, 4)
    d = cfg.d_model
    if desc.kind == "ssd":
        p = {"ln": L.init_norm(cfg, d), "ssd": L.init_ssd(ks[0], cfg)}
        if cfg.latent.enabled:
            p["ssd"] = _factorize_ssd_init(ks[0], cfg)
        return p
    if desc.kind == "shared_attn":
        return {}  # shared params live at top level
    # attn block
    p = {"ln1": L.init_norm(cfg, d), "ln2": L.init_norm(cfg, d)}
    if cfg.latent.enabled:
        r = latent_ranks(cfg)
        p["attn"] = L.init_latent_attention(ks[0], cfg, r["r_q"], r["r_k"],
                                            r["r_v"], r["r_o"])
    else:
        p["attn"] = L.init_attention(ks[0], cfg)
    if desc.moe:
        p["moe"] = L.init_moe(ks[1], cfg)
    else:
        if cfg.latent.enabled:
            r = latent_ranks(cfg)
            p["mlp"] = L.init_latent_mlp(ks[1], cfg, r["r_u"], r["r_d"])
        else:
            p["mlp"] = L.init_mlp(ks[1], cfg)
    return p


def _factorize_ssd_init(key, cfg: ModelConfig) -> Params:
    """SSD block with factored in/out projections (latent SSM, DESIGN §5)."""
    p = L.init_ssd(key, cfg)
    r = latent_ranks(cfg)
    ks = jax.random.split(key, 4)
    d, di = cfg.d_model, cfg.d_inner
    proj_out = p["in_proj"]["w"].shape[1]
    s = lambda n: 1.0 / math.sqrt(n)
    p["in_proj"] = {
        "a": jax.random.normal(ks[0], (d, r["r_in"]), jnp.float32) * s(d),
        "b": jax.random.normal(ks[1], (r["r_in"], proj_out), jnp.float32) * s(r["r_in"]),
    }
    p["out_proj"] = {
        "a": jax.random.normal(ks[2], (di, r["r_out"]), jnp.float32) * s(di),
        "b": jax.random.normal(ks[3], (r["r_out"], d), jnp.float32) * s(r["r_out"]),
    }
    return p


def _maybe_factored_dense(p: Params, x: jax.Array) -> jax.Array:
    if "a" in p:  # factored
        return (x @ p["a"].astype(x.dtype)) @ p["b"].astype(x.dtype)
    return L.dense(p, x)


def apply_block(
    p: Params,
    x: jax.Array,
    cfg: ModelConfig,
    desc: BlockDesc,
    *,
    positions: jax.Array,
    cache: Optional[Params],
    shared: Optional[Params] = None,
    lengths: Optional[jax.Array] = None,
    ring_span: Optional[int] = None,
) -> Tuple[jax.Array, Optional[Params], jax.Array]:
    """Returns (x, new_cache, aux_loss). ``lengths`` (B,) marks the true
    row lengths of a right-padded ragged prefill (per-row cache fill);
    ``ring_span`` (the engine's max_len) enables carry-in prefill over
    windowed ring layers (see ``latent_attention_fwd``)."""
    aux = jnp.zeros((), jnp.float32)
    if desc.kind == "ssd":
        h = L.norm_fwd(p["ln"], x)
        y, new_cache = _ssd_maybe_latent(p["ssd"], h, cfg, cache)
        return x + y, new_cache, aux
    if desc.kind == "shared_attn":
        assert shared is not None
        return _apply_attn_block(shared, x, cfg, desc, positions, cache,
                                 lengths, ring_span)
    return _apply_attn_block(p, x, cfg, desc, positions, cache, lengths,
                             ring_span)


def _ssd_maybe_latent(p: Params, x: jax.Array, cfg: ModelConfig,
                      cache: Optional[Params]):
    if "a" in p.get("in_proj", {}):
        # temporarily materialize factored projections through the same path
        q = dict(p)
        q["in_proj"] = {"w_factored": p["in_proj"]}
        # custom apply to avoid materializing the full product
        return _ssd_fwd_factored(p, x, cfg, cache)
    return L.ssd_fwd(p, x, cfg, cache)


def _ssd_fwd_factored(p: Params, x: jax.Array, cfg: ModelConfig,
                      cache: Optional[Params]):
    """ssd_fwd but with low-rank in/out projections applied as two matmuls."""
    sub = dict(p)
    in_p, out_p = p["in_proj"], p["out_proj"]

    class _F:  # minimal shim so layers.ssd_fwd's dense() sees a w/b dict
        pass

    # Rather than shim, inline: project input through factors then call the
    # body of ssd_fwd with a dense-equivalent weight is wasteful; instead we
    # duplicate the (short) ssd_fwd with factored matmuls.
    B, S, d = x.shape
    di, G, N = cfg.d_inner, cfg.ssm_ngroups, cfg.ssm_state
    Hs, P = cfg.ssm_nheads, cfg.ssm_head_dim
    W = cfg.ssm_conv_width
    zxbcdt = constrain_bsf(_maybe_factored_dense(in_p, x))
    z, xbc, dt = jnp.split(zxbcdt, [di, di + di + 2 * G * N], axis=-1)
    if cache is not None:
        conv_in = jnp.concatenate([cache["conv"].astype(xbc.dtype), xbc], axis=1)
        new_conv = conv_in[:, -(W - 1):]
    else:
        conv_in = jnp.pad(xbc, ((0, 0), (W - 1, 0), (0, 0)))
        new_conv = conv_in[:, -(W - 1):]
    xbc = L._causal_conv(conv_in, p["conv_w"], p["conv_b"], S)
    xbc = jax.nn.silu(xbc)
    xs, Bm, Cm = jnp.split(xbc, [di, di + G * N], axis=-1)
    xh = xs.reshape(B, S, Hs, P)
    Bm = Bm.reshape(B, S, G, N)
    Cm = Cm.reshape(B, S, G, N)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    if cache is not None and S == 1:
        s_prev = cache["ssm"]
        dA = jnp.exp(dt[:, 0, :] * A[None, :])
        rep = Hs // G
        Bh = jnp.repeat(Bm[:, 0], rep, axis=1)
        Ch = jnp.repeat(Cm[:, 0], rep, axis=1)
        dBx = jnp.einsum("bhn,bhp,bh->bhpn", Bh.astype(jnp.float32),
                         xh[:, 0].astype(jnp.float32), dt[:, 0])
        s_new = s_prev * dA[:, :, None, None] + dBx
        y = jnp.einsum("bhpn,bhn->bhp", s_new, Ch.astype(jnp.float32))
        y = y[:, None].astype(x.dtype)
        new_cache = {"conv": new_conv, "ssm": s_new}
    else:
        y, final_state = L._ssd_chunked(xh, dt, A, Bm, Cm, min(cfg.ssm_chunk, S))
        new_cache = {"conv": new_conv, "ssm": final_state} if cache is not None else None
    y = y + xh * p["D"].astype(x.dtype)[None, None, :, None]
    y = y.reshape(B, S, di)
    y = L.norm_fwd(p["norm"], y) * jax.nn.silu(z)
    out = _maybe_factored_dense(out_p, y)
    return out, new_cache


def _apply_attn_block(p, x, cfg, desc, positions, cache, lengths=None,
                      ring_span=None):
    aux = jnp.zeros((), jnp.float32)
    h = L.norm_fwd(p["ln1"], x)
    attn_cache = cache.get("attn") if cache is not None else None
    if cfg.latent.enabled:
        y, new_attn_cache = L.latent_attention_fwd(
            p["attn"], h, cfg, positions=positions, window=desc.window,
            cache=attn_cache, lengths=lengths, ring_span=ring_span)
    else:
        y, new_attn_cache = L.attention_fwd(
            p["attn"], h, cfg, positions=positions, window=desc.window,
            cache=attn_cache, lengths=lengths)
    x = x + y
    h = L.norm_fwd(p["ln2"], x)
    if "moe" in p:
        y, aux = L.moe_fwd(p["moe"], h, cfg)
    elif cfg.latent.enabled:
        y = L.latent_mlp_fwd(p["mlp"], h, cfg)
    else:
        y = L.mlp_fwd(p["mlp"], h, cfg)
    x = x + y
    new_cache = {"attn": new_attn_cache} if cache is not None else None
    return x, new_cache, aux


# ----------------------------------------------------------------------
# cache init
# ----------------------------------------------------------------------

def init_block_cache(cfg: ModelConfig, desc: BlockDesc, batch: int,
                     max_len: int) -> Params:
    if desc.kind == "ssd":
        return L.init_ssd_cache(cfg, batch)
    window = desc.window
    if cfg.latent.enabled:
        r = latent_ranks(cfg)
        return {"attn": L.init_latent_attention_cache(
            cfg, batch, max_len, r["r_k"], r["r_v"], window)}
    return {"attn": L.init_attention_cache(cfg, batch, max_len, window)}


def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> Params:
    group, n, trailing = group_spec(cfg)
    stacked = []
    for desc in group:
        one = init_block_cache(cfg, desc, batch, max_len)
        stacked.append(jax.tree.map(
            lambda a: jnp.broadcast_to(a, (n,) + a.shape), one))
    trail = [init_block_cache(cfg, d, batch, max_len) for d in trailing]
    return {"pos": jnp.zeros((), jnp.int32), "groups": stacked, "trailing": trail}


def cache_layouts(cfg: ModelConfig, max_len: int):
    """(group layouts, trailing layouts): one ``CacheLayout`` per block
    descriptor (``None`` for state-cache ssd blocks) — the single source
    of truth for how each layer's cache maps positions to slots, shared
    by the serving arena, the engine, and the sharding rules."""
    group, _, trailing = group_spec(cfg)

    def one(desc: BlockDesc):
        if desc.kind == "ssd":
            return None
        return CacheLayout.make(max_len, desc.window)

    return [one(d) for d in group], [one(d) for d in trailing]


# ----------------------------------------------------------------------
# model init / forward
# ----------------------------------------------------------------------

def init_params(key, cfg: ModelConfig) -> Params:
    group, n, trailing = group_spec(cfg)
    keys = jax.random.split(key, 8)
    p: Params = {}
    p["embed"] = jax.random.normal(keys[0], (cfg.vocab_size, cfg.d_model),
                                   jnp.float32) / math.sqrt(cfg.d_model)
    if cfg.pos_emb == "learned":
        p["pos_embed"] = jax.random.normal(
            keys[1], (cfg.max_position_embeddings, cfg.d_model), jnp.float32) * 0.02
    # stacked groups
    stacked = []
    for di, desc in enumerate(group):
        gkeys = jax.random.split(jax.random.fold_in(keys[2], di), n)
        stacked.append(jax.vmap(lambda k: init_block(k, cfg, desc))(gkeys))
    p["groups"] = stacked
    p["trailing"] = [init_block(jax.random.fold_in(keys[3], i), cfg, d)
                     for i, d in enumerate(trailing)]
    if cfg.family == "hybrid":
        shared_desc = BlockDesc("attn", window=None, moe=False)
        p["shared_block"] = init_block(keys[4], cfg, shared_desc)
    p["final_norm"] = L.init_norm(cfg, cfg.d_model)
    if not cfg.tie_embeddings:
        p["lm_head"] = jax.random.normal(
            keys[5], (cfg.d_model, cfg.vocab_size), jnp.float32) / math.sqrt(cfg.d_model)
    return p


def forward(
    params: Params,
    cfg: ModelConfig,
    *,
    tokens: Optional[jax.Array] = None,
    frames: Optional[jax.Array] = None,
    cache: Optional[Params] = None,
    lengths: Optional[jax.Array] = None,
    remat: bool = False,
    remat_policy: Optional[str] = "nothing",
    ring_span: Optional[int] = None,
) -> Tuple[jax.Array, Optional[Params], jax.Array]:
    """Returns (logits, new_cache, aux_loss). ``lengths`` (B,) flags a
    right-padded ragged prefill (serving admission): each attention
    layer's cache fill writes only a row's own trailing tokens, which
    ring (sliding-window) layouts require — padding positions wrap onto
    the same slots as real tokens. ``ring_span`` (the engine's max_len)
    enables carry-in chunked prefill over windowed ring layers."""
    group, n, trailing = group_spec(cfg)
    comp_dtype = dtype_of(cfg)
    if cfg.input_mode == "embeddings":
        assert frames is not None
        x = frames.astype(comp_dtype)
        B, S = x.shape[:2]
    else:
        assert tokens is not None
        B, S = tokens.shape
        x = params["embed"].astype(comp_dtype)[tokens]
    pos0 = cache["pos"] if cache is not None else jnp.zeros((), jnp.int32)
    # cache["pos"] is either a scalar (shared across the batch — train /
    # prefill / lockstep decode) or a (B,) vector (the serving engine's
    # ragged decode, and — for absorbed latent configs — carry-in
    # chunked/paged prefill where each row resumes at its own base).
    if pos0.ndim == 1:
        positions = pos0[:, None] + jnp.arange(S, dtype=jnp.int32)  # (B, S)
    else:
        positions = pos0 + jnp.arange(S, dtype=jnp.int32)  # (S,)
    if cfg.pos_emb == "learned":
        x = x + params["pos_embed"].astype(comp_dtype)[positions]
    x = constrain_bsd(x)

    shared = params.get("shared_block")
    aux_total = jnp.zeros((), jnp.float32)

    def group_body(x, group_params, group_cache):
        aux_g = jnp.zeros((), jnp.float32)
        new_caches = []
        for bi, desc in enumerate(group):
            bc = group_cache[bi] if group_cache is not None else None
            x, nc, aux = apply_block(
                group_params[bi], x, cfg, desc,
                positions=positions, cache=bc, shared=shared,
                lengths=lengths, ring_span=ring_span)
            x = constrain_bsd(x).astype(comp_dtype)  # keep the carry bf16
            new_caches.append(nc)
            aux_g = aux_g + aux
        return x, new_caches, aux_g

    if remat:
        policy = None
        if remat_policy == "dots":
            policy = jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
        group_body = jax.checkpoint(group_body, policy=policy,
                                    static_argnums=())

    def scan_fn(carry, xs):
        x, aux_acc = carry
        if cache is not None:
            gp, gc = xs
        else:
            gp, gc = xs, None
        x, new_caches, aux_g = group_body(x, gp, gc)
        return (x, aux_acc + aux_g), new_caches

    if cache is not None:
        xs = (params["groups"], cache["groups"])
    else:
        xs = params["groups"]
    (x, aux_total), new_group_caches = lax.scan(scan_fn, (x, aux_total), xs)

    new_trailing = []
    for i, desc in enumerate(trailing):
        tc = cache["trailing"][i] if cache is not None else None
        x, nc, aux = apply_block(params["trailing"][i], x, cfg, desc,
                                 positions=positions, cache=tc, shared=shared,
                                 lengths=lengths, ring_span=ring_span)
        new_trailing.append(nc)
        aux_total = aux_total + aux

    x = L.norm_fwd(params["final_norm"], x)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = x @ head.astype(x.dtype)
    logits = constrain(logits, [[("pod", "data"), "data", None], [None],
                                [("model",), None]])
    if cfg.final_logit_softcap:
        c = cfg.final_logit_softcap
        logits = jnp.tanh(logits / c) * c

    new_cache = None
    if cache is not None:
        new_cache = {
            "pos": cache["pos"] + S,
            "groups": new_group_caches,
            "trailing": new_trailing,
        }
    return logits, new_cache, aux_total
