"""LM heads of the framework: loss, train_step / prefill_step / decode_step.

These are the functions the launcher jits with in/out shardings; they are
also what the multi-pod dry-run lowers.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, dtype_of
from repro.models import transformer as T

Params = Dict[str, Any]

MOE_AUX_COEF = 0.01


def cross_entropy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Mean token NLL; fp32 logsumexp regardless of logits dtype."""
    from repro.distributed.constraints import constrain
    lse = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
    # one-hot einsum keeps the vocab axis sharded (GSPMD-friendly pick)
    onehot = jax.nn.one_hot(labels, logits.shape[-1], dtype=logits.dtype)
    onehot = constrain(onehot, [[("pod", "data"), "data", None], [None],
                                [("model",), None]])
    picked = jnp.einsum("bsv,bsv->bs", logits, onehot).astype(jnp.float32)
    return jnp.mean(lse - picked)


def loss_fn(params: Params, cfg: ModelConfig, batch: Dict[str, jax.Array],
            remat: bool = True, remat_policy: str = "nothing") -> Tuple[jax.Array, Dict]:
    logits, _, aux = T.forward(
        params, cfg,
        tokens=batch.get("tokens"),
        frames=batch.get("frames"),
        cache=None, remat=remat, remat_policy=remat_policy)
    labels = batch["labels"]
    # next-token prediction: shift within the sequence
    nll = cross_entropy(logits[:, :-1], labels[:, 1:])
    loss = nll + MOE_AUX_COEF * aux
    return loss, {"nll": nll, "aux": aux}


def make_train_step(cfg: ModelConfig, optimizer, *, remat: bool = True,
                    remat_policy: str = "nothing",
                    grad_accum: int = 1,
                    accum_dtype: str = "float32") -> Callable:
    """Returns train_step(params, opt_state, batch, step) -> (params,
    opt_state, metrics). ``optimizer`` is a repro.optim object with
    init/update. ``accum_dtype='bfloat16'`` halves the microbatch
    gradient-accumulation buffer (needed to fit 400B-class models)."""
    acc_dt = jnp.bfloat16 if accum_dtype == "bfloat16" else jnp.float32

    def single(params, batch):
        (loss, parts), grads = jax.value_and_grad(
            lambda p: loss_fn(p, cfg, batch, remat, remat_policy),
            has_aux=True)(params)
        return loss, parts, grads

    def train_step(params, opt_state, batch, step):
        if grad_accum > 1:
            def micro(carry, mb):
                loss_acc, grad_acc = carry
                loss, _, grads = single(params, mb)
                return (loss_acc + loss,
                        jax.tree.map(lambda a, g: a + g.astype(acc_dt),
                                     grad_acc, grads)), None
            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, acc_dt), params)
            mbs = jax.tree.map(
                lambda a: a.reshape((grad_accum, a.shape[0] // grad_accum)
                                    + a.shape[1:]), batch)
            (loss, grads), _ = jax.lax.scan(
                micro, (jnp.zeros((), jnp.float32), zeros), mbs)
            loss = loss / grad_accum
            grads = jax.tree.map(lambda g: g / grad_accum, grads)
        else:
            loss, _, grads = single(params, batch)
        params, opt_state = optimizer.update(grads, opt_state, params, step)
        return params, opt_state, {"loss": loss}

    return train_step


def make_eval_step(cfg: ModelConfig) -> Callable:
    def eval_step(params, batch):
        loss, parts = loss_fn(params, cfg, batch, remat=False)
        return parts["nll"]
    return eval_step


# ----------------------------------------------------------------------
# serving
# ----------------------------------------------------------------------

def make_prefill_step(cfg: ModelConfig, max_len: int) -> Callable:
    """prefill_step(params, batch) -> (cache, last_logits)."""

    def prefill_step(params, batch):
        tokens = batch.get("tokens")
        frames = batch.get("frames")
        B = (tokens if tokens is not None else frames).shape[0]
        cache = T.init_cache(cfg, B, max_len)
        logits, cache, _ = T.forward(params, cfg, tokens=tokens,
                                     frames=frames, cache=cache)
        return cache, logits[:, -1]

    return prefill_step


def make_decode_step(cfg: ModelConfig) -> Callable:
    """decode_step(params, cache, token) -> (logits, cache).

    ``token``: (B, 1) int32 (or (B,1,d) frames). One autoregressive step
    against the KV/state cache — this is what decode_* shapes lower."""

    def decode_step(params, cache, batch):
        logits, cache, _ = T.forward(params, cfg,
                                     tokens=batch.get("tokens"),
                                     frames=batch.get("frames"),
                                     cache=cache)
        return logits[:, -1], cache

    return decode_step


def make_generate_step(cfg: ModelConfig, steps: int) -> Callable:
    """generate(params, cache, tok) -> (tokens, cache).

    ``tok``: (B, 1) int32 — the first token to feed. Runs ``steps``
    greedy decode steps as ONE ``lax.scan`` over the cache carry, so an
    N-token generation is a single dispatch instead of N Python-loop
    dispatches. Returns tokens (B, steps): the argmax after each fed
    token (the continuation of ``tok``, which the caller already has)."""
    assert cfg.input_mode == "tokens", "scan generation is token-mode only"

    def generate(params, cache, tok):
        def body(carry, _):
            cache, tok = carry
            logits, cache, _ = T.forward(params, cfg, tokens=tok,
                                         cache=cache)
            nxt = jnp.argmax(logits[:, -1], axis=-1).astype(tok.dtype)
            return (cache, nxt[:, None]), nxt

        (cache, _), toks = jax.lax.scan(body, (cache, tok), None,
                                        length=steps)
        return jnp.swapaxes(toks, 0, 1), cache  # (B, steps)

    return generate


def jit_generate(cfg: ModelConfig, steps: int, *,
                 donate_cache: bool = True) -> Callable:
    """Jitted scan-generation step with the cache buffers donated (the
    old cache is dead after the call, so XLA reuses its HBM in place).
    Donation is skipped on CPU, which does not implement it."""
    donate = (1,) if (donate_cache and jax.default_backend() != "cpu") else ()
    return jax.jit(make_generate_step(cfg, steps), donate_argnums=donate)


def greedy_generate(cfg: ModelConfig, params: Params, prompt: jax.Array,
                    steps: int, max_len: int, *,
                    use_scan: bool = True) -> jax.Array:
    """Greedy generation used by examples/serve (not the dry-run).

    ``use_scan=True`` (default) runs the whole continuation as one
    ``lax.scan`` dispatch; ``use_scan=False`` keeps the per-token Python
    loop (reference path, bit-identical tokens)."""
    prefill = jax.jit(make_prefill_step(cfg, max_len))
    cache, logits = prefill(params, {"tokens": prompt})
    tok = jnp.argmax(logits, axis=-1)[:, None].astype(prompt.dtype)
    if steps <= 1:
        return tok
    if use_scan:
        toks, _ = jit_generate(cfg, steps - 1)(params, cache, tok)
        return jnp.concatenate([tok, toks], axis=1)
    decode = jax.jit(make_decode_step(cfg))
    out = [tok]
    for _ in range(steps - 1):
        logits, cache = decode(params, cache, {"tokens": out[-1]})
        out.append(jnp.argmax(logits, axis=-1)[:, None].astype(prompt.dtype))
    return jnp.concatenate(out, axis=1)
