"""LM heads of the framework: loss, train_step / prefill_step / decode_step.

These are the functions the launcher jits with in/out shardings; they are
also what the multi-pod dry-run lowers.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, dtype_of
from repro.models import sampling as smp
from repro.models import transformer as T

Params = Dict[str, Any]

MOE_AUX_COEF = 0.01


def cross_entropy(logits: jax.Array, labels: jax.Array,
                  mask: Optional[jax.Array] = None) -> jax.Array:
    """Mean token NLL; fp32 logsumexp regardless of logits dtype.

    ``mask`` (B, S) optional token weights: the mean is token-weighted
    (sum(nll*mask)/sum(mask)) so padded positions in ragged eval batches
    contribute nothing. ``mask=None`` is the plain mean over every
    position (bit-identical to the unmasked behaviour)."""
    from repro.distributed.constraints import constrain
    lse = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
    # one-hot einsum keeps the vocab axis sharded (GSPMD-friendly pick)
    onehot = jax.nn.one_hot(labels, logits.shape[-1], dtype=logits.dtype)
    onehot = constrain(onehot, [[("pod", "data"), "data", None], [None],
                                [("model",), None]])
    picked = jnp.einsum("bsv,bsv->bs", logits, onehot).astype(jnp.float32)
    nll = lse - picked
    if mask is None:
        return jnp.mean(nll)
    w = mask.astype(jnp.float32)
    return jnp.sum(nll * w) / jnp.maximum(jnp.sum(w), 1.0)


def loss_fn(params: Params, cfg: ModelConfig, batch: Dict[str, jax.Array],
            remat: bool = True, remat_policy: str = "nothing") -> Tuple[jax.Array, Dict]:
    logits, _, aux = T.forward(
        params, cfg,
        tokens=batch.get("tokens"),
        frames=batch.get("frames"),
        cache=None, remat=remat, remat_policy=remat_policy)
    labels = batch["labels"]
    # next-token prediction: shift within the sequence; an optional
    # batch["mask"] (1 = real token, 0 = padding) shifts with the labels
    mask = batch.get("mask")
    nll = cross_entropy(logits[:, :-1], labels[:, 1:],
                        mask[:, 1:] if mask is not None else None)
    loss = nll + MOE_AUX_COEF * aux
    return loss, {"nll": nll, "aux": aux}


def make_train_step(cfg: ModelConfig, optimizer, *, remat: bool = True,
                    remat_policy: str = "nothing",
                    grad_accum: int = 1,
                    accum_dtype: str = "float32") -> Callable:
    """Returns train_step(params, opt_state, batch, step) -> (params,
    opt_state, metrics). ``optimizer`` is a repro.optim object with
    init/update. ``accum_dtype='bfloat16'`` halves the microbatch
    gradient-accumulation buffer (needed to fit 400B-class models)."""
    acc_dt = jnp.bfloat16 if accum_dtype == "bfloat16" else jnp.float32

    def single(params, batch):
        (loss, parts), grads = jax.value_and_grad(
            lambda p: loss_fn(p, cfg, batch, remat, remat_policy),
            has_aux=True)(params)
        return loss, parts, grads

    def train_step(params, opt_state, batch, step):
        if grad_accum > 1:
            def micro(carry, mb):
                loss_acc, grad_acc = carry
                loss, _, grads = single(params, mb)
                return (loss_acc + loss,
                        jax.tree.map(lambda a, g: a + g.astype(acc_dt),
                                     grad_acc, grads)), None
            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, acc_dt), params)
            mbs = jax.tree.map(
                lambda a: a.reshape((grad_accum, a.shape[0] // grad_accum)
                                    + a.shape[1:]), batch)
            (loss, grads), _ = jax.lax.scan(
                micro, (jnp.zeros((), jnp.float32), zeros), mbs)
            loss = loss / grad_accum
            grads = jax.tree.map(lambda g: g / grad_accum, grads)
        else:
            loss, _, grads = single(params, batch)
        params, opt_state = optimizer.update(grads, opt_state, params, step)
        return params, opt_state, {"loss": loss}

    return train_step


def make_eval_step(cfg: ModelConfig) -> Callable:
    """eval_step(params, batch) -> mean token NLL. An optional
    ``batch["mask"]`` (1 = real token, 0 = padding) makes the mean
    token-weighted so ragged eval batches don't pollute perplexity."""
    def eval_step(params, batch):
        loss, parts = loss_fn(params, cfg, batch, remat=False)
        return parts["nll"]
    return eval_step


# ----------------------------------------------------------------------
# serving
# ----------------------------------------------------------------------

def make_prefill_step(cfg: ModelConfig, max_len: int) -> Callable:
    """prefill_step(params, batch) -> (cache, last_logits)."""

    def prefill_step(params, batch):
        tokens = batch.get("tokens")
        frames = batch.get("frames")
        B = (tokens if tokens is not None else frames).shape[0]
        cache = T.init_cache(cfg, B, max_len)
        logits, cache, _ = T.forward(params, cfg, tokens=tokens,
                                     frames=frames, cache=cache)
        return cache, logits[:, -1]

    return prefill_step


def make_decode_step(cfg: ModelConfig, *, sample: bool = False) -> Callable:
    """decode_step(params, cache, token) -> (logits, cache).

    ``token``: (B, 1) int32 (or (B,1,d) frames). One autoregressive step
    against the KV/state cache — this is what decode_* shapes lower.

    With ``sample=True`` the step fuses token selection into the same
    dispatch: decode_step(params, cache, batch, keys, temperature,
    top_k, top_p) -> (tokens (B,), cache), where ``keys`` are (B, 2)
    uint32 per-row PRNG keys and the sampling params are per-row arrays
    (or scalars). ``temperature == 0`` rows are greedy (argmax)."""

    def decode_step(params, cache, batch):
        logits, cache, _ = T.forward(params, cfg,
                                     tokens=batch.get("tokens"),
                                     frames=batch.get("frames"),
                                     cache=cache)
        return logits[:, -1], cache

    if not sample:
        return decode_step

    def decode_sample_step(params, cache, batch, keys, temperature,
                           top_k=0, top_p=1.0):
        logits, cache = decode_step(params, cache, batch)
        tok = smp.sample_logits(logits, keys, temperature=temperature,
                                top_k=top_k, top_p=top_p)
        return tok, cache

    return decode_sample_step


def _stop_mask(tok, eos_id, stop_tokens):
    """tok: (B,) -> (B,) bool, True where tok terminates the row."""
    done = jnp.zeros(tok.shape, bool)
    for s in ((eos_id,) if eos_id is not None else ()) + tuple(stop_tokens):
        done |= tok == s
    return done


def make_generate_step(cfg: ModelConfig, steps: int, *,
                       temperature=0.0, top_k=0, top_p=1.0, seed: int = 0,
                       eos_id: Optional[int] = None,
                       stop_tokens: Tuple[int, ...] = (),
                       pad_id: int = 0, step_offset: int = 0) -> Callable:
    """generate(params, cache, tok) -> (tokens, cache).

    ``tok``: (B, 1) int32 — the first token to feed. Runs ``steps``
    decode steps as ONE ``lax.scan`` over the cache carry, so an N-token
    generation is a single dispatch instead of N Python-loop dispatches.
    Returns tokens (B, steps): the continuation of ``tok``.

    Sampling: ``temperature == 0`` (default) is greedy argmax — the old
    behaviour, bit-identical. A non-zero temperature samples with
    per-row keys derived from ``seed`` (row r uses fold_in(PRNGKey(seed),
    r); token i folds in ``step_offset + i``, so a prefix-sampled first
    token can use index 0 and pass ``step_offset=1`` here). ``top_k``/
    ``top_p`` filter before sampling; scalars or (B,) arrays.

    Early stop: with ``eos_id``/``stop_tokens`` set, rows that emit a
    stop token keep their position in the batch but emit ``pad_id`` for
    the remaining steps (shapes are static — callers like the serving
    engine detect the pad/stop and finish slots early)."""
    assert cfg.input_mode == "tokens", "scan generation is token-mode only"
    greedy = isinstance(temperature, (int, float)) and temperature == 0
    track_done = eos_id is not None or len(tuple(stop_tokens)) > 0

    def generate(params, cache, tok):
        B = tok.shape[0]
        if not greedy:
            rkeys = smp.row_keys(seed, B)

        def body(carry, i):
            cache, tok, done = carry
            logits, cache, _ = T.forward(params, cfg, tokens=tok,
                                         cache=cache)
            if greedy:
                nxt = jnp.argmax(logits[:, -1], axis=-1)
            else:
                nxt = smp.sample_logits(logits[:, -1], smp.fold_keys(rkeys, i),
                                        temperature=temperature,
                                        top_k=top_k, top_p=top_p)
            nxt = nxt.astype(tok.dtype)
            if track_done:
                nxt = jnp.where(done, jnp.asarray(pad_id, tok.dtype), nxt)
                done = done | _stop_mask(nxt, eos_id, stop_tokens)
            return (cache, nxt[:, None], done), nxt

        done0 = (_stop_mask(tok[:, 0], eos_id, stop_tokens) if track_done
                 else jnp.zeros((B,), bool))
        xs = jnp.arange(step_offset, step_offset + steps, dtype=jnp.uint32)
        (cache, _, _), toks = jax.lax.scan(body, (cache, tok, done0), xs,
                                           length=steps)
        return jnp.swapaxes(toks, 0, 1), cache  # (B, steps)

    return generate


def jit_generate(cfg: ModelConfig, steps: int, *,
                 donate_cache: bool = True, **kw) -> Callable:
    """Jitted scan-generation step with the cache buffers donated (the
    old cache is dead after the call, so XLA reuses its HBM in place).
    Donation is skipped on CPU, which does not implement it. Extra
    keyword args (sampling / stop config) pass to make_generate_step."""
    donate = (1,) if (donate_cache and jax.default_backend() != "cpu") else ()
    return jax.jit(make_generate_step(cfg, steps, **kw),
                   donate_argnums=donate)


def greedy_generate(cfg: ModelConfig, params: Params, prompt: jax.Array,
                    steps: int, max_len: int, *, use_scan: bool = True,
                    temperature=0.0, top_k=0, top_p=1.0, seed: int = 0,
                    eos_id: Optional[int] = None,
                    stop_tokens: Tuple[int, ...] = (),
                    pad_id: int = 0) -> jax.Array:
    """Generation driver used by examples/serve (not the dry-run).

    Greedy by default (the name survives from when argmax was the only
    mode); ``temperature > 0`` samples — see make_generate_step for the
    key scheme (the first token uses fold index 0, the scan continues
    at 1). With ``eos_id``/``stop_tokens``, rows that stop emit
    ``pad_id`` for the remaining steps instead of running on.

    ``use_scan=True`` (default) runs the whole continuation as one
    ``lax.scan`` dispatch; ``use_scan=False`` keeps the per-token Python
    loop (reference path, bit-identical tokens)."""
    greedy = isinstance(temperature, (int, float)) and temperature == 0
    track_done = eos_id is not None or len(tuple(stop_tokens)) > 0
    B = prompt.shape[0]
    prefill = jax.jit(make_prefill_step(cfg, max_len))
    cache, logits = prefill(params, {"tokens": prompt})
    if greedy:
        tok = jnp.argmax(logits, axis=-1)[:, None].astype(prompt.dtype)
    else:
        rkeys = smp.row_keys(seed, B)
        tok = smp.sample_logits(logits, smp.fold_keys(rkeys, 0),
                                temperature=temperature, top_k=top_k,
                                top_p=top_p)[:, None].astype(prompt.dtype)
    if steps <= 1:
        return tok
    sample_kw = dict(temperature=temperature, top_k=top_k, top_p=top_p,
                     seed=seed, eos_id=eos_id, stop_tokens=stop_tokens,
                     pad_id=pad_id)
    if use_scan:
        toks, _ = jit_generate(cfg, steps - 1, step_offset=1,
                               **sample_kw)(params, cache, tok)
        return jnp.concatenate([tok, toks], axis=1)
    decode = jax.jit(make_decode_step(cfg))
    done = _stop_mask(tok[:, 0], eos_id, stop_tokens)
    out = [tok]
    for t in range(1, steps):
        logits, cache = decode(params, cache, {"tokens": out[-1]})
        if greedy:
            nxt = jnp.argmax(logits, axis=-1)
        else:
            nxt = smp.sample_logits(logits, smp.fold_keys(rkeys, t),
                                    temperature=temperature, top_k=top_k,
                                    top_p=top_p)
        nxt = nxt.astype(prompt.dtype)
        if track_done:
            nxt = jnp.where(done, jnp.asarray(pad_id, prompt.dtype), nxt)
            done = done | _stop_mask(nxt, eos_id, stop_tokens)
        out.append(nxt[:, None])
    return jnp.concatenate(out, axis=1)


# ----------------------------------------------------------------------
# continuous-batching engine heads (repro.serve builds on these)
# ----------------------------------------------------------------------

def make_engine_prefill(cfg: ModelConfig, max_len: int,
                        carry: bool = False) -> Callable:
    """engine_prefill(params, tokens, lengths, base_keys, temperature,
    top_k, top_p) -> (first_tok (B, 1), cache).

    With ``carry=True`` returns the carry-in chunked variant instead
    (``make_engine_chunk_prefill``): same sampling, but the head takes
    the arena cache plus per-row ``slot_ids``/``bases`` so a prompt can
    prefill incrementally across bounded chunks.

    Ragged admission prefill: ``tokens`` is a right-padded (B, S_bucket)
    batch, ``lengths`` (B,) the true prompt lengths. One forward fills
    the cache for all rows; each row's first token is sampled from the
    logits at its own last *valid* position. ``lengths`` is also threaded
    into the forward so each row's cache fill writes only its OWN
    trailing tokens — on a linear cache padding garbage was merely inert
    (masked by the validity prefix), but on a ring (sliding-window)
    cache padding positions wrap onto the same slots as real tokens and
    would clobber them. The returned cache carries per-row positions:
    ``cache['pos'] = lengths`` — the engine decodes all slots ragged."""
    assert cfg.input_mode == "tokens", "the engine is token-mode only"
    if carry:
        return make_engine_chunk_prefill(cfg, max_len)

    def engine_prefill(params, tokens, lengths, base_keys, temperature,
                       top_k=0, top_p=1.0):
        B, _ = tokens.shape
        cache = T.init_cache(cfg, B, max_len)
        logits, cache, _ = T.forward(params, cfg, tokens=tokens, cache=cache,
                                     lengths=lengths)
        last = jnp.take_along_axis(
            logits, (lengths - 1)[:, None, None], axis=1)[:, 0]
        keys = smp.fold_keys(base_keys, jnp.zeros((B,), jnp.uint32))
        tok0 = smp.sample_logits(last, keys, temperature=temperature,
                                 top_k=top_k, top_p=top_p)
        cache["pos"] = lengths.astype(jnp.int32)  # per-row ragged positions
        return tok0[:, None].astype(tokens.dtype), cache

    return engine_prefill


def _arena_gather(cache, slot_ids: jax.Array):
    """Gather arena slot rows into a (B, …) prefill view. Arena leaves
    are group-stacked (n, num_slots, L, …) / trailing (num_slots, L, …);
    sentinel slot ids clip to the last slot — their reads are garbage
    but finite, and their writes drop on the scatter back."""
    def g_groups(a):
        return jnp.take(a, slot_ids, axis=1, mode="clip")

    def g_trail(a):
        return jnp.take(a, slot_ids, axis=0, mode="clip")

    return {"groups": [jax.tree.map(g_groups, g) for g in cache["groups"]],
            "trailing": [jax.tree.map(g_trail, t) for t in cache["trailing"]]}


def _arena_scatter(cache, view, slot_ids: jax.Array):
    """Scatter a prefill view's rows (and per-row ``pos``) back into the
    arena at ``slot_ids``; sentinel (out-of-bounds) rows drop."""
    def s_groups(a, v):
        return a.at[:, slot_ids].set(v.astype(a.dtype), mode="drop")

    def s_trail(a, v):
        return a.at[slot_ids].set(v.astype(a.dtype), mode="drop")

    return {
        "pos": cache["pos"].at[slot_ids].set(
            view["pos"].astype(jnp.int32), mode="drop"),
        "groups": [jax.tree.map(s_groups, g, vg)
                   for g, vg in zip(cache["groups"], view["groups"])],
        "trailing": [jax.tree.map(s_trail, t, vt)
                     for t, vt in zip(cache["trailing"], view["trailing"])],
    }


def make_engine_chunk_prefill(cfg: ModelConfig, max_len: int) -> Callable:
    """chunk_prefill(params, cache, slot_ids, tokens, lengths, bases,
    base_keys, temperature, top_k, top_p) -> (first_tok (B, 1), cache).

    Carry-in chunked admission prefill operating directly ON THE ARENA:
    ``tokens`` is a right-padded (B, S_bucket) batch of prompt *chunks*,
    ``bases`` (B,) how many tokens of each row are already resident (0
    for the first chunk), ``slot_ids`` (B,) the arena slots (sentinel
    ``num_slots`` pads drop). Each row's forward resumes at its own base
    — per-row (B, S) positions route through the carry-in prefill branch
    of ``latent_attention_fwd`` (``q_offsets``/abs-aligned ring buffers),
    so a chunk attends to every previously written token and the chunked
    result is bit-identical to a single unchunked pass. ``tok0`` is
    sampled from every chunk with the SAME fold (index 0) as
    ``make_engine_prefill``; the engine uses it only on a row's FINAL
    chunk, which keeps the first generated token bit-identical too.
    Requires an absorbed latent config (``pos_emb != 'rope'``, no qkv
    bias) — the engine gates chunked mode on that."""
    assert cfg.input_mode == "tokens", "the engine is token-mode only"
    assert cfg.latent.enabled and cfg.pos_emb != "rope" and not cfg.qkv_bias, \
        "chunked prefill requires an absorbed latent config"

    def chunk_prefill(params, cache, slot_ids, tokens, lengths, bases,
                      base_keys, temperature, top_k=0, top_p=1.0):
        B, _ = tokens.shape
        slot_ids = slot_ids.astype(jnp.int32)
        view = _arena_gather(cache, slot_ids)
        view["pos"] = bases.astype(jnp.int32)   # (B,): per-row carry-in base
        logits, view, _ = T.forward(params, cfg, tokens=tokens, cache=view,
                                    lengths=lengths, ring_span=max_len)
        last = jnp.take_along_axis(
            logits, (lengths - 1)[:, None, None], axis=1)[:, 0]
        keys = smp.fold_keys(base_keys, jnp.zeros((B,), jnp.uint32))
        tok0 = smp.sample_logits(last, keys, temperature=temperature,
                                 top_k=top_k, top_p=top_p)
        view["pos"] = (bases + lengths).astype(jnp.int32)
        cache = _arena_scatter(cache, view, slot_ids)
        return tok0[:, None].astype(tokens.dtype), cache

    return chunk_prefill


def make_engine_step(cfg: ModelConfig, pad_id: int = 0,
                     greedy: bool = False) -> Callable:
    """engine_step(params, cache, tok, base_keys, gen_count, temperature,
    top_k, top_p, active[, poison]) -> (next_tok (B, 1), finite (B,),
    cache).

    ONE fused dispatch per serving step across all arena slots: ragged
    decode (per-row cache positions), per-row sampling params, per-row
    PRNG streams (token i of a request folds ``gen_count`` into its base
    key — slot placement never changes the sampled sequence), and an
    ``active`` mask. Inactive (free/finished) slots emit ``pad_id`` and
    do NOT advance their cache position, so a freshly admitted request
    always resumes from exactly its prefill state.

    ``finite`` is the per-row non-finite logits guard: True iff the
    row's final logits contain no NaN/Inf. A poisoned row (numerical
    blow-up, corrupted cache, injected fault) emits ``pad_id`` and does
    NOT advance its position, so the engine can quarantine exactly that
    slot without the bad row contaminating sampling (NaN logits would
    otherwise argmax to token 0 / NaN-propagate through the gumbel
    draw). ``poison`` (B,) bool, optional, overwrites masked rows'
    logits with NaN *before* the guard — the fault-injection hook;
    passing None adds nothing to the jaxpr.

    ``greedy=True`` builds the all-greedy variant with the same
    signature but plain argmax — no vocab sort / gumbel draw in the
    jaxpr. The engine dispatches it whenever no resident request
    samples; tokens are bit-identical to the sampled step at
    temperature 0, so switching between the two is free."""

    def engine_step(params, cache, tok, base_keys, gen_count, temperature,
                    top_k, top_p, active, poison=None):
        logits, cache, _ = T.forward(params, cfg, tokens=tok, cache=cache)
        row = logits[:, -1]
        if poison is not None:
            row = jnp.where(poison[:, None], jnp.nan, row)
        finite = jnp.all(jnp.isfinite(row), axis=-1)
        if greedy:
            nxt = jnp.argmax(row, axis=-1).astype(jnp.int32)
        else:
            keys = smp.fold_keys(base_keys, gen_count)
            # quarantined rows sample from zeros, not NaN: the sampled
            # value is discarded (finite=False forces pad below) but NaN
            # here would make the gumbel argmax lane undefined
            safe = jnp.where(finite[:, None], row, 0.0)
            nxt = smp.sample_logits(safe, keys,
                                    temperature=temperature,
                                    top_k=top_k, top_p=top_p)
        ok = active & finite
        nxt = jnp.where(ok, nxt, pad_id).astype(tok.dtype)
        cache["pos"] = jnp.where(ok, cache["pos"], cache["pos"] - 1)
        return nxt[:, None], finite, cache

    return engine_step


# ----------------------------------------------------------------------
# paged engine heads (block-table slots over a shared block pool)
# ----------------------------------------------------------------------

def _paged_gather(pool: Params, view_idx: jax.Array) -> Params:
    """Gather each slot's block chain into a contiguous linear view.

    ``pool`` leaves are (n, NB, bs, …) group-stacked / (NB, bs, …)
    trailing; ``view_idx`` (B, V) maps view row -> flat pool row
    (``PagedCacheLayout.view_index``). Returns leaves (n, B, V, …) /
    (B, V, …) — exactly the linear cache the unchanged forward expects.
    Sentinel (unallocated) entries clip to the last pool row; they sit
    beyond every row's valid length so attention never reads them."""
    B, V = view_idx.shape
    flat_idx = view_idx.reshape(-1)

    def g_groups(a):
        n, NB, bs = a.shape[:3]
        flat = a.reshape((n, NB * bs) + a.shape[3:])
        # mode="clip", NOT the NaN-filling default: sentinel entries sit
        # beyond valid_len, but NaN would poison the kernels' softmax
        return jnp.take(flat, flat_idx, axis=1, mode="clip").reshape(
            (n, B, V) + a.shape[3:])

    def g_trail(a):
        NB, bs = a.shape[:2]
        flat = a.reshape((NB * bs,) + a.shape[2:])
        return jnp.take(flat, flat_idx, axis=0, mode="clip").reshape(
            (B, V) + a.shape[2:])

    return {"groups": [jax.tree.map(g_groups, g) for g in pool["groups"]],
            "trailing": [jax.tree.map(g_trail, t) for t in pool["trailing"]]}


def _paged_scatter(pool: Params, view: Params, fill_idx: jax.Array,
                   positions: jax.Array) -> Params:
    """Write the view rows at ``positions`` (B, S) back through the block
    tables: ``fill_idx`` (B, S) holds flat pool rows (sentinel = out of
    bounds, dropped) from ``fill_index``/``write_index``. Only the named
    rows move — shared prefix blocks other slots reference are never
    touched because their view rows are not in ``positions``."""
    def s_groups(a, v):
        n, NB, bs = a.shape[:3]
        idx = positions.reshape((1,) + positions.shape + (1,) * (v.ndim - 3))
        rows = jnp.take_along_axis(v, idx, axis=2)       # (n, B, S, …)
        flat = a.reshape((n, NB * bs) + a.shape[3:])
        flat = flat.at[:, fill_idx].set(rows.astype(a.dtype), mode="drop")
        return flat.reshape(a.shape)

    def s_trail(a, v):
        NB, bs = a.shape[:2]
        idx = positions.reshape(positions.shape + (1,) * (v.ndim - 2))
        rows = jnp.take_along_axis(v, idx, axis=1)       # (B, S, …)
        flat = a.reshape((NB * bs,) + a.shape[2:])
        flat = flat.at[fill_idx].set(rows.astype(a.dtype), mode="drop")
        return flat.reshape(a.shape)

    return {"groups": [jax.tree.map(s_groups, g, vg)
                       for g, vg in zip(pool["groups"], view["groups"])],
            "trailing": [jax.tree.map(s_trail, t, vt)
                         for t, vt in zip(pool["trailing"],
                                          view["trailing"])]}


def make_paged_engine_prefill(cfg: ModelConfig, layout) -> Callable:
    """paged_prefill(params, pool, tables, tokens, lengths, bases,
    base_keys, temperature, top_k, top_p) -> (first_tok (B, 1), pool).

    Suffix-only admission prefill over a paged pool: ``tokens`` is the
    right-padded UNCACHED suffix of each prompt, ``bases`` (B,) the
    cached-prefix length admission matched (prefix rows already sit in
    the shared blocks ``tables`` points at). The forward runs with
    per-row positions ``base + t`` and its new latents scatter back
    through the tables (``fill_index`` — padding drops). ``layout`` is
    the arena's ``PagedCacheLayout``; first-token sampling matches
    ``make_engine_prefill`` bit-for-bit (same keys, same fold)."""
    assert cfg.input_mode == "tokens", "the engine is token-mode only"

    def paged_prefill(params, pool, tables, tokens, lengths, bases,
                      base_keys, temperature, top_k=0, top_p=1.0):
        B, S = tokens.shape
        view = _paged_gather(pool, layout.view_index(tables))
        view["pos"] = bases.astype(jnp.int32)
        logits, view, _ = T.forward(params, cfg, tokens=tokens, cache=view,
                                    lengths=lengths)
        last = jnp.take_along_axis(
            logits, (lengths - 1)[:, None, None], axis=1)[:, 0]
        keys = smp.fold_keys(base_keys, jnp.zeros((B,), jnp.uint32))
        tok0 = smp.sample_logits(last, keys, temperature=temperature,
                                 top_k=top_k, top_p=top_p)
        positions = bases[:, None].astype(jnp.int32) \
            + jnp.arange(S, dtype=jnp.int32)[None, :]
        fill = layout.fill_index(tables, positions, lengths)
        pool = _paged_scatter(pool, view, fill, positions)
        return tok0[:, None].astype(tokens.dtype), pool

    return paged_prefill


def make_paged_engine_step(cfg: ModelConfig, layout, pad_id: int = 0,
                           greedy: bool = False) -> Callable:
    """paged_step(params, pool, tables, pos, tok, base_keys, gen_count,
    temperature, top_k, top_p, active[, poison]) -> (next_tok (B, 1),
    finite (B,), pool).

    STILL one fused dispatch per serving step: gather the block tables
    into a contiguous view, run the unchanged ragged engine step (same
    kernels, same sampling — the gathered view is bit-identical to a
    linear arena at equal ``max_len``), then scatter ONLY the newly
    written row per slot back through the tables. The host tracks
    positions (``pos`` (B,)); inactive slots' writes drop at the
    sentinel. ``finite``/``poison`` are the same non-finite guard /
    fault hook as ``make_engine_step`` — a quarantined row's scatter is
    ALSO dropped (its latent row may be poisoned, and paged blocks are
    shared state). The whole body jits as one computation — gather,
    forward, sample, scatter fuse into a single executable."""
    inner = make_engine_step(cfg, pad_id, greedy)

    def paged_step(params, pool, tables, pos, tok, base_keys, gen_count,
                   temperature, top_k, top_p, active, poison=None):
        view = _paged_gather(pool, layout.view_index(tables))
        view["pos"] = pos.astype(jnp.int32)
        nxt, finite, view = inner(params, view, tok, base_keys, gen_count,
                                  temperature, top_k, top_p, active, poison)
        wpos = pos[:, None].astype(jnp.int32)
        flat = layout.write_index(tables, wpos)
        flat = jnp.where((active & finite)[:, None], flat, layout.sentinel)
        pool = _paged_scatter(pool, view, flat, wpos)
        return nxt, finite, pool

    return paged_step
