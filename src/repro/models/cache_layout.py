"""CacheLayout: the single mapping from token positions to cache slots.

Before this abstraction the cache model was smeared across four layers —
the kernels assumed a ``valid_len`` *prefix*, ``models/layers.py`` kept
its own modulo arithmetic for windowed writes, the serving arena assumed
linear per-slot positions, and the Engine rejected windowed configs
outright. ``CacheLayout`` names the two layouts explicitly and owns every
piece of slot arithmetic the stack shares:

* **linear** (``window is None``): slot ``t`` holds absolute position
  ``t``; validity is the prefix ``t <= pos`` the decode kernels encode as
  ``valid_len``.
* **ring** (``window = w``): a cache of ``n = min(max_len, w)`` slots
  where slot ``t`` holds the LARGEST absolute position ``p ≡ t (mod n)``
  with ``p <= pos`` — writes go to ``p % n`` and wrap. Validity is a
  contiguous ring segment described by ``(start, length)``: the ring
  decode kernels mask ``(t - start) mod n < length`` instead of a prefix.
* **paged** (``PagedCacheLayout``): block-table indirection over a flat
  refcounted block pool (serve/block_pool.py) — slot rows live in
  fixed-size blocks scattered through the pool, and a per-slot table
  maps logical block index to physical block id. Gathering the table
  yields a contiguous linear view, so validity/abs_positions are the
  linear rules; only the write/fill indices go through the table.

All arithmetic is int32-overflow-safe at large absolute positions: the
old formulation ``(pos // n) * n + slot`` exceeds ``pos`` by up to
``n - 1`` (wraps within ``n`` of ``2**31``), and the retired
``BIG_WINDOW = 1 << 30`` sentinel made ``pos - window`` a trap; here
every comparison is phrased on bounded differences (``pos - abs_pos`` is
always in ``[0, n)``).

Shapes: ``positions`` is either ``(S,)`` shared across the batch (train /
prefill / lockstep decode) or ``(B, S)`` per-row (the serving engine's
ragged decode, ``S == 1``); results broadcast accordingly, exactly like
the pre-refactor helpers in ``models/layers.py`` (which now delegate
here).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class CacheLayout:
    """How a cache of ``cache_len`` physical slots maps absolute token
    positions to slots. ``window=None`` is a linear prefix cache;
    ``window=w`` is a ring holding the trailing ``w``-token window."""

    cache_len: int
    window: Optional[int] = None

    def __post_init__(self):
        if self.cache_len < 1:
            raise ValueError(f"cache_len must be >= 1, got {self.cache_len}")
        if self.window is not None and self.window < 1:
            raise ValueError(f"window must be >= 1, got {self.window}")

    @staticmethod
    def make(max_len: int, window: Optional[int] = None) -> "CacheLayout":
        """Layout for a cache sized for ``max_len`` tokens: a window
        shrinks the physical slot count to ``min(max_len, window)``."""
        n = min(max_len, window) if window else max_len
        return CacheLayout(n, window)

    @property
    def is_ring(self) -> bool:
        return self.window is not None

    @property
    def span(self) -> int:
        """Most tokens ever simultaneously valid in this cache."""
        return min(self.cache_len, self.window) if self.is_ring \
            else self.cache_len

    # -- slot arithmetic ----------------------------------------------
    def write_index(self, positions: jax.Array) -> jax.Array:
        """Physical slot for a token at each absolute position."""
        return positions % self.cache_len if self.is_ring else positions

    def abs_positions(self, positions: jax.Array) -> jax.Array:
        """Absolute position held by each slot, given the just-written
        ``positions``. Returns ``(cache_len,)`` for shared positions,
        ``(B, cache_len)`` for per-row ``(B, S)`` positions. Ring slots
        report the latest position congruent mod ``cache_len`` that is
        ``<= pos`` (which may be negative = never written)."""
        slots = jnp.arange(self.cache_len)
        cur = positions[..., -1]
        if positions.ndim == 2:
            cur = cur[:, None]
        if not self.is_ring:
            if positions.ndim == 2:
                return jnp.broadcast_to(slots, cur.shape[:-1]
                                        + (self.cache_len,))
            return slots
        # overflow-safe: cur - slots >= cur - n, and the mod result is in
        # [0, n), so abs_pos ∈ (cur - n, cur] without ever exceeding cur
        return cur - (cur - slots) % self.cache_len

    def validity(self, positions: jax.Array) -> jax.Array:
        """Bool mask of slots holding live tokens after writing
        ``positions``; ``(cache_len,)`` shared or ``(B, cache_len)``
        per-row. Ring validity keeps slots whose token is at most
        ``window - 1`` behind the current position."""
        cur = positions[..., -1]
        if positions.ndim == 2:
            cur = cur[:, None]
        abs_pos = self.abs_positions(positions)
        if not self.is_ring:
            return (abs_pos <= cur) & (abs_pos >= 0)
        # cur - abs_pos ∈ [0, n): bounded, no sentinel subtraction
        return (abs_pos >= 0) & (cur - abs_pos < self.window)

    def ring_state(self, positions: jax.Array) -> Tuple[jax.Array, jax.Array]:
        """The ``(start, length)`` ring descriptor the ring kernels mask
        with: valid slots are exactly ``(start + i) % cache_len`` for
        ``i < length``. Shapes follow ``positions[..., -1]`` (scalar or
        ``(B,)``). For a linear layout this degenerates to
        ``(0, min(pos + 1, cache_len))`` — the kernels' prefix."""
        cur = positions[..., -1]
        span = self.span
        # phrased as a select so cur + 1 never feeds the result when cur
        # is large (int32 wrap would otherwise poison the minimum)
        length = jnp.where(cur >= span - 1, span, cur + 1).astype(jnp.int32)
        length = jnp.maximum(length, 0)
        if not self.is_ring:
            return jnp.zeros_like(length), length
        start = self.write_index(cur - jnp.maximum(length - 1, 0))
        return start.astype(jnp.int32), length

    def fill_index(self, positions: jax.Array, lengths: jax.Array) -> jax.Array:
        """Per-row scatter slots for a right-padded prefill chunk.

        ``positions``: (S,) the chunk's absolute positions shared across
        rows, or (B, S) per-row positions (chunked prefill, where each
        row resumes from its own carry-in base); ``lengths``: (B,) true
        token counts per row (the rest is right-padding). Returns (B, S)
        int32 slots where each row writes only ITS last ``min(length,
        cache_len)`` real tokens; every other entry gets the
        out-of-bounds sentinel ``cache_len`` so a ``mode='drop'``
        scatter skips it. This is what makes ragged ring admission safe:
        a shorter row's padding positions wrap onto the same slots as
        its real tokens and would clobber them under a shared trailing
        write."""
        if positions.ndim == 2:
            last = positions[:, 0] + lengths - 1              # (B,)
            keep = (positions <= last[:, None]) & \
                (positions > (last - self.cache_len)[:, None])
            return jnp.where(keep, self.write_index(positions),
                             self.cache_len).astype(jnp.int32)
        last = positions[0] + lengths - 1                     # (B,)
        keep = (positions[None, :] <= last[:, None]) & \
            (positions[None, :] > last[:, None] - self.cache_len)
        return jnp.where(keep, self.write_index(positions)[None, :],
                         self.cache_len).astype(jnp.int32)


@dataclasses.dataclass(frozen=True)
class PagedCacheLayout:
    """Block-table indirection for a paged latent cache.

    A slot's ``cache_len`` logical rows live in ``cache_len //
    block_size`` fixed-size blocks drawn from a flat pool of
    ``num_blocks`` physical blocks (``serve.block_pool.BlockPool``
    owns the refcounts). ``tables`` (B, blocks_per_slot) int32 maps
    logical block index -> physical block id; the sentinel id
    ``num_blocks`` marks an unallocated table entry, and every method
    arranges for sentinel-backed rows to land OUT OF BOUNDS of the
    ``num_blocks * block_size``-row flat pool so a ``mode='drop'``
    scatter skips them.

    The decode/prefill hot path never indexes blocks directly: the
    engine gathers ``view_index`` rows into a contiguous (B, cache_len)
    linear view, runs the UNCHANGED linear kernels over it, and
    scatters the freshly written rows back through ``write_index`` /
    ``fill_index``. Validity and abs_positions on the gathered view are
    therefore exactly the linear ``CacheLayout`` rules — delegated."""

    cache_len: int
    block_size: int
    num_blocks: int

    def __post_init__(self):
        if self.block_size < 1:
            raise ValueError(f"block_size must be >= 1, got {self.block_size}")
        if self.num_blocks < 1:
            raise ValueError(f"num_blocks must be >= 1, got {self.num_blocks}")
        if self.cache_len < 1 or self.cache_len % self.block_size != 0:
            raise ValueError(
                f"cache_len ({self.cache_len}) must be a positive multiple "
                f"of block_size ({self.block_size}): the gathered view must "
                f"tile exactly into pool blocks")

    @property
    def blocks_per_slot(self) -> int:
        return self.cache_len // self.block_size

    @property
    def sentinel(self) -> int:
        """Flat pool row that a ``mode='drop'`` scatter discards."""
        return self.num_blocks * self.block_size

    # -- block-table indirection --------------------------------------
    def view_index(self, tables: jax.Array) -> jax.Array:
        """(B, blocks_per_slot) tables -> (B, cache_len) flat pool rows
        gathering each slot's contiguous linear view. Sentinel entries
        produce out-of-range rows (gathers clamp; the rows they fetch
        are masked garbage)."""
        B = tables.shape[0]
        off = jnp.arange(self.block_size, dtype=jnp.int32)
        rows = tables[..., None] * self.block_size + off[None, None, :]
        return rows.reshape(B, self.cache_len).astype(jnp.int32)

    def write_index(self, tables: jax.Array, positions: jax.Array) -> jax.Array:
        """Flat pool row for a token at each absolute position.

        ``positions`` (B, S) per-row absolute positions (< cache_len);
        returns (B, S) rows ``table[b, p // bs] * bs + p % bs``. Entries
        whose table slot is the sentinel land out of bounds."""
        blk = jnp.take_along_axis(tables, positions // self.block_size,
                                  axis=1)
        return (blk * self.block_size
                + positions % self.block_size).astype(jnp.int32)

    def fill_index(self, tables: jax.Array, positions: jax.Array,
                   lengths: jax.Array) -> jax.Array:
        """Per-row scatter rows for a right-padded prefill chunk.

        ``positions`` (B, S) per-row absolute positions; ``lengths``
        (B,) true token counts (the rest is right-padding). Real tokens
        map through the block table; padding gets the out-of-bounds
        sentinel so a ``mode='drop'`` scatter skips it."""
        S = positions.shape[1]
        keep = jnp.arange(S)[None, :] < lengths[:, None]
        idx = self.write_index(tables, jnp.where(keep, positions, 0))
        return jnp.where(keep, idx, self.sentinel).astype(jnp.int32)

    # -- linear-view delegation ---------------------------------------
    def validity(self, positions: jax.Array) -> jax.Array:
        return CacheLayout(self.cache_len).validity(positions)

    def abs_positions(self, positions: jax.Array) -> jax.Array:
        return CacheLayout(self.cache_len).abs_positions(positions)
