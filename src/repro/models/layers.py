"""Layer zoo: norms, RoPE, attention (dense + latent/MLA), MLP, MoE, Mamba2 SSD.

Pure-JAX functional modules: ``init_*`` build param pytrees (fp32),
``*_fwd`` apply them (compute in cfg.dtype, reductions in fp32).
"""
from __future__ import annotations

import functools
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig, dtype_of
from repro.distributed.constraints import (constrain, constrain_bsd,
                                           constrain_bsf, constrain_heads)
from repro.kernels import ops as kops
from repro.kernels import quant as kquant
from repro.models.cache_layout import CacheLayout

Params = Dict[str, Any]

# ----------------------------------------------------------------------
# helpers
# ----------------------------------------------------------------------

def _init_dense(key, d_in, d_out, bias: bool, scale: Optional[float] = None) -> Params:
    scale = scale if scale is not None else 1.0 / math.sqrt(d_in)
    p = {"w": jax.random.normal(key, (d_in, d_out), jnp.float32) * scale}
    if bias:
        p["b"] = jnp.zeros((d_out,), jnp.float32)
    return p


def dense(p: Params, x: jax.Array) -> jax.Array:
    y = x @ p["w"].astype(x.dtype)
    if "b" in p:
        y = y + p["b"].astype(x.dtype)
    return y


def init_norm(cfg: ModelConfig, d: int) -> Params:
    if cfg.norm == "layernorm":
        return {"scale": jnp.ones((d,), jnp.float32), "bias": jnp.zeros((d,), jnp.float32)}
    return {"scale": jnp.ones((d,), jnp.float32)}


def norm_fwd(p: Params, x: jax.Array) -> jax.Array:
    xf = x.astype(jnp.float32)
    if "bias" in p:  # layernorm
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mu) * lax.rsqrt(var + 1e-5) * p["scale"] + p["bias"]
    else:  # rmsnorm
        ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * lax.rsqrt(ms + 1e-6) * p["scale"]
    return y.astype(x.dtype)


def _activation(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu, "relu": jax.nn.relu}[name]


# ----------------------------------------------------------------------
# RoPE
# ----------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    return theta ** (-jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim)


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, Dh); positions: (..., S)."""
    dh = x.shape[-1]
    freqs = rope_frequencies(dh, theta)  # (Dh/2,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., S, Dh/2)
    cos = jnp.cos(angles)[..., :, None, :]
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ----------------------------------------------------------------------
# Attention (dense MHA/GQA + sliding window + softcap) with blocked softmax
# ----------------------------------------------------------------------

def init_attention(key, cfg: ModelConfig) -> Params:
    ks = jax.random.split(key, 4)
    d = cfg.d_model
    return {
        "q": _init_dense(ks[0], d, cfg.q_dim, cfg.qkv_bias),
        "k": _init_dense(ks[1], d, cfg.kv_dim, cfg.qkv_bias),
        "v": _init_dense(ks[2], d, cfg.kv_dim, cfg.qkv_bias),
        "o": _init_dense(ks[3], cfg.q_dim, d, cfg.o_bias),
    }


def _gqa_scores(q, k, scale, softcap):
    """Grouped-head scores without materializing repeated KV.

    q: (B, qb, G, R, Dh), k: (B, S, G, Dh) -> (B, G, R, qb, S) fp32.

    NOTE: the matmul emits the input dtype and is upcast AFTERWARDS — the
    MXU accumulates in fp32 either way, but an explicit cast (vs
    preferred_element_type=f32) keeps the *backward* cotangents in bf16,
    halving the TP all-reduce bytes of dL/dx (measured in §Perf)."""
    s = jnp.einsum("bqgrd,bsgd->bgrqs", q, k).astype(jnp.float32)
    s = s * scale
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    return s


def _gqa_values(a, v):
    """a: (B, G, R, qb, S) in x-dtype, v: (B, S, G, Dh) -> (B, qb, G, R, Dh)."""
    return jnp.einsum("bgrqs,bsgd->bqgrd", a, v)


def attention_fwd(
    p: Params,
    x: jax.Array,
    cfg: ModelConfig,
    *,
    positions: jax.Array,
    window: Optional[int] = None,
    cache: Optional[Params] = None,
    lengths: Optional[jax.Array] = None,
    q_block: int = 512,
) -> Tuple[jax.Array, Optional[Params]]:
    """Dense attention. x: (B, S, d); positions: (S,) shared across batch
    (keeps masks batch-free: (qb, S) instead of (B, qb, S)) — except the
    decode step, which also accepts per-row (B, 1) positions (the
    serving engine's ragged slots). ``cache``:
    S == 1  -> decode step (scatter one token, attend over cache)
    S > 1   -> prefill (full blocked attention + cache fill).
    ``lengths`` (B,) marks the true token count of a right-padded ragged
    prefill so the cache fill writes each row's own trailing window
    (required for ring caches — see ``_prefill_fill``)."""
    B, S, _ = x.shape
    H, Hkv, Dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    R = H // Hkv
    if FUSED_PROJECTIONS:
        # fused QKV: one matmul -> ONE dL/dx all-reduce in the backward
        # instead of three. MEASURED NET-NEGATIVE with FSDP-sharded
        # separate leaves (the runtime concat reshards the gathered
        # weights, §Perf/A3) — kept behind a flag; the winning variant
        # needs pre-fused parameter leaves.
        w_qkv = jnp.concatenate(
            [p["q"]["w"], p["k"]["w"], p["v"]["w"]], axis=1).astype(x.dtype)
        qkv = constrain_bsf(x @ w_qkv)
        if "b" in p["q"]:
            qkv = qkv + jnp.concatenate(
                [p["q"]["b"], p["k"]["b"], p["v"]["b"]]).astype(x.dtype)
        q, k, v = jnp.split(qkv, [H * Dh, H * Dh + Hkv * Dh], axis=-1)
        q = q.reshape(B, S, Hkv, R, Dh)
        k = k.reshape(B, S, Hkv, Dh)
        v = v.reshape(B, S, Hkv, Dh)
    else:
        q = constrain_bsf(dense(p["q"], x)).reshape(B, S, Hkv, R, Dh)
        k = constrain_bsf(dense(p["k"], x)).reshape(B, S, Hkv, Dh)
        v = constrain_bsf(dense(p["v"], x)).reshape(B, S, Hkv, Dh)
    if cfg.pos_emb == "rope":
        q = apply_rope(q.reshape(B, S, H, Dh), positions, cfg.rope_theta)
        q = q.reshape(B, S, Hkv, R, Dh)
        k = apply_rope(k, positions, cfg.rope_theta)
    scale = 1.0 / math.sqrt(Dh)

    if cache is not None and S == 1:
        layout = CacheLayout(cache["k"].shape[1], window)
        write_idx = layout.write_index(positions)
        ck = _scatter_cache(cache["k"], k, write_idx)
        cv = _scatter_cache(cache["v"], v, write_idx)
        new_cache = {"k": ck, "v": cv}
        valid = layout.validity(positions)
        s = _gqa_scores(q, ck, scale, cfg.attn_logit_softcap)
        s = jnp.where(_expand_valid(valid), s, -1e30)
        a = jax.nn.softmax(s, axis=-1).astype(x.dtype)
        y = _gqa_values(a, cv).reshape(B, S, H * Dh)
        return dense(p["o"], constrain_bsf(y)), new_cache

    # training / prefill: scan over query blocks (row-blocked softmax).
    # Sharding: head dims on 'model' when they divide, else the QUERY rows
    # (sequence-parallel attention) — never Dh (see constrain_heads).
    assert positions.ndim == 1, "per-row positions are decode-only (S == 1)"
    k = constrain_heads(k, head_dims=(2,), seq_dim=None)
    v = constrain_heads(v, head_dims=(2,), seq_dim=None)
    qb = min(q_block, S)
    n_blocks = S // qb
    assert S % qb == 0, (S, qb)
    q_blocks = q.reshape(B, n_blocks, qb, Hkv, R, Dh).transpose(1, 0, 2, 3, 4, 5)
    pos_blocks = positions.reshape(n_blocks, qb)
    k_pos = positions  # (S,)

    def body(_, inp):
        qi, pi = inp
        qi = constrain_heads(qi, head_dims=(2, 3), seq_dim=1)
        s = _gqa_scores(qi, k, scale, cfg.attn_logit_softcap)
        m = k_pos[None, :] <= pi[:, None]  # (qb, S)
        if window is not None:
            # bounded difference (both positions live in this chunk) —
            # never `pi - window`, which underflows for sentinel windows
            m &= (pi[:, None] - k_pos[None, :]) < window
        s = jnp.where(m[None, None, None, :, :], s, -1e30)
        a = jax.nn.softmax(s, axis=-1).astype(x.dtype)
        # pin the output to the SAME layout as the scores so GSPMD never
        # reshards the S² tensor (§Perf/B2: 30 TB involuntary regather)
        return None, constrain_heads(_gqa_values(a, v), head_dims=(2, 3),
                                     seq_dim=1)

    _, y = lax.scan(body, None, (q_blocks, pos_blocks))
    # (n, B, qb, Hkv, R, Dh) -> (B, S, H*Dh)
    y = y.transpose(1, 0, 2, 3, 4, 5).reshape(B, S, H * Dh)
    y = dense(p["o"], constrain_bsf(y))

    new_cache = None
    if cache is not None:  # prefill: fill cache with the trailing window
        layout = CacheLayout(cache["k"].shape[1], window)
        new_cache = {
            "k": _prefill_fill(cache["k"], k, layout, positions, lengths),
            "v": _prefill_fill(cache["v"], v, layout, positions, lengths),
        }
    return y, new_cache


def _cache_validity(positions, cache_len, window):
    """Validity mask per cache slot (ring-aware; delegates to
    ``CacheLayout`` — the one place the slot arithmetic lives).

    positions: (S,) shared across batch, or (B, S) per-row (the serving
    engine's ragged decode: every slot sits at its own position). The
    just-written absolute positions; returns (cache_len,) bool when
    shared, (B, cache_len) when per-row."""
    return CacheLayout(cache_len, window).validity(positions)


def _expand_valid(valid: jax.Array) -> jax.Array:
    """Broadcast a validity mask against (B, G, R, q, T) scores."""
    if valid.ndim == 2:  # per-row (B, T)
        return valid[:, None, None, None, :]
    return valid[None, None, None, None, :]


def _scatter_cache(cache: jax.Array, new: jax.Array, idx: jax.Array) -> jax.Array:
    """cache: (B, Smax, ...); new: (B, S, ...); idx: (S,) shared slot
    indices, or (B, S) per-row slot indices (ragged decode). Out-of-
    bounds indices (the ``cache_len`` sentinel) are dropped."""
    if idx.ndim == 2:
        rows = jnp.arange(cache.shape[0])[:, None]
        return cache.at[rows, idx].set(new.astype(cache.dtype), mode="drop")
    return cache.at[:, idx].set(new.astype(cache.dtype), mode="drop")


def _prefill_fill(old: jax.Array, new: jax.Array, layout: CacheLayout,
                  positions: jax.Array, lengths: Optional[jax.Array]) -> jax.Array:
    """Write a prefilled chunk into a cache leaf, ring- and ragged-aware.

    old: (B, n, ...); new: (B, S, ...); positions: (S,) chunk positions.
    Shared path (``lengths is None``, lockstep prefill): every row writes
    the trailing ``min(S, n)`` tokens at their layout slots. Ragged path
    (``lengths`` (B,), the engine's right-padded admission): each row
    writes only ITS own trailing window — padding and pre-window history
    get the OOB sentinel and are dropped, so a short row's ring is never
    clobbered by padding positions that wrap onto its real slots."""
    if lengths is None:
        take = min(new.shape[1], layout.cache_len)
        return _scatter_cache(old, new[:, -take:],
                              layout.write_index(positions[-take:]))
    idx = layout.fill_index(positions, lengths)        # (B, S), sentinel n
    rows = jnp.arange(old.shape[0])[:, None]
    return old.at[rows, idx].set(new.astype(old.dtype), mode="drop")


def _quant_scatter(cache: Params, c_k: jax.Array, c_v: jax.Array,
                   idx: jax.Array) -> Params:
    """Quantize-on-write into an int8 latent cache (decode / carry-in).

    Fresh fp latents are row-quantized and the int8 values + fp32 scale
    columns are scattered with the SAME indices — the four leaves stay
    slot-aligned by construction."""
    qk, sk = kquant.quantize_rows(c_k)
    qv, sv = kquant.quantize_rows(c_v)
    return {
        "c_k": _scatter_cache(cache["c_k"], qk, idx),
        "ck_scale": _scatter_cache(cache["ck_scale"], sk, idx),
        "c_v": _scatter_cache(cache["c_v"], qv, idx),
        "cv_scale": _scatter_cache(cache["cv_scale"], sv, idx),
    }


def _quant_fill(cache: Params, c_k: jax.Array, c_v: jax.Array,
                layout: CacheLayout, positions: jax.Array,
                lengths: Optional[jax.Array]) -> Params:
    """Quantize-on-write prefill fill (ring- and ragged-aware)."""
    qk, sk = kquant.quantize_rows(c_k)
    qv, sv = kquant.quantize_rows(c_v)
    return {
        "c_k": _prefill_fill(cache["c_k"], qk, layout, positions, lengths),
        "ck_scale": _prefill_fill(cache["ck_scale"], sk, layout, positions,
                                  lengths),
        "c_v": _prefill_fill(cache["c_v"], qv, layout, positions, lengths),
        "cv_scale": _prefill_fill(cache["cv_scale"], sv, layout, positions,
                                  lengths),
    }


def init_attention_cache(cfg: ModelConfig, batch: int, max_len: int,
                         window: Optional[int] = None) -> Params:
    n = CacheLayout.make(max_len, window).cache_len
    shape = (batch, n, cfg.num_kv_heads, cfg.head_dim)
    return {
        "k": jnp.zeros(shape, dtype_of(cfg)),
        "v": jnp.zeros(shape, dtype_of(cfg)),
    }


# ----------------------------------------------------------------------
# Latent (MLA) attention — the paper's compressed attention (§4.1/§4.2)
# ----------------------------------------------------------------------

def init_latent_attention(key, cfg: ModelConfig, r_q: int, r_k: int, r_v: int,
                          r_o: int) -> Params:
    """Random-init latent attention (real weights come from core.compress)."""
    ks = jax.random.split(key, 8)
    d, H, Hkv, Dh = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    s = lambda *sh: 1.0 / math.sqrt(sh[0])
    p = {
        # shared compression planes (paper: A_q, A_k, A_v stored block-identity)
        "a_q": jax.random.normal(ks[0], (d, r_q), jnp.float32) * s(d),
        "a_k": jax.random.normal(ks[1], (d, r_k), jnp.float32) * s(d),
        "a_v": jax.random.normal(ks[2], (d, r_v), jnp.float32) * s(d),
        # per-head decompression
        "b_q": jax.random.normal(ks[3], (H, r_q, Dh), jnp.float32) * s(r_q),
        "b_k": jax.random.normal(ks[4], (Hkv, r_k, Dh), jnp.float32) * s(r_k),
        "b_v": jax.random.normal(ks[5], (Hkv, r_v, Dh), jnp.float32) * s(r_v),
        # output: local low-rank W_o ≈ A_o · B_o  (in->r_o->d)
        "a_o": jax.random.normal(ks[6], (H * Dh, r_o), jnp.float32) * s(H * Dh),
        "b_o": jax.random.normal(ks[7], (r_o, d), jnp.float32) * s(r_o),
    }
    if cfg.qkv_bias:
        p["bias_q"] = jnp.zeros((H * Dh,), jnp.float32)
        p["bias_k"] = jnp.zeros((Hkv * Dh,), jnp.float32)
        p["bias_v"] = jnp.zeros((Hkv * Dh,), jnp.float32)
    if cfg.o_bias:
        p["bias_o"] = jnp.zeros((d,), jnp.float32)
    return p


def latent_attention_fwd(
    p: Params,
    x: jax.Array,
    cfg: ModelConfig,
    *,
    positions: jax.Array,
    window: Optional[int] = None,
    cache: Optional[Params] = None,
    lengths: Optional[jax.Array] = None,
    q_block: int = 512,
    ring_span: Optional[int] = None,
) -> Tuple[jax.Array, Optional[Params]]:
    """MLA forward. The KV cache holds *latent* c_k=(B,S,r_k), c_v=(B,S,r_v):
    the paper's KV-cache reduction. Decode uses the ABSORBED form
    (q̃ᵢ = Hᵢᵀ A_q x scores directly against latent keys, values are reduced
    in latent space) — DeepSeek-style MLA absorption, no per-token
    decompression. RoPE models fall back to decompress-then-rope (decoupled
    RoPE approximation; App. F.3 discusses window-limited RoPE awareness).
    ``positions`` is (S,) shared across batch; the decode step (S == 1)
    also accepts per-row (B, 1) positions for ragged serving slots.
    ``lengths`` (B,) marks true row lengths of a right-padded ragged
    prefill (cache fill per row — see ``_prefill_fill``). Sliding-window
    layers run over a ring ``CacheLayout``: writes wrap mod ``cache_len``
    and the absorbed decode dispatches the (start, length) ring kernels
    instead of falling back to einsum."""
    B, S, _ = x.shape
    H, Hkv, Dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    R = H // Hkv
    c_q = x @ p["a_q"].astype(x.dtype)  # (B,S,r_q)
    c_k = x @ p["a_k"].astype(x.dtype)  # (B,S,r_k)
    c_v = x @ p["a_v"].astype(x.dtype)  # (B,S,r_v)

    def decomp(c, b, bias, nheads):
        y = jnp.einsum("bsr,hrd->bshd", c, b.astype(c.dtype))
        if bias is not None:
            y = y + bias.astype(c.dtype).reshape(1, 1, nheads, Dh)
        return y

    scale = 1.0 / math.sqrt(Dh)
    use_absorbed = cfg.pos_emb != "rope" and not cfg.qkv_bias
    quantized = cache is not None and kquant.is_quantized_cache(cache)

    if cache is not None and S == 1:
        layout = CacheLayout(cache["c_k"].shape[1], window)
        write_idx = layout.write_index(positions)
        if quantized:
            new_cache = _quant_scatter(cache, c_k, c_v, write_idx)
        else:
            new_cache = {
                "c_k": _scatter_cache(cache["c_k"], c_k, write_idx),
                "c_v": _scatter_cache(cache["c_v"], c_v, write_idx),
            }
        ck, cv = new_cache["c_k"], new_cache["c_v"]
        if use_absorbed:
            # Fused grouped decode kernel: absorption -> latent attention
            # -> per-head value decompression in ONE pallas_call. Linear
            # caches mask a valid_len prefix; ring (windowed) caches
            # dispatch the (start, length) ring variant — sliding-window
            # configs keep the fast path instead of an einsum fallback.
            # Under a mesh the kernel runs per-shard (heads on 'model')
            # when Hkv divides, else the ref einsum path (ops.py).
            bq = p["b_q"].astype(x.dtype).reshape(Hkv, R, *p["b_q"].shape[1:])
            qt = jnp.einsum("bq,grqd,gKd->bgrK", c_q[:, 0], bq,
                            p["b_k"].astype(x.dtype))   # (B, Hkv, R, r_k)
            start, length = layout.ring_state(positions)
            bv = p["b_v"].astype(x.dtype)
            start_b = jnp.broadcast_to(start, (B,)).astype(jnp.int32)
            len_b = jnp.broadcast_to(length, (B,)).astype(jnp.int32)
            if layout.is_ring and quantized:
                yh = kops.mla_decode_grouped_ring_quant_sharded(
                    qt, ck, new_cache["ck_scale"], cv,
                    new_cache["cv_scale"], bv, start_b, len_b,
                    scale=scale, softcap=cfg.attn_logit_softcap)
            elif layout.is_ring:
                yh = kops.mla_decode_grouped_ring_sharded(
                    qt, ck, cv, bv, start_b, len_b,
                    scale=scale, softcap=cfg.attn_logit_softcap)
            elif quantized:
                yh = kops.mla_decode_grouped_quant_sharded(
                    qt, ck, new_cache["ck_scale"], cv,
                    new_cache["cv_scale"], bv, len_b,
                    scale=scale, softcap=cfg.attn_logit_softcap)
            else:
                yh = kops.mla_decode_grouped_sharded(
                    qt, ck, cv, bv, len_b,
                    scale=scale, softcap=cfg.attn_logit_softcap)
            y = yh.reshape(B, S, H * Dh)
        else:
            valid = layout.validity(positions)
            if quantized:
                ck = kquant.dequantize_rows(ck, new_cache["ck_scale"],
                                            x.dtype)
                cv = kquant.dequantize_rows(cv, new_cache["cv_scale"],
                                            x.dtype)
            k = decomp(ck, p["b_k"], p.get("bias_k"), Hkv)
            v = decomp(cv, p["b_v"], p.get("bias_v"), Hkv)
            q = decomp(c_q, p["b_q"], p.get("bias_q"), H)
            if cfg.pos_emb == "rope":
                abs_pos = layout.abs_positions(positions)
                q = apply_rope(q, positions, cfg.rope_theta)
                k = apply_rope(k, abs_pos, cfg.rope_theta)
            q = q.reshape(B, S, Hkv, R, Dh)
            s = _gqa_scores(q, k, scale, cfg.attn_logit_softcap)
            s = jnp.where(_expand_valid(valid), s, -1e30)
            a = jax.nn.softmax(s, axis=-1).astype(x.dtype)
            y = _gqa_values(a, v).reshape(B, S, H * Dh)
        y = (y @ p["a_o"].astype(y.dtype)) @ p["b_o"].astype(y.dtype)
        if "bias_o" in p:
            y = y + p["bias_o"].astype(y.dtype)
        return y, new_cache

    if cache is not None and use_absorbed and positions.ndim == 2:
        # Carry-in prefill: each row resumes at its own base position —
        # either a paged suffix prefill over a gathered contiguous view
        # whose rows [0, base) hold the prefix-cache hit, or a chunked
        # admission prefill continuing from the previous chunk's rows.
        assert lengths is not None, "carry-in prefill is ragged by definition"
        n = cache["c_k"].shape[1]
        layout = CacheLayout(n, window)
        bases = positions[:, 0].astype(jnp.int32)
        fill = layout.fill_index(positions, lengths)           # (B, S)
        bq = p["b_q"].astype(x.dtype).reshape(Hkv, R, *p["b_q"].shape[1:])
        qt = jnp.einsum("bsq,grqd,gKd->bgrsK", c_q, bq,
                        p["b_k"].astype(x.dtype)).reshape(B, H, S, -1)
        if window is None:
            # Linear / paged view: scatter the chunk latents in FIRST,
            # then run the flash kernel over the whole abs-aligned cache
            # — queries at absolute positions base + t (``q_offsets``),
            # keys masked at base + length. An int8 cache scatters
            # QUANTIZED chunk latents, so the chunk attends to itself
            # through the same quantizer its successors will see —
            # chunked and unchunked quant prefill stay consistent.
            if quantized:
                new_cache = _quant_scatter(cache, c_k, c_v, fill)
                u = kops.mla_prefill_quant_sharded(
                    qt, new_cache["c_k"], new_cache["ck_scale"],
                    new_cache["c_v"], new_cache["cv_scale"],
                    bases + lengths.astype(jnp.int32), scale=scale,
                    softcap=cfg.attn_logit_softcap, q_offsets=bases)
            else:
                ck = _scatter_cache(cache["c_k"], c_k, fill)
                cv = _scatter_cache(cache["c_v"], c_v, fill)
                new_cache = {"c_k": ck, "c_v": cv}
                u = kops.mla_prefill_sharded(
                    qt, ck, cv, bases + lengths.astype(jnp.int32),
                    scale=scale, softcap=cfg.attn_logit_softcap,
                    q_offsets=bases)
        else:
            # Windowed ring: the ring holds only min(max_len, window)
            # slots, so the kernel can't read it absolute-aligned. Build
            # an absolute-position-aligned key buffer of ``ring_span``
            # lanes: lane j holds this chunk's latent for j in
            # [base, base + S) and the ring slot j % n otherwise. Lanes
            # outside a query's window carry stale ring rows (or zeros)
            # — the kernel's window/causal/valid_len masks drop exactly
            # those lanes, and because the lane alignment is identical
            # to an unchunked single-pass prefill (masked lanes
            # contribute exact zeros to the online softmax), chunked
            # output matches unchunked bitwise. The chunk is scattered
            # into the ring AFTER attention: a chunk must not clobber
            # the window history it still attends to.
            assert ring_span is not None, \
                "windowed carry-in prefill needs ring_span (engine max_len)"
            j = jnp.arange(ring_span, dtype=jnp.int32)
            in_chunk = (j[None, :] >= bases[:, None]) & \
                (j[None, :] < bases[:, None] + S)
            src = jnp.where(
                in_chunk,
                n + jnp.clip(j[None, :] - bases[:, None], 0, S - 1),
                j[None, :] % n)                                # (B, M)

            def absbuf(hist, chunk):
                buf = jnp.concatenate([hist, chunk.astype(hist.dtype)],
                                      axis=1)                  # (B, n+S, r)
                return jnp.take_along_axis(buf, src[..., None], axis=1)

            # int8 ring: dequantize the window history into the fp abs
            # buffer (the fp kernel reads it once; no quant variant of
            # the lane-gathered view is needed), then quantize-on-write.
            if quantized:
                hist_k = kquant.dequantize_rows(
                    cache["c_k"], cache["ck_scale"], x.dtype)
                hist_v = kquant.dequantize_rows(
                    cache["c_v"], cache["cv_scale"], x.dtype)
            else:
                hist_k, hist_v = cache["c_k"], cache["c_v"]
            u = kops.mla_prefill_sharded(qt, absbuf(hist_k, c_k),
                                         absbuf(hist_v, c_v),
                                         bases + lengths.astype(jnp.int32),
                                         scale=scale,
                                         softcap=cfg.attn_logit_softcap,
                                         window=window, q_offsets=bases)
            if quantized:
                new_cache = _quant_scatter(cache, c_k, c_v, fill)
            else:
                new_cache = {"c_k": _scatter_cache(cache["c_k"], c_k, fill),
                             "c_v": _scatter_cache(cache["c_v"], c_v, fill)}
        u = u.reshape(B, Hkv, R, S, -1)
        yh = jnp.einsum("bgrsV,gVd->bsgrd", u, p["b_v"].astype(x.dtype))
        y = yh.reshape(B, S, H * Dh)
        y = (constrain_bsf(y) @ p["a_o"].astype(y.dtype)) \
            @ p["b_o"].astype(y.dtype)
        if "bias_o" in p:
            y = y + p["bias_o"].astype(y.dtype)
        return y, new_cache

    assert positions.ndim == 1, "per-row positions are decode-only (S == 1)"
    if cache is not None and use_absorbed:
        # Serving prefill fast path: flash-style causal attention computed
        # directly in latent space (q̃ blocks × c_k/c_v blocks, online
        # softmax in VMEM). Never materializes the (B, g, r, S, T) score
        # tensor the einsum branch below would build. Windowed layers pass
        # the window into the kernel's block mask (plus two-sided block
        # pruning); the cache fill wraps into the ring layout.
        layout = CacheLayout(cache["c_k"].shape[1], window)
        bq = p["b_q"].astype(x.dtype).reshape(Hkv, R, *p["b_q"].shape[1:])
        qt = jnp.einsum("bsq,grqd,gKd->bgrsK", c_q, bq,
                        p["b_k"].astype(x.dtype)).reshape(B, H, S, -1)
        u = kops.mla_prefill_sharded(qt, c_k, c_v,
                                     jnp.full((B,), S, jnp.int32),
                                     scale=scale,
                                     softcap=cfg.attn_logit_softcap,
                                     window=window)
        u = u.reshape(B, Hkv, R, S, -1)
        yh = jnp.einsum("bgrsV,gVd->bsgrd", u, p["b_v"].astype(x.dtype))
        y = yh.reshape(B, S, H * Dh)
        y = (constrain_bsf(y) @ p["a_o"].astype(y.dtype)) \
            @ p["b_o"].astype(y.dtype)
        if "bias_o" in p:
            y = y + p["bias_o"].astype(y.dtype)
        # int8 caches: the prompt attends to its own FRESH fp latents
        # above; only the STORED window is quantized (decode sees int8).
        if quantized:
            return y, _quant_fill(cache, c_k, c_v, layout, positions,
                                  lengths)
        return y, {
            "c_k": _prefill_fill(cache["c_k"], c_k, layout, positions, lengths),
            "c_v": _prefill_fill(cache["c_v"], c_v, layout, positions, lengths),
        }

    # train / prefill. The per-head decompression (shared latent -> H·d_h)
    # cannot head-shard when H doesn't divide the axis; sequence-shard its
    # OUTPUT so the einsum computes S/16 rows per device instead of being
    # replicated 16× (§Perf/B3: measured 3.5× compute inflation otherwise).
    q = constrain_heads(decomp(c_q, p["b_q"], p.get("bias_q"), H),
                        head_dims=(2,), seq_dim=1)
    k = constrain_heads(decomp(c_k, p["b_k"], p.get("bias_k"), Hkv),
                        head_dims=(2,), seq_dim=1)
    v = constrain_heads(decomp(c_v, p["b_v"], p.get("bias_v"), Hkv),
                        head_dims=(2,), seq_dim=1)
    if cfg.pos_emb == "rope":
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    q = q.reshape(B, S, Hkv, R, Dh)
    k = constrain_heads(k, head_dims=(2,), seq_dim=None)
    v = constrain_heads(v, head_dims=(2,), seq_dim=None)
    qb = min(q_block, S)
    n_blocks = S // qb
    q_blocks = q.reshape(B, n_blocks, qb, Hkv, R, Dh).transpose(1, 0, 2, 3, 4, 5)
    pos_blocks = positions.reshape(n_blocks, qb)
    k_pos = positions  # (S,)

    def body(_, inp):
        qi, pi = inp
        qi = constrain_heads(qi, head_dims=(2, 3), seq_dim=1)
        s = _gqa_scores(qi, k, scale, cfg.attn_logit_softcap)
        m = k_pos[None, :] <= pi[:, None]
        if window is not None:
            m &= (pi[:, None] - k_pos[None, :]) < window
        s = jnp.where(m[None, None, None, :, :], s, -1e30)
        a = jax.nn.softmax(s, axis=-1).astype(x.dtype)
        return None, constrain_heads(_gqa_values(a, v), head_dims=(2, 3),
                                     seq_dim=1)

    _, y = lax.scan(body, None, (q_blocks, pos_blocks))
    y = y.transpose(1, 0, 2, 3, 4, 5).reshape(B, S, H * Dh)
    y = (constrain_bsf(y) @ p["a_o"].astype(y.dtype)) @ p["b_o"].astype(y.dtype)
    if "bias_o" in p:
        y = y + p["bias_o"].astype(y.dtype)

    new_cache = None
    if cache is not None:  # prefill cache fill with trailing latents
        layout = CacheLayout(cache["c_k"].shape[1], window)
        if quantized:
            new_cache = _quant_fill(cache, c_k, c_v, layout, positions,
                                    lengths)
        else:
            new_cache = {
                "c_k": _prefill_fill(cache["c_k"], c_k, layout, positions, lengths),
                "c_v": _prefill_fill(cache["c_v"], c_v, layout, positions, lengths),
            }
    return y, new_cache


def _cache_abs_positions(positions, cache_len, window):
    """Absolute position of each cache slot (delegates to ``CacheLayout``);
    (cache_len,) for shared positions, (B, cache_len) for per-row (ragged
    decode) positions."""
    return CacheLayout(cache_len, window).abs_positions(positions)


def init_latent_attention_cache(cfg: ModelConfig, batch: int, max_len: int,
                                r_k: int, r_v: int,
                                window: Optional[int] = None) -> Params:
    n = CacheLayout.make(max_len, window).cache_len
    if cfg.latent.cache_dtype == "int8":
        # int8 rows + per-(slot, row) fp32 scale columns. Zero scales mark
        # unwritten slots; they dequantize to exact zeros, matching the
        # fp cache's zero-init, and every attention path masks invalid
        # slots anyway. The sibling leaves flow through the same generic
        # tree scatters (arena admission, paged gather) as the fp pair.
        return {
            "c_k": jnp.zeros((batch, n, r_k), jnp.int8),
            "ck_scale": jnp.zeros((batch, n, 1), jnp.float32),
            "c_v": jnp.zeros((batch, n, r_v), jnp.int8),
            "cv_scale": jnp.zeros((batch, n, 1), jnp.float32),
        }
    return {
        "c_k": jnp.zeros((batch, n, r_k), dtype_of(cfg)),
        "c_v": jnp.zeros((batch, n, r_v), dtype_of(cfg)),
    }


# ----------------------------------------------------------------------
# MLP (dense / gated) and latent MLP
# ----------------------------------------------------------------------

def init_mlp(key, cfg: ModelConfig, d_ff: Optional[int] = None) -> Params:
    d_ff = d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    d = cfg.d_model
    p = {"up": _init_dense(ks[0], d, d_ff, cfg.mlp_bias),
         "down": _init_dense(ks[1], d_ff, d, cfg.mlp_bias)}
    if cfg.gated_mlp:
        p["gate"] = _init_dense(ks[2], d, d_ff, cfg.mlp_bias)
    return p


FUSED_PROJECTIONS = False  # see attention_fwd note; flip for §Perf/A3 runs


def mlp_fwd(p: Params, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    act = _activation(cfg.activation)
    if "gate" in p and FUSED_PROJECTIONS:
        w = jnp.concatenate([p["up"]["w"], p["gate"]["w"]],
                            axis=1).astype(x.dtype)
        ug = constrain_bsf(x @ w)
        if "b" in p["up"]:
            ug = ug + jnp.concatenate(
                [p["up"]["b"], p["gate"]["b"]]).astype(x.dtype)
        u, g = jnp.split(ug, 2, axis=-1)
        u = u * act(g)
    elif "gate" in p:
        u = constrain_bsf(dense(p["up"], x))
        u = u * act(constrain_bsf(dense(p["gate"], x)))
    else:
        u = act(constrain_bsf(dense(p["up"], x)))
    return dense(p["down"], u)


def init_latent_mlp(key, cfg: ModelConfig, r_u: int, r_d: int,
                    d_ff: Optional[int] = None) -> Params:
    """Low-rank factored MLP: W_u≈B_u·A_u, W_d≈B_d·A_d (stored as dense pairs;
    block-identity structure handled by core.latent packing)."""
    d_ff = d_ff or cfg.d_ff
    ks = jax.random.split(key, 6)
    d = cfg.d_model
    s = lambda n: 1.0 / math.sqrt(n)
    p = {
        "up_a": jax.random.normal(ks[0], (d, r_u), jnp.float32) * s(d),
        "up_b": jax.random.normal(ks[1], (r_u, d_ff), jnp.float32) * s(r_u),
        "down_a": jax.random.normal(ks[2], (d_ff, r_d), jnp.float32) * s(d_ff),
        "down_b": jax.random.normal(ks[3], (r_d, d), jnp.float32) * s(r_d),
    }
    if cfg.gated_mlp:
        p["gate_a"] = jax.random.normal(ks[4], (d, r_u), jnp.float32) * s(d)
        p["gate_b"] = jax.random.normal(ks[5], (r_u, d_ff), jnp.float32) * s(r_u)
    if cfg.mlp_bias:
        p["up_bias"] = jnp.zeros((d_ff,), jnp.float32)
        p["down_bias"] = jnp.zeros((d,), jnp.float32)
        if cfg.gated_mlp:
            p["gate_bias"] = jnp.zeros((d_ff,), jnp.float32)
    return p


def latent_mlp_fwd(p: Params, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    act = _activation(cfg.activation)

    def lr(x, a, b, bias=None):
        y = (x @ a.astype(x.dtype)) @ b.astype(x.dtype)
        if bias is not None:
            y = y + bias.astype(x.dtype)
        return y

    u = constrain_bsf(lr(x, p["up_a"], p["up_b"], p.get("up_bias")))
    if "gate_a" in p:
        u = u * act(constrain_bsf(lr(x, p["gate_a"], p["gate_b"], p.get("gate_bias"))))
    else:
        u = act(u)
    return lr(u, p["down_a"], p["down_b"], p.get("down_bias"))


# ----------------------------------------------------------------------
# MoE (GShard-style top-k with capacity; experts sharded on 'model')
# ----------------------------------------------------------------------

def init_moe(key, cfg: ModelConfig) -> Params:
    ks = jax.random.split(key, 5)
    d, E, F = cfg.d_model, cfg.num_experts, cfg.d_ff
    s = lambda n: 1.0 / math.sqrt(n)
    p = {
        "router": jax.random.normal(ks[0], (d, E), jnp.float32) * s(d),
        "up": jax.random.normal(ks[1], (E, d, F), jnp.float32) * s(d),
        "down": jax.random.normal(ks[2], (E, F, d), jnp.float32) * s(F),
    }
    if cfg.gated_mlp:
        p["gate"] = jax.random.normal(ks[3], (E, d, F), jnp.float32) * s(d)
    if cfg.num_shared_experts:
        p["shared"] = init_mlp(ks[4], cfg, d_ff=cfg.d_ff * cfg.num_shared_experts)
    return p


def moe_fwd(p: Params, x: jax.Array, cfg: ModelConfig,
            tokens_per_group: int = 4096) -> Tuple[jax.Array, jax.Array]:
    """Returns (y, aux_loss). GShard-style grouped top-k capacity dispatch.

    Tokens are split into groups (sharded on the data axis); each group
    dispatches to every expert with per-group capacity — the dispatch
    einsum becomes the all_to_all under GSPMD when experts live on the
    'model' axis."""
    B, S, d = x.shape
    E, K = cfg.num_experts, cfg.num_experts_per_tok
    T = B * S
    tpg = min(tokens_per_group, T)
    n_grp = T // tpg
    assert T % tpg == 0, (T, tpg)
    xt = constrain(x.reshape(n_grp, tpg, d),
                   [[("pod", "data"), "data", None], [None], [None]])
    logits = (xt @ p["router"].astype(xt.dtype)).astype(jnp.float32)  # (g,t,E)
    probs = jax.nn.softmax(logits, axis=-1)

    # min-capacity 8 keeps small decode batches dropless; train groups are
    # governed by capacity_factor as usual (GShard semantics).
    cap = max(8, int(cfg.capacity_factor * tpg * K / E))
    cap = min(cap, tpg)
    gates, dispatch = _topk_capacity(probs, K, cap)  # (g,t,E), (g,t,E,cap)

    # load-balancing auxiliary loss (Switch-style)
    density = jnp.mean(jnp.sum(dispatch, axis=-1), axis=(0, 1))  # (E,)
    density_proxy = jnp.mean(probs, axis=(0, 1))
    aux = jnp.sum(density * density_proxy) * E / K

    act = _activation(cfg.activation)
    ba = [("pod", "data"), "data", None]
    xe = jnp.einsum("gtec,gtd->gecd", dispatch.astype(xt.dtype), xt)
    # the (tokens->experts) resharding below IS the all_to_all under GSPMD
    xe = constrain(xe, [ba, ["model", None], [None], [None]])
    u = jnp.einsum("gecd,edf->gecf", xe, p["up"].astype(xt.dtype))
    if "gate" in p:
        g = jnp.einsum("gecd,edf->gecf", xe, p["gate"].astype(xt.dtype))
        u = u * act(g)
    else:
        u = act(u)
    ye = jnp.einsum("gecf,efd->gecd", u, p["down"].astype(xt.dtype))
    ye = constrain(ye, [ba, ["model", None], [None], [None]])
    combine = (gates[..., None] * dispatch).astype(xt.dtype)  # (g,t,E,cap)
    y = jnp.einsum("gtec,gecd->gtd", combine, ye)
    if "shared" in p:
        y = y + p_shared_fwd(p["shared"], xt, cfg)
    return y.reshape(B, S, d), aux


def p_shared_fwd(p: Params, xt: jax.Array, cfg: ModelConfig) -> jax.Array:
    act = _activation(cfg.activation)
    u = dense(p["up"], xt)
    if "gate" in p:
        u = u * act(dense(p["gate"], xt))
    else:
        u = act(u)
    return dense(p["down"], u)


def _topk_capacity(probs: jax.Array, k: int, cap: int):
    """Greedy top-k routing with per-expert, per-group capacity.

    probs: (g, t, E). Returns gates (g,t,E) and dispatch (g,t,E,cap)."""
    G, T, E = probs.shape
    gates_acc = jnp.zeros((G, T, E), probs.dtype)
    disp_slot = jnp.full((G, T, E), -1, jnp.int32)
    p_work = probs
    counts = jnp.zeros((G, 1, E), probs.dtype)  # slots used by earlier k-iters
    for _ in range(k):
        idx = jnp.argmax(p_work, axis=-1)  # (g,t)
        onehot = jax.nn.one_hot(idx, E, dtype=probs.dtype)  # (g,t,E)
        # slot within the expert queue = # earlier tokens routed there,
        # offset by slots consumed in previous top-k iterations
        pos = jnp.cumsum(onehot, axis=1) - 1.0 + counts
        slot = jnp.sum(pos * onehot, axis=-1).astype(jnp.int32)  # (g,t)
        keep = slot < cap
        gate = jnp.sum(probs * onehot, axis=-1) * keep
        gates_acc = gates_acc + onehot * gate[..., None]
        disp_slot = jnp.where((onehot > 0) & keep[..., None],
                              slot[..., None], disp_slot)
        counts = counts + jnp.sum(onehot, axis=1, keepdims=True)
        p_work = p_work * (1.0 - onehot)
    slot_oh = jax.nn.one_hot(disp_slot, cap, dtype=probs.dtype)  # (g,t,E,cap)
    dispatch = slot_oh * (disp_slot >= 0)[..., None]
    denom = jnp.sum(gates_acc, axis=-1, keepdims=True) + 1e-9
    gates = gates_acc / denom  # renormalized top-k gates (Mixtral-style)
    return gates, dispatch


# ----------------------------------------------------------------------
# Mamba2 (SSD) block
# ----------------------------------------------------------------------

def init_ssd(key, cfg: ModelConfig) -> Params:
    ks = jax.random.split(key, 4)
    d, di = cfg.d_model, cfg.d_inner
    G, N, Hs = cfg.ssm_ngroups, cfg.ssm_state, cfg.ssm_nheads
    conv_dim = di + 2 * G * N
    proj_out = 2 * di + 2 * G * N + Hs  # z, x, B, C, dt
    p = {
        "in_proj": _init_dense(ks[0], d, proj_out, False),
        "conv_w": jax.random.normal(ks[1], (cfg.ssm_conv_width, conv_dim), jnp.float32) * 0.2,
        "conv_b": jnp.zeros((conv_dim,), jnp.float32),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, Hs, dtype=jnp.float32)),
        "dt_bias": jnp.zeros((Hs,), jnp.float32),
        "D": jnp.ones((Hs,), jnp.float32),
        "norm": {"scale": jnp.ones((di,), jnp.float32)},
        "out_proj": _init_dense(ks[2], di, d, False),
    }
    return p


def _ssd_chunked(xh, dt, A, Bm, Cm, chunk: int):
    """SSD chunked scan (Dao & Gu 2024, state-space duality).

    xh: (B,S,H,P) dt: (B,S,H) A: (H,) (negative) Bm/Cm: (B,S,G,N).
    Heads are processed grouped (H = G·R) so B/C are never repeated.
    Returns (y (B,S,H,P), final_state (B,H,P,N))."""
    Bsz, S, H, P = xh.shape
    G, N = Bm.shape[2], Bm.shape[3]
    S_orig = S
    if S % chunk:  # zero-pad tail; dt=0 there so the state is untouched
        pad = chunk - S % chunk
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        S = S + pad
    nc, R = S // chunk, H // G

    xc = xh.reshape(Bsz, nc, chunk, G, R, P)
    dtc = dt.reshape(Bsz, nc, chunk, G, R)
    Bc = Bm.reshape(Bsz, nc, chunk, G, N)
    Cc = Cm.reshape(Bsz, nc, chunk, G, N)

    dA = dtc * A.reshape(1, 1, 1, G, R)  # negative
    cum = jnp.cumsum(dA, axis=2)  # (B,nc,Q,G,R) intra-chunk log-decay

    # intra-chunk: y[t] = Σ_{s<=t} (C_t·B_s) exp(cum_t−cum_s) dt_s x_s
    # (bf16 matmul + explicit upcast: keeps backward comms in bf16)
    CB = jnp.einsum("bnqgk,bnsgk->bngqs", Cc, Bc).astype(jnp.float32)
    decay = jnp.exp(cum[:, :, :, None] - cum[:, :, None])  # (B,nc,Q,S,G,R)
    mask = jnp.tril(jnp.ones((chunk, chunk), bool))
    decay = jnp.where(mask[None, None, :, :, None, None], decay, 0.0)
    xdt = xc * dtc[..., None].astype(xc.dtype)  # (B,nc,Q,G,R,P)
    y_intra = jnp.einsum("bngqs,bnqsgr,bnsgrp->bnqgrp",
                         CB.astype(xh.dtype), decay.astype(xh.dtype), xdt)

    # chunk states: S_n = Σ_s exp(cum_end − cum_s) B_s dt_s x_s
    last = cum[:, :, -1:]  # (B,nc,1,G,R)
    state_decay = jnp.exp(last - cum)  # (B,nc,Q,G,R)
    states = jnp.einsum("bnsgk,bnsgrp,bnsgr->bngrpk",
                        Bc, xdt, state_decay.astype(xh.dtype))

    chunk_decay = jnp.exp(last[:, :, 0])  # (B,nc,G,R)

    def scan_fn(s_prev, inp):
        s_new, dec = inp  # (B,G,R,P,N), (B,G,R)
        return s_new + dec[..., None, None].astype(s_new.dtype) * s_prev, s_prev

    init = jnp.zeros_like(states[:, 0])
    final_state, prev_states = lax.scan(
        scan_fn, init,
        (states.transpose(1, 0, 2, 3, 4, 5), chunk_decay.transpose(1, 0, 2, 3)))
    prev_states = prev_states.transpose(1, 0, 2, 3, 4, 5)  # (B,nc,G,R,P,N)

    in_decay = jnp.exp(cum)  # (B,nc,Q,G,R)
    y_off = jnp.einsum("bnqgk,bngrpk,bnqgr->bnqgrp",
                       Cc, prev_states, in_decay.astype(xh.dtype))
    y = (y_intra + y_off).reshape(Bsz, S, H, P)[:, :S_orig]
    return y, final_state.reshape(Bsz, H, P, N).astype(jnp.float32)


def ssd_fwd(p: Params, x: jax.Array, cfg: ModelConfig,
            cache: Optional[Params] = None) -> Tuple[jax.Array, Optional[Params]]:
    """Mamba2 block. cache = {'conv': (B,W-1,conv_dim), 'ssm': (B,H,P,N)}."""
    B, S, d = x.shape
    di, G, N = cfg.d_inner, cfg.ssm_ngroups, cfg.ssm_state
    Hs, P = cfg.ssm_nheads, cfg.ssm_head_dim
    W = cfg.ssm_conv_width
    zxbcdt = constrain_bsf(dense(p["in_proj"], x))
    z, xbc, dt = jnp.split(zxbcdt, [di, di + di + 2 * G * N], axis=-1)
    # conv over (x,B,C)
    if cache is not None:
        conv_in = jnp.concatenate([cache["conv"].astype(xbc.dtype), xbc], axis=1)
        new_conv = conv_in[:, -(W - 1):]
    else:
        conv_in = jnp.pad(xbc, ((0, 0), (W - 1, 0), (0, 0)))
        new_conv = conv_in[:, -(W - 1):]
    xbc = _causal_conv(conv_in, p["conv_w"], p["conv_b"], S)
    xbc = jax.nn.silu(xbc)
    xs, Bm, Cm = jnp.split(xbc, [di, di + G * N], axis=-1)
    xh = xs.reshape(B, S, Hs, P)
    Bm = Bm.reshape(B, S, G, N)
    Cm = Cm.reshape(B, S, G, N)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B,S,Hs)
    A = -jnp.exp(p["A_log"])  # (Hs,) negative

    if cache is not None and S == 1:
        # recurrent single-step update
        s_prev = cache["ssm"]  # (B,H,P,N)
        dA = jnp.exp(dt[:, 0, :] * A[None, :])  # (B,H)
        rep = Hs // G
        Bh = jnp.repeat(Bm[:, 0], rep, axis=1)  # (B,H,N)
        Ch = jnp.repeat(Cm[:, 0], rep, axis=1)
        dBx = jnp.einsum("bhn,bhp,bh->bhpn", Bh.astype(jnp.float32),
                         xh[:, 0].astype(jnp.float32), dt[:, 0])
        s_new = s_prev * dA[:, :, None, None] + dBx
        y = jnp.einsum("bhpn,bhn->bhp", s_new, Ch.astype(jnp.float32))
        y = y[:, None].astype(x.dtype)  # (B,1,H,P)
        new_cache = {"conv": new_conv, "ssm": s_new}
    else:
        y, final_state = _ssd_chunked(xh, dt, A, Bm, Cm, min(cfg.ssm_chunk, S))
        new_cache = {"conv": new_conv, "ssm": final_state} if cache is not None else None

    y = y + xh * p["D"].astype(x.dtype)[None, None, :, None]
    y = y.reshape(B, S, di)
    y = norm_fwd(p["norm"], y) * jax.nn.silu(z)
    out = dense(p["out_proj"], y)
    return out, new_cache


def _causal_conv(x_padded: jax.Array, w: jax.Array, b: jax.Array, S: int) -> jax.Array:
    """Depthwise causal conv. x_padded: (B, S+W-1, C); w: (W, C)."""
    W = w.shape[0]
    y = sum(x_padded[:, i:i + S, :] * w[i].astype(x_padded.dtype) for i in range(W))
    return y + b.astype(x_padded.dtype)


def init_ssd_cache(cfg: ModelConfig, batch: int) -> Params:
    conv_dim = cfg.d_inner + 2 * cfg.ssm_ngroups * cfg.ssm_state
    return {
        "conv": jnp.zeros((batch, cfg.ssm_conv_width - 1, conv_dim), dtype_of(cfg)),
        "ssm": jnp.zeros((batch, cfg.ssm_nheads, cfg.ssm_head_dim, cfg.ssm_state),
                          jnp.float32),
    }
