"""Batched token sampling from logits (temperature / top-k / top-p).

One fused function for a whole batch of rows with *per-row* sampling
parameters and *per-row* PRNG keys, so a continuous-batching engine can
serve mixed sampling configs in a single dispatch. Greedy is the
``temperature == 0`` special case and is bit-identical to
``jnp.argmax`` (no noise is added on those rows).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG = -1e30  # same "masked" value the attention paths use


def fold_keys(base_keys: jax.Array, step: jax.Array) -> jax.Array:
    """Per-row fold: base_keys (B, 2) uint32, step () or (B,) int32."""
    step = jnp.broadcast_to(jnp.asarray(step, jnp.uint32),
                            (base_keys.shape[0],))
    return jax.vmap(jax.random.fold_in)(base_keys, step)


def make_keys(seeds) -> jax.Array:
    """(B,) int seeds -> (B, 2) uint32 raw PRNG keys."""
    return jax.vmap(jax.random.PRNGKey)(jnp.asarray(seeds, jnp.int32))


def row_keys(seed: int, rows: int) -> jax.Array:
    """One seed -> (rows, 2) per-row keys (row r = fold_in(key, r)).

    The batch-generation key scheme: both the scan and the Python-loop
    generate paths derive their keys here, which is what keeps their
    sampled tokens bit-identical."""
    base = jax.random.PRNGKey(seed)
    return jax.vmap(lambda r: jax.random.fold_in(base, r))(
        jnp.arange(rows, dtype=jnp.uint32))


def sample_logits(logits: jax.Array, keys: jax.Array, *,
                  temperature, top_k=0, top_p=1.0) -> jax.Array:
    """Sample one token per row. logits: (B, V); keys: (B, 2) uint32.

    ``temperature`` (B,) fp32 — 0 means greedy (bit-identical argmax);
    ``top_k`` (B,) int32 — 0 disables; ``top_p`` (B,) fp32 — 1 disables.
    Scalars broadcast. Filtering order matches the common convention:
    temperature scale, then top-k, then nucleus (top-p) on the
    renormalized distribution. Returns (B,) int32.
    """
    lf = logits.astype(jnp.float32)
    B, V = lf.shape
    temperature = jnp.broadcast_to(
        jnp.asarray(temperature, jnp.float32), (B,))
    top_k = jnp.broadcast_to(jnp.asarray(top_k, jnp.int32), (B,))
    top_p = jnp.broadcast_to(jnp.asarray(top_p, jnp.float32), (B,))

    greedy = jnp.argmax(lf, axis=-1)
    scaled = lf / jnp.maximum(temperature, 1e-6)[:, None]

    # top-k: strict rank-based mask — exactly k candidates survive even
    # when several logits tie with the k-th (a `scaled < kth` threshold
    # would keep every tied one, overflowing the candidate set). Ties
    # break by vocab index (stable argsort), matching argmax's choice.
    order = jnp.argsort(-scaled, axis=-1)                  # (B, V) desc
    sorted_desc = jnp.take_along_axis(scaled, order, axis=-1)
    ranks = jnp.argsort(order, axis=-1)                    # inverse perm
    k = jnp.clip(jnp.where(top_k > 0, top_k, V), 1, V)
    scaled = jnp.where(ranks < k[:, None], scaled, NEG)

    # top-p: keep the smallest prefix of the sorted distribution whose
    # mass reaches p (the crossing token is kept; ties at the threshold
    # probability are all kept). The sorted probs come from re-masking
    # sorted_desc by column rank (softmax is monotonic) — no second
    # O(V log V) sort on the decode hot path.
    probs = jax.nn.softmax(scaled, axis=-1)
    cols = jnp.arange(V)[None, :]
    sp = jax.nn.softmax(jnp.where(cols < k[:, None], sorted_desc, NEG),
                        axis=-1)
    csum = jnp.cumsum(sp, axis=-1)
    keep = (csum - sp) < top_p[:, None]
    thresh = jnp.min(jnp.where(keep, sp, jnp.inf), axis=-1, keepdims=True)
    scaled = jnp.where(probs < thresh, NEG, scaled)

    gumbel = jax.vmap(lambda kk: jax.random.gumbel(kk, (V,)))(keys)
    sampled = jnp.argmax(scaled + gumbel, axis=-1)
    return jnp.where(temperature > 0, sampled, greedy).astype(jnp.int32)
