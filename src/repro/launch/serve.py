"""Serving driver: batched prefill + decode against a (latent) KV cache.

The paper's payoff at inference: a LatentLLM-compressed model serves with
an r_k+r_v latent cache instead of 2·H·d_h per token — ``--latent`` sizes
the cache accordingly and the decode path runs the absorbed MLA form.
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import REGISTRY, LatentConfig, get_config, reduced
from repro.checkpoint import CheckpointManager
from repro.core.ranks import latent_ranks
from repro.data import tokenizer
from repro.models import lm, transformer as T


def cache_bytes(cfg, batch, seq):
    tree = jax.eval_shape(lambda: T.init_cache(cfg, batch, seq))
    return sum(np.prod(l.shape) * l.dtype.itemsize
               for l in jax.tree.leaves(tree))


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="opt-125m", choices=list(REGISTRY))
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--latent", type=float, default=None)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    latent = (LatentConfig(enabled=True, compression=args.latent)
              if args.latent else None)
    cfg = get_config(args.arch, latent)
    if args.reduced:
        cfg = reduced(cfg)
        if latent:
            cfg = dataclasses.replace(cfg, latent=latent)

    key = jax.random.PRNGKey(args.seed)
    params = T.init_params(key, cfg)
    if args.ckpt_dir:
        ckpt = CheckpointManager(args.ckpt_dir)
        (params, _), _ = ckpt.restore((params, jax.tree.map(jnp.zeros_like,
                                                            params)))

    max_len = args.prompt_len + args.gen_len
    prefill = jax.jit(lm.make_prefill_step(cfg, max_len))
    # the whole continuation is ONE lax.scan dispatch with the cache
    # buffers donated — not a per-token Python loop.
    generate = lm.jit_generate(cfg, args.gen_len - 1)

    prompt = jax.random.randint(key, (args.batch, args.prompt_len), 0,
                                min(cfg.vocab_size, 256))
    # AOT-compile both dispatches so the printed ms are steady-state
    # serving numbers, not one-off XLA compile time.
    prefill_c = prefill.lower(params, {"tokens": prompt}).compile()
    t0 = time.time()
    cache, logits = prefill_c(params, {"tokens": prompt})
    tok = jnp.argmax(logits, axis=-1)[:, None].astype(prompt.dtype)
    jax.block_until_ready((cache, tok))
    t_prefill = time.time() - t0
    if args.gen_len > 1:
        generate_c = generate.lower(params, cache, tok).compile()
        t0 = time.time()
        toks, cache = generate_c(params, cache, tok)
        gen = jnp.concatenate([tok, toks], axis=1)
    else:
        t0 = time.time()
        gen = tok
    jax.block_until_ready(gen)
    t_decode = time.time() - t0

    kv = cache_bytes(cfg, args.batch, max_len)
    print(f"[serve] arch={cfg.name} latent={args.latent}")
    print(f"[serve] prefill {args.prompt_len} toks x {args.batch}: "
          f"{t_prefill * 1e3:.1f} ms")
    print(f"[serve] decode  {args.gen_len} steps: "
          f"{t_decode * 1e3 / max(args.gen_len - 1, 1):.2f} ms/tok")
    print(f"[serve] KV cache {kv / 1e6:.2f} MB "
          f"({'latent c_k/c_v' if cfg.latent.enabled else 'dense k/v'})")
    print("[serve] sample:", tokenizer.decode(np.asarray(gen[0]))[:80])
    return gen


if __name__ == "__main__":
    main()
