"""Serve CLI: a thin driver over the continuous-batching Engine.

The paper's payoff at inference: a LatentLLM-compressed model serves with
an r_k+r_v latent cache instead of 2·H·d_h per token — ``--latent`` sizes
the arena slots accordingly and decode runs the absorbed MLA form.
Sliding-window archs (``--arch gemma2-27b`` / ``h2o-danube-3-4b``) serve
too: their windowed layers get ring arena slots of the WINDOW length
(reported in the cache line) and prompts may exceed the window — the
ring wraps.

Two modes:

  * **batch CLI** (default): build requests (``--prompt`` text or
    mixed-length synthetic traffic), ``Engine.run()``, print
    per-request outputs, throughput, and the latent-vs-dense footprint;
  * **server** (``--serve [--port N]``): the HTTP+SSE front-end from
    ``repro.serve.server`` — ``POST /v1/generate`` streams tokens,
    ``GET /metrics`` serves the registry (JSON / Prometheus), and the
    first SIGINT drains in-flight requests to completion before the
    listener exits (second SIGINT aborts). ``--smoke`` self-tests the
    server: stream one request through ``repro.serve.client``, scrape
    /metrics + /healthz, drain, exit.

The heavy lifting lives in ``repro.serve``; this file only parses args.
"""
from __future__ import annotations

import argparse
import contextlib
import dataclasses
import signal

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import REGISTRY, LatentConfig, get_config, reduced
from repro.checkpoint import CheckpointManager
from repro.data import tokenizer
from repro.launch.mesh import make_debug_mesh, make_production_mesh
from repro.models import transformer as T
from repro.serve import (Engine, MetricsRegistry, Request, SamplingParams,
                         ServeClient, ServeServer, synthetic_prompts)


def _install_sigint_drain(engine):
    """Graceful ^C: the first SIGINT begins a drain (queued requests are
    cancelled, residents finish and report), a second one aborts hard.
    Returns the previous handler so the caller can restore it."""
    prev = signal.getsignal(signal.SIGINT)
    hits = {"n": 0}

    def handler(signum, frame):
        hits["n"] += 1
        if hits["n"] == 1:
            print("\n[serve] SIGINT: draining — residents finish, queued "
                  "requests cancelled; ^C again to abort")
            engine.begin_drain(cancel_queued=True)
        else:
            signal.signal(signal.SIGINT, prev)
            raise KeyboardInterrupt

    signal.signal(signal.SIGINT, handler)
    return prev


@contextlib.contextmanager
def _sigint_drain(engine):
    """Scoped SIGINT drain: installs the handler and ALWAYS restores
    the previous one on exit — including the normal no-^C path, which
    used to leave the drain handler armed for the rest of the
    process."""
    prev = _install_sigint_drain(engine)
    try:
        yield
    finally:
        signal.signal(signal.SIGINT, prev)


@contextlib.contextmanager
def _sigint_server_drain(server):
    """Server-mode ^C: first SIGINT asks the scheduler to drain (the
    listener exits once residents finished), second aborts (cancels
    everything). Restores the previous handler on exit."""
    prev = signal.getsignal(signal.SIGINT)
    hits = {"n": 0}

    def handler(signum, frame):
        hits["n"] += 1
        if hits["n"] == 1:
            print("\n[serve] SIGINT: draining — in-flight requests finish, "
                  "admission closed; ^C again to abort")
            server.request_stop(drain=True)
        else:
            print("\n[serve] SIGINT: aborting — cancelling all requests")
            server.request_stop(drain=False)

    signal.signal(signal.SIGINT, handler)
    try:
        yield
    finally:
        signal.signal(signal.SIGINT, prev)


def _parse_mesh(spec: str):
    """``--mesh data,model`` -> Mesh. ``16,16`` (one pod) routes through
    make_production_mesh; anything smaller is a debug mesh (pair with
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` on CPU)."""
    try:
        data, model = (int(x) for x in spec.split(","))
    except ValueError:
        raise SystemExit(f"--mesh wants 'data,model' ints, got {spec!r}")
    if (data, model) == (16, 16):
        return make_production_mesh()
    n = data * model
    if len(jax.devices()) < n:
        raise SystemExit(
            f"--mesh {spec} needs {n} devices, found {len(jax.devices())} "
            "— on CPU run under "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={n}")
    return make_debug_mesh(data, model)


def _print_scheduler(engine):
    """End-of-run chunked-scheduler stats (no-op when chunking is off):
    chunks issued, tokens chunk-prefilled, and the SLO shaping state."""
    sr = engine.scheduler_report()
    if not sr["chunked"]:
        return
    print(f"[serve] scheduler: token_budget={sr['token_budget']} "
          f"prefill_chunk={sr['prefill_chunk']} "
          f"chunks={sr['prefill_chunks']} "
          f"chunk_toks={sr['prefill_chunk_tokens']} "
          f"prefill_share={sr['prefill_share']} "
          f"slo_backoffs={sr['slo_backoffs']} "
          f"ttft_risk_boosts={sr['ttft_risk_boosts']}")


def _serve_mode(args, cfg, engine, prompts):
    """``--serve``: hand the engine to the scheduler thread and listen.
    Returns None (server ran until SIGINT) or the smoke-test result."""
    srv = ServeServer(engine, host=args.host, port=args.port)
    host, port = srv.start()
    print(f"[serve] listening on http://{host}:{port} arch={cfg.name} "
          f"slots={engine.arena.num_slots} max_len={engine.arena.max_len} "
          f"max_queue={engine.max_queue}")
    print("[serve] POST /v1/generate | DELETE /v1/requests/<id> | "
          "GET /metrics | GET /healthz  (^C drains, ^C^C aborts)")
    with _sigint_server_drain(srv):
        if args.smoke:
            return _smoke(args, srv, engine, prompts)
        srv.wait()
    srv.stop(timeout_s=5.0)        # scheduler already exited: close listener
    life = engine.lifecycle_report()
    kv = " ".join(f"{k}={v}" for k, v in sorted(life["counters"].items()))
    print(f"[serve] drained: finished={life['finished']} "
          f"rejected={life['rejected']}{' ' + kv if kv else ''}")
    _print_scheduler(engine)
    return None


def _smoke(args, srv, engine, prompts):
    """One full client round trip against the live server: stream a
    request over SSE, check /metrics (JSON + Prometheus) and /healthz,
    then drain-stop. Under --prefill-chunk/--token-budget, also admits a
    LONG prompt while a short request streams: the long prefill must
    proceed in bounded chunks (scheduler counters prove it) and both
    streams finish. Raises on any mismatch — the CI smoke gate."""
    client = ServeClient(srv.host, srv.port)
    hz = client.healthz()
    assert hz["status"] == "ok", hz
    streamed = []
    out = client.generate([int(t) for t in prompts[0]],
                          max_new_tokens=args.gen_len,
                          temperature=args.temperature, seed=args.seed,
                          on_token=streamed.append)
    assert out["finish_reason"] and out["tokens"] == streamed
    snap = client.metrics()
    prom = client.metrics("prometheus")
    assert snap["histograms"]["ttft_s"]["count"] >= 1, snap
    assert "serve_ttft_s" in prom and "serve_queue_depth" in prom
    print(f"[serve] smoke: {out['num_generated']} toks over SSE "
          f"(finish={out['finish_reason']}, "
          f"ttft={out['client_ttft_s'] * 1e3:.1f} ms, "
          f"server_ttft_p50={snap['histograms']['ttft_s']['p50']:.4f} s)")
    if engine.scheduler_report()["chunked"]:
        import threading
        cap = engine.arena.max_len - args.gen_len - 1
        long_prompt = np.tile(prompts[0],
                              -(-cap // prompts[0].size))[:cap]
        short_toks, res = [], {}

        def stream_short():
            res["short"] = client.generate(
                [int(t) for t in prompts[0]],
                max_new_tokens=args.gen_len, on_token=short_toks.append)

        th = threading.Thread(target=stream_short)
        th.start()      # short stream decodes while the long one admits
        long_toks = []
        res["long"] = client.generate([int(t) for t in long_prompt],
                                      max_new_tokens=args.gen_len,
                                      on_token=long_toks.append)
        th.join()
        assert res["short"]["tokens"] == short_toks
        assert res["long"]["tokens"] == long_toks
        sr = engine.scheduler_report()
        assert sr["prefill_chunks"] > 0, sr
        assert "serve_prefill_backlog_tokens" in client.metrics("prometheus")
        print(f"[serve] smoke: long prompt ({long_prompt.size} toks) "
              f"chunk-prefilled over {sr['prefill_chunks']} chunks "
              f"({sr['prefill_chunk_tokens']} toks) alongside a live "
              f"short stream — OK")
    clean = srv.stop(drain=True, timeout_s=120.0)
    assert clean, "drain did not complete"
    print("[serve] smoke: drained clean — OK")
    _print_scheduler(engine)
    return out


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="opt-125m", choices=list(REGISTRY))
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--latent", type=float, default=None)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--prompt", action="append", default=None,
                    help="text prompt (repeatable); default: synthetic "
                         "mixed-length traffic")
    ap.add_argument("--batch", type=int, default=4,
                    help="number of synthetic requests")
    ap.add_argument("--prompt-len", type=int, default=32,
                    help="max synthetic prompt length (lengths are mixed)")
    ap.add_argument("--gen-len", type=int, default=32)
    ap.add_argument("--num-slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=None,
                    help="arena slot length (default prompt+gen rounded)")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--top-k", type=int, default=0)
    ap.add_argument("--top-p", type=float, default=1.0)
    ap.add_argument("--eos-id", type=int, default=None)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--mesh", default=None, metavar="DATA,MODEL",
                    help="shard the engine over a device mesh, e.g. "
                         "'2,4' (debug) or '16,16' (production pod); "
                         "greedy tokens are identical to unsharded")
    ap.add_argument("--no-warmup", action="store_true",
                    help="skip the compile warmup pass (timings include "
                         "XLA compile)")
    ap.add_argument("--paged", action="store_true",
                    help="paged latent cache + radix prefix reuse: slots "
                         "become block tables over a shared pool and "
                         "repeated prompt prefixes skip prefill. Needs "
                         "--latent and implies the absorbed NoPE form "
                         "(pos_emb=none, no qkv bias) that makes latent "
                         "blocks prefix-shareable")
    ap.add_argument("--block-size", type=int, default=16,
                    help="tokens per pool block in --paged mode")
    ap.add_argument("--deadline-s", type=float, default=None,
                    help="per-request completion deadline (seconds from "
                         "submit; expired requests finish as 'timeout')")
    ap.add_argument("--ttft-deadline-s", type=float, default=None,
                    help="per-request time-to-first-token deadline")
    ap.add_argument("--serve", action="store_true",
                    help="run the HTTP+SSE server instead of the batch "
                         "CLI: POST /v1/generate (SSE or JSON), "
                         "DELETE /v1/requests/<id>, GET /metrics "
                         "(JSON/Prometheus), GET /healthz; first SIGINT "
                         "drains in-flight requests before exit")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=8100,
                    help="server port (0 = ephemeral, printed at start)")
    ap.add_argument("--max-queue", type=int, default=64,
                    help="server admission queue bound (excess -> 429)")
    ap.add_argument("--smoke", action="store_true",
                    help="with --serve: stream one request through the "
                         "bundled client, scrape /metrics + /healthz, "
                         "drain, and exit (the `make serve-smoke` gate); "
                         "with --prefill-chunk/--token-budget also admits "
                         "a long prompt mid-decode of a short stream")
    ap.add_argument("--prefill-chunk", type=int, default=None,
                    help="chunked prefill: cap prompt prefill at this many "
                         "tokens per engine step (long prompts interleave "
                         "with resident decode). Needs --latent; applies "
                         "the absorbed NoPE overrides like --paged")
    ap.add_argument("--token-budget", type=int, default=None,
                    help="unified per-step token budget: resident decode "
                         "rows spend 1 token each, the remainder buys "
                         "prefill chunks. Needs --latent (see "
                         "--prefill-chunk)")
    ap.add_argument("--quant-cache", action="store_true",
                    help="store the latent KV cache as int8 rows + fp32 "
                         "per-row scales; the absorbed kernels dequantize "
                         "in-kernel. Roughly halves latent cache bytes "
                         "again. Needs --latent; applies the absorbed "
                         "NoPE overrides like --paged")
    args = ap.parse_args(argv)

    latent = (LatentConfig(enabled=True, compression=args.latent)
              if args.latent else None)
    cfg = get_config(args.arch, latent)
    if args.reduced:
        cfg = reduced(cfg)
        if latent:
            cfg = dataclasses.replace(cfg, latent=latent)
    if args.paged:
        if latent is None:
            raise SystemExit("--paged needs --latent: block sharing only "
                             "pays off on the absorbed latent cache")
        # prefix-shared latent blocks require the absorbed NoPE decode —
        # no registry arch ships that way, so the flag applies the same
        # overrides the absorbed kernels are benchmarked with
        cfg = dataclasses.replace(cfg, pos_emb="none", qkv_bias=False)
    if args.prefill_chunk is not None or args.token_budget is not None:
        if latent is None:
            raise SystemExit("--prefill-chunk/--token-budget need --latent: "
                             "chunks resume mid-prompt through the absorbed "
                             "carry-in latent prefill path")
        cfg = dataclasses.replace(cfg, pos_emb="none", qkv_bias=False)
    if args.quant_cache:
        if latent is None:
            raise SystemExit("--quant-cache needs --latent: only the latent "
                             "c_k/c_v cache has an int8 storage form")
        # int8 latents are read by the absorbed decode/prefill kernels
        # only — apply the same NoPE overrides as --paged
        cfg = dataclasses.replace(cfg, pos_emb="none", qkv_bias=False)

    key = jax.random.PRNGKey(args.seed)
    params = T.init_params(key, cfg)
    if args.ckpt_dir:
        ckpt = CheckpointManager(args.ckpt_dir)
        (params, _), _ = ckpt.restore((params, jax.tree.map(jnp.zeros_like,
                                                            params)))

    if args.prompt:
        prompts = [tokenizer.encode(t) for t in args.prompt]
    else:
        prompts = synthetic_prompts(key, args.batch, args.prompt_len,
                                    cfg.vocab_size)
    max_len = args.max_len or (max(p.size for p in prompts) + args.gen_len)
    if args.paged and max_len % args.block_size:  # pool views tile blocks
        max_len += args.block_size - max_len % args.block_size

    def make_requests():
        return [Request(p, SamplingParams(
            temperature=args.temperature, top_k=args.top_k, top_p=args.top_p,
            seed=args.seed + i, max_new_tokens=args.gen_len,
            eos_id=args.eos_id), deadline_s=args.deadline_s,
            ttft_deadline_s=args.ttft_deadline_s)
            for i, p in enumerate(prompts)]

    mesh = _parse_mesh(args.mesh) if args.mesh else None
    engine = Engine(cfg, params, num_slots=args.num_slots, max_len=max_len,
                    mesh=mesh, paged=args.paged, block_size=args.block_size,
                    max_queue=args.max_queue if args.serve else None,
                    metrics=MetricsRegistry() if args.serve else None,
                    token_budget=args.token_budget,
                    prefill_chunk=args.prefill_chunk,
                    cache_dtype="int8" if args.quant_cache else "fp")
    if args.serve:
        return _serve_mode(args, cfg, engine, prompts)
    with _sigint_drain(engine):
        if not args.no_warmup:  # compile prefill/decode/scatter shapes once
            engine.run(make_requests())
        requests = make_requests()
        done = engine.run(requests)
    st = engine.last_stats
    rep = engine.cache_report()
    life = engine.lifecycle_report()

    mesh_lbl = "x".join(str(mesh.shape[a]) for a in mesh.axis_names) \
        if mesh else "none"
    rings = sorted({l.cache_len for l in engine.arena.layouts[0]
                    + engine.arena.layouts[1]
                    if l is not None and l.is_ring})
    ring_lbl = f" ring_slots={'/'.join(map(str, rings))}" if rings else ""
    print(f"[serve] arch={cfg.name} latent={args.latent} "
          f"slots={args.num_slots} max_len={max_len} mesh={mesh_lbl}"
          f"{ring_lbl}")
    print(f"[serve] engine: {st['requests']} reqs, {st['tokens']} toks in "
          f"{st['seconds']:.3f} s -> {st['req_per_s']:.2f} req/s, "
          f"{st['tok_per_s']:.1f} tok/s "
          f"({st['seconds'] * 1e3 / max(st['tokens'], 1):.2f} ms/tok, "
          f"{st['steps']} fused steps)")
    kind = "dense k/v"
    if cfg.latent.enabled:
        kind = ("int8 latent c_k/c_v" if args.quant_cache
                else "latent c_k/c_v")
    print(f"[serve] cache/slot: {rep['slot_bytes'] / 1e3:.1f} KB "
          f"({kind}) vs dense {rep['dense_slot_bytes'] / 1e3:.1f} KB "
          f"(ratio {rep['ratio']:.2f})")
    if args.quant_cache:
        print(f"[serve] quant: int8 cache {rep['slot_bytes'] / 1e3:.1f} KB "
              f"vs fp latent {rep['fp_slot_bytes'] / 1e3:.1f} KB/slot "
              f"({rep['fp_slot_bytes'] / max(rep['slot_bytes'], 1):.2f}x "
              f"smaller; {rep['compression_vs_dense']:.2f}x vs dense)")
    if args.paged:
        print(f"[serve] paged: block_size={args.block_size} "
              f"blocks={rep['blocks_in_use']}/{rep['num_blocks']} in use, "
              f"prefix_hit_rate={rep['prefix_hit_rate']:.2%} "
              f"({rep['prefill_tokens_saved']} prompt toks served from "
              f"cache, {rep['prefill_tokens_computed']} prefilled)")
    if life["counters"]:
        kv = " ".join(f"{k}={v}" for k, v in sorted(life["counters"].items()))
        print(f"[serve] lifecycle: {kv}")
    _print_scheduler(engine)
    for r in sorted(done, key=lambda r: r.request_id):
        text = tokenizer.decode(r.output_tokens)[:60]
        print(f"[req {r.request_id}] prompt={r.prompt.size} toks -> "
              f"{r.num_generated} toks ({r.finish_reason}): {text!r}")
    return sorted(done, key=lambda r: r.request_id)


if __name__ == "__main__":
    main()
