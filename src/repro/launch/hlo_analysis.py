"""Post-partitioning HLO analysis for the roofline report.

Why not ``compiled.cost_analysis()``: XLA's HloCostAnalysis visits each
instruction ONCE — a ``while`` body lowered from ``lax.scan`` over L layer
groups is counted a single time, undercounting FLOPs/bytes by ~L×
(verified empirically; see EXPERIMENTS.md §Dry-run notes). This module
parses ``compiled.as_text()`` (the per-device SPMD module), builds the
computation call graph, derives loop trip counts from the scan condition
constants, and multiplies through.

Counted:
  - flops: dot ops (2 · result_elems · contraction_size), anywhere in the
    module (including inside fusions) × computation multiplicity.
  - collective_bytes: all-reduce / all-gather / reduce-scatter /
    all-to-all / collective-permute / collective-broadcast result bytes ×
    multiplicity (wire-traffic proxy; all-reduce counted once, ring
    overheads folded into the link-bandwidth constant).
  - traffic_bytes: Σ result bytes of top-level (non-fusion-body)
    instructions × multiplicity × 2 (each value written once, read ~once)
    — an HBM-traffic proxy that is consistent across perf iterations.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "f8e4m3": 1, "f8e3m4": 1, "f8e8m0fnu": 1, "f8e4m3b11fnuz": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COMP_HDR = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s+\(.*\)\s*->")
_INSTR = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*"
    r"((?:\([^)]*\))|(?:\w+\[[^\]]*\](?:\{[^}]*\})?))\s+"
    r"([\w\-]+)")
_TRIP = re.compile(r'known_trip_count\\?":\{\\?"n\\?":\\?"(\d+)')
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute", "collective-broadcast")


def shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def shape_elems(shape_str: str) -> int:
    m = _SHAPE_RE.search(shape_str)
    if not m:
        return 0
    n = 1
    for d in m.group(2).split(","):
        if d:
            n *= int(d)
    return n


@dataclasses.dataclass
class Instr:
    name: str
    shape: str
    opcode: str
    line: str


@dataclasses.dataclass
class Computation:
    name: str
    instrs: List[Instr]
    shapes: Dict[str, str]


def _parse_computations(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    entry_name = None
    for line in text.splitlines():
        hdr = _COMP_HDR.match(line)
        if hdr and "{" in line:
            cur = Computation(hdr.group(2), [], {})
            comps[cur.name] = cur
            if hdr.group(1):
                entry_name = cur.name
            continue
        if cur is None:
            continue
        if line.strip() == "}":
            cur = None
            continue
        m = _INSTR.match(line)
        if m:
            ins = Instr(m.group(1), m.group(2), m.group(3), line)
            cur.instrs.append(ins)
            cur.shapes[ins.name] = ins.shape
    if entry_name:
        comps["__entry__"] = comps[entry_name]
    return comps


_REF = re.compile(r"%?([\w\.\-]+)")


def _attr_comp(line: str, attr: str) -> List[str]:
    out = []
    for m in re.finditer(attr + r"=\s*{?\s*%?([\w\.\-]+)", line):
        out.append(m.group(1))
    return out


def _trip_count(while_line: str, cond: Optional[Computation]) -> int:
    """Prefer XLA's known_trip_count backend_config; fall back to the
    constant in the scan condition (cond compares induction var < N)."""
    m = _TRIP.search(while_line)
    if m:
        return int(m.group(1))
    best = 1
    if cond is not None:
        for ins in cond.instrs:
            if ins.opcode == "constant":
                mm = re.search(r"constant\((\d+)\)", ins.line)
                if mm:
                    best = max(best, int(mm.group(1)))
    return best


def _multiplicities(comps: Dict[str, Computation]) -> Dict[str, float]:
    entry = comps.get("__entry__")
    mult: Dict[str, float] = defaultdict(float)
    if entry is None:
        return mult
    seen = set()

    def visit(comp: Computation, m: float):
        key = (comp.name,)
        mult[comp.name] += m
        for ins in comp.instrs:
            if ins.opcode == "while":
                bodies = _attr_comp(ins.line, "body")
                conds = _attr_comp(ins.line, "condition")
                cond_comp = comps.get(conds[0]) if conds else None
                trip = _trip_count(ins.line, cond_comp)
                for b in bodies:
                    if b in comps:
                        visit(comps[b], m * trip)
                for c in conds:
                    if c in comps:
                        visit(comps[c], m * (trip + 1))
            elif ins.opcode == "fusion":
                for f in _attr_comp(ins.line, "calls"):
                    if f in comps:
                        visit(comps[f], m)
            elif ins.opcode == "call":
                for f in _attr_comp(ins.line, "to_apply"):
                    if f in comps:
                        visit(comps[f], m)
            elif ins.opcode == "conditional":
                for attr in ("true_computation", "false_computation",
                             "branch_computations"):
                    for f in _attr_comp(ins.line, attr):
                        if f in comps:
                            visit(comps[f], m)

    visit(entry, 1.0)
    return mult


_DOT_DIMS = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_DOT_OPERANDS = re.compile(r"dot\(([^)]*)\)")


def _dot_flops(ins: Instr, comp: Computation) -> float:
    ops = _DOT_OPERANDS.search(ins.line)
    if not ops:
        return 0.0
    names = [_REF.search(x.strip()).group(1) for x in ops.group(1).split(",")]
    if not names:
        return 0.0
    lhs_shape = comp.shapes.get(names[0], "")
    m = _SHAPE_RE.search(lhs_shape)
    # operands may carry inline shapes: "f32[8,16]{1,0} %x"
    if m is None:
        m = _SHAPE_RE.search(ops.group(1))
    if m is None:
        return 0.0
    lhs_dims = [int(d) for d in m.group(2).split(",") if d]
    cd = _DOT_DIMS.search(ins.line)
    contracting = [int(i) for i in cd.group(1).split(",") if i] if cd else []
    csize = 1
    for i in contracting:
        if i < len(lhs_dims):
            csize *= lhs_dims[i]
    return 2.0 * shape_elems(ins.shape) * csize


def analyze(hlo_text: str) -> Dict[str, float]:
    comps = _parse_computations(hlo_text)
    mult = _multiplicities(comps)
    flops = 0.0
    coll: Dict[str, float] = defaultdict(float)
    coll_count: Dict[str, int] = defaultdict(int)
    writes = 0.0
    for name, comp in comps.items():
        if name == "__entry__":
            continue
        m = mult.get(name, 0.0)
        if m == 0.0:
            continue
        is_fusion_body = name.startswith("fused_") or ".fused" in name
        for ins in comp.instrs:
            if ins.opcode == "dot":
                flops += m * _dot_flops(ins, comp)
            if ins.opcode in _COLLECTIVES:
                b = shape_bytes(ins.shape)
                coll[ins.opcode] += m * b
                coll_count[ins.opcode] += 1
            if not is_fusion_body and ins.opcode not in (
                    "parameter", "constant", "get-tuple-element", "tuple",
                    "bitcast", "while", "conditional"):
                if ins.opcode == "dynamic-update-slice":
                    # in-place update: only the slice is written, not the
                    # whole carried buffer — use the update operand's bytes
                    ops = re.search(r"dynamic-update-slice\(([^)]*)\)", ins.line)
                    if ops:
                        parts = [x.strip() for x in ops.group(1).split(",")]
                        if len(parts) >= 2:
                            upd = _REF.search(parts[1])
                            if upd and upd.group(1) in comp.shapes:
                                writes += m * shape_bytes(comp.shapes[upd.group(1)])
                                continue
                writes += m * shape_bytes(ins.shape)
    return {
        "flops": flops,
        "collective_bytes": sum(coll.values()),
        "collectives": dict(coll),
        "collective_op_counts": dict(coll_count),
        "traffic_bytes": 2.0 * writes,
    }
