import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this proves the distribution config is coherent on the
production mesh (16×16 single-pod / 2×16×16 multi-pod) WITHOUT hardware:
``jax.jit(step, in_shardings, out_shardings).lower(**specs).compile()``
must succeed; ``memory_analysis()`` proves it fits; the HLO analyzer
extracts the roofline terms (FLOPs / traffic / collective bytes with
while-loop trip-count multiplicity — see hlo_analysis.py).

Results are written incrementally to a JSON file so the sweep is
resumable and other tooling (benchmarks/roofline.py) can consume it.

Usage:
  python -m repro.launch.dryrun --arch h2o-danube-3-4b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--latent 0.3]
  python -m repro.launch.dryrun --all --both-meshes --out results/dryrun.json
"""
import argparse
import dataclasses
import json
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs import (ASSIGNED, LatentConfig, REGISTRY, SHAPES,
                           get_config, input_specs, shape_applicable)
from repro.configs.base import ModelConfig, ShapeConfig
from repro.distributed import sharding as shd
from repro.launch import hlo_analysis
from repro.launch.mesh import make_production_mesh
from repro.models import lm, transformer as T
from repro.optim import AdamW, AdamWConfig

# TPU v5e hardware constants (target platform; see brief)
PEAK_FLOPS = 197e12       # bf16 per chip
HBM_BW = 819e9            # bytes/s per chip
ICI_BW = 50e9             # bytes/s per link

# per-arch memory policy: models whose fp32 moments + fp32 accumulation
# cannot fit 16 GB/chip use the 8-bit-Adam + bf16-accum configuration
# (optim/adamw.py blockwise int8 moments) — a deployed-system choice,
# recorded per cell in EXPERIMENTS.md §Dry-run.
MEMORY_POLICY = {
    "llama4-maverick-400b-a17b": {"moments_dtype": "int8",
                                  "accum_dtype": "bfloat16",
                                  "grad_accum": 4},
    "qwen1.5-110b": {"moments_dtype": "bfloat16", "grad_accum": 8},
    "chameleon-34b": {"grad_accum": 8},
}


def abstract_tree(fn, *args, **kw):
    return jax.eval_shape(fn, *args, **kw)


def build_cell(cfg: ModelConfig, shape: ShapeConfig, mesh,
               remat_policy: str = "nothing",
               grad_accum: int = 4):
    """Returns (jitted_fn, arg_shapes, arg_shardings) for the cell kind."""
    policy = MEMORY_POLICY.get(cfg.name, {})
    moments_dtype = policy.get("moments_dtype", "float32")
    accum_dtype = policy.get("accum_dtype", "float32")
    grad_accum = policy.get("grad_accum", grad_accum)
    specs_in = input_specs(cfg, shape)
    key = jax.random.PRNGKey(0)
    params_shape = abstract_tree(lambda: T.init_params(key, cfg))
    pspecs = shd.param_specs(params_shape, mesh)
    pshard = shd.to_named(mesh, pspecs)
    bspecs = shd.batch_specs(mesh, specs_in)
    bshard = shd.to_named(mesh, bspecs)

    if shape.kind == "train":
        opt = AdamW(AdamWConfig(moments_dtype=moments_dtype))
        opt_shape = abstract_tree(lambda: opt.init(params_shape))
        ospecs = shd.opt_specs(opt_shape, pspecs, mesh)
        oshard = shd.to_named(mesh, ospecs)
        step_fn = lm.make_train_step(cfg, opt, remat=True,
                                     remat_policy=remat_policy,
                                     grad_accum=grad_accum,
                                     accum_dtype=accum_dtype)
        sshard = shd.to_named(mesh, jax.sharding.PartitionSpec())
        jfn = jax.jit(
            step_fn,
            in_shardings=(pshard, oshard, bshard, sshard),
            out_shardings=(pshard, oshard, None),
            donate_argnums=(0, 1),
        )
        args = (params_shape, opt_shape,
                specs_in, jax.ShapeDtypeStruct((), jnp.int32))
        return jfn, args

    if shape.kind == "prefill":
        step_fn = lm.make_prefill_step(cfg, max_len=shape.seq_len)
        cache_shape = abstract_tree(
            lambda: T.init_cache(cfg, shape.global_batch, shape.seq_len))
        cspecs = shd.cache_specs(mesh, cache_shape)
        cshard = shd.to_named(mesh, cspecs)
        jfn = jax.jit(step_fn, in_shardings=(pshard, bshard),
                      out_shardings=(cshard, None))
        return jfn, (params_shape, specs_in)

    # decode: one token against a seq_len cache
    step_fn = lm.make_decode_step(cfg)
    cache_shape = abstract_tree(
        lambda: T.init_cache(cfg, shape.global_batch, shape.seq_len))
    cspecs = shd.cache_specs(mesh, cache_shape)
    cshard = shd.to_named(mesh, cspecs)
    jfn = jax.jit(step_fn, in_shardings=(pshard, cshard, bshard),
                  out_shardings=(None, cshard), donate_argnums=(1,))
    return jfn, (params_shape, cache_shape, specs_in)


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             latent: Optional[float] = None,
             remat_policy: str = "nothing") -> Dict[str, Any]:
    shape = SHAPES[shape_name]
    lat = None
    if latent is not None:
        lat = LatentConfig(enabled=True, compression=latent)
    cfg = get_config(arch, lat)
    ok, why = shape_applicable(cfg, shape)
    out: Dict[str, Any] = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "latent": latent, "remat_policy": remat_policy,
    }
    if not ok:
        out["status"] = "skipped"
        out["reason"] = why
        return out
    t0 = time.time()
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        with mesh:
            jfn, args = build_cell(cfg, shape, mesh, remat_policy)
            lowered = jfn.lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
            mem = compiled.memory_analysis()
            cost = compiled.cost_analysis()
            hlo = compiled.as_text()
            ana = hlo_analysis.analyze(hlo)
        n_chips = 512 if multi_pod else 256
        flops_dev = ana["flops"]
        traffic_dev = ana["traffic_bytes"]
        coll_dev = ana["collective_bytes"]
        compute_s = flops_dev / PEAK_FLOPS
        memory_s = traffic_dev / HBM_BW
        collective_s = coll_dev / ICI_BW
        # useful-FLOPs yardstick: 6·N·D train, 2·N·D prefill (D = all
        # tokens), 2·N·B decode (one new token per sequence)
        if shape.kind == "train":
            model_flops = 6 * cfg.num_active_params() * shape.tokens
        elif shape.kind == "prefill":
            model_flops = 2 * cfg.num_active_params() * shape.tokens
        else:
            model_flops = 2 * cfg.num_active_params() * shape.global_batch
        out.update({
            "status": "ok",
            "lower_s": round(t_lower, 1),
            "compile_s": round(t_compile, 1),
            "memory": {
                "argument_bytes": mem.argument_size_in_bytes,
                "output_bytes": mem.output_size_in_bytes,
                "temp_bytes": mem.temp_size_in_bytes,
                "alias_bytes": mem.alias_size_in_bytes,
                "peak_per_device": mem.argument_size_in_bytes
                + mem.temp_size_in_bytes,
            },
            "cost_analysis": {
                "flops_single_visit": cost.get("flops", 0.0),
                "bytes_single_visit": cost.get("bytes accessed", 0.0),
            },
            "hlo_analysis": {
                "flops_per_device": flops_dev,
                "traffic_bytes_per_device": traffic_dev,
                "collective_bytes_per_device": coll_dev,
                "collectives": ana["collectives"],
                "collective_op_counts": ana["collective_op_counts"],
            },
            "roofline": {
                "compute_s": compute_s,
                "memory_s": memory_s,
                "collective_s": collective_s,
                "bound": max(
                    (("compute", compute_s), ("memory", memory_s),
                     ("collective", collective_s)), key=lambda kv: kv[1])[0],
                "model_flops_total": model_flops,
                "hlo_flops_total": flops_dev * n_chips,
                "useful_flops_ratio": model_flops / (flops_dev * n_chips + 1e-30),
                "roofline_fraction": model_flops / n_chips / PEAK_FLOPS
                / max(compute_s, memory_s, collective_s, 1e-30),
            },
        })
    except Exception as e:  # noqa: BLE001 — recorded, sweep continues
        out["status"] = "error"
        out["error"] = f"{type(e).__name__}: {e}"
        out["traceback"] = traceback.format_exc()[-4000:]
    out["wall_s"] = round(time.time() - t0, 1)
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(REGISTRY) + ["all"], default=None)
    ap.add_argument("--shape", choices=list(SHAPES) + ["all"], default="all")
    ap.add_argument("--all", action="store_true", help="all assigned archs")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--latent", type=float, default=None,
                    help="enable LatentLLM compression at this ratio")
    ap.add_argument("--remat-policy", default="nothing",
                    choices=["nothing", "dots"])
    ap.add_argument("--out", default="results/dryrun.json")
    ap.add_argument("--resume", action="store_true",
                    help="skip cells already present in --out")
    args = ap.parse_args()

    archs = ASSIGNED if (args.all or args.arch in (None, "all")) else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    results = []
    done = set()
    if args.resume and os.path.exists(args.out):
        with open(args.out) as f:
            results = json.load(f)
        done = {(r["arch"], r["shape"], r["mesh"], r.get("latent"),
                 r.get("remat_policy", "nothing"))
                for r in results if r.get("status") in ("ok", "skipped")}

    for mp in meshes:
        mesh_name = "2x16x16" if mp else "16x16"
        for arch in archs:
            for shape in shapes:
                key = (arch, shape, mesh_name, args.latent, args.remat_policy)
                if key in done:
                    continue
                print(f"[dryrun] {arch} × {shape} × {mesh_name}"
                      f"{' latent=' + str(args.latent) if args.latent else ''}",
                      flush=True)
                r = run_cell(arch, shape, mp, args.latent, args.remat_policy)
                print(f"  -> {r['status']} ({r.get('wall_s', '?')}s)"
                      + (f" bound={r['roofline']['bound']}"
                         f" mem={r['memory']['peak_per_device']/1e9:.2f}GB/dev"
                         if r["status"] == "ok" else
                         f" {r.get('reason', r.get('error', ''))[:200]}"),
                      flush=True)
                results = [x for x in results
                           if (x["arch"], x["shape"], x["mesh"],
                               x.get("latent"), x.get("remat_policy", "nothing")) != key]
                results.append(r)
                with open(args.out, "w") as f:
                    json.dump(results, f, indent=1)
    n_ok = sum(1 for r in results if r["status"] == "ok")
    n_skip = sum(1 for r in results if r["status"] == "skipped")
    n_err = sum(1 for r in results if r["status"] == "error")
    print(f"[dryrun] done: {n_ok} ok / {n_skip} skipped / {n_err} errors")
    if n_err:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
