"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state. Single pod = 256 chips (16 data × 16 model);
multi-pod adds a leading 'pod' axis (2 × 256 = 512 chips).
"""
from __future__ import annotations

import jax
import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices, found {len(devices)} — run under "
            "dryrun.py (XLA_FLAGS=--xla_force_host_platform_device_count=512) "
            "or on real hardware")
    if len(devices) == n:
        return jax.make_mesh(shape, axes)
    dev = np.asarray(devices[:n]).reshape(shape)
    return jax.sharding.Mesh(dev, axes)


def make_debug_mesh(data: int = 2, model: int = 4):
    """Small mesh for CPU distribution tests (8 fake devices)."""
    devices = jax.devices()
    n = data * model
    dev = np.asarray(devices[:n]).reshape(data, model)
    return jax.sharding.Mesh(dev, ("data", "model"))
