"""Training driver: data pipeline -> sharded train loop -> checkpoints.

Runs the same code path at every scale:
  - CPU smoke:     python -m repro.launch.train --arch opt-125m --reduced \
                       --steps 50 --mesh debug
  - production:    --mesh pod / --mesh multipod under a real TPU slice
                   (the dry-run validates those meshes offline).

Fault tolerance: CheckpointManager (atomic, keep-k) + deterministic data
(replay by step) + ElasticManager hooks. Gradient compression
(--grad-compress powersgd) applies the PowerSGD low-rank approximation +
error feedback before the optimizer — the factors are what a multi-pod
reduction would move (optim/compression.py).
"""
from __future__ import annotations

import argparse
import dataclasses
import math
import time
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import REGISTRY, LatentConfig, get_config, reduced
from repro.checkpoint import CheckpointManager
from repro.data import DataConfig, TokenDataset
from repro.distributed import sharding as shd
from repro.launch.mesh import make_debug_mesh, make_production_mesh
from repro.models import lm, transformer as T
from repro.optim import (AdamW, AdamWConfig, GradCompressionConfig,
                         compress_decompress, init_compression_state)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="opt-125m", choices=list(REGISTRY))
    ap.add_argument("--reduced", action="store_true",
                    help="CPU-sized config of the same family")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--mesh", choices=["none", "debug", "pod", "multipod"],
                    default="none")
    ap.add_argument("--latent", type=float, default=None)
    ap.add_argument("--grad-compress", choices=["none", "powersgd", "int8"],
                    default="none")
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    latent = (LatentConfig(enabled=True, compression=args.latent)
              if args.latent else None)
    cfg = get_config(args.arch, latent)
    if args.reduced:
        cfg = reduced(cfg)
        if latent:
            cfg = dataclasses.replace(cfg, latent=latent)
    cfg = dataclasses.replace(cfg, dtype="float32") \
        if args.mesh in ("none", "debug") else cfg

    mesh = None
    if args.mesh == "debug":
        mesh = make_debug_mesh()
    elif args.mesh == "pod":
        mesh = make_production_mesh()
    elif args.mesh == "multipod":
        mesh = make_production_mesh(multi_pod=True)

    key = jax.random.PRNGKey(args.seed)
    params = T.init_params(key, cfg)
    opt = AdamW(AdamWConfig(lr=args.lr, warmup_steps=max(args.steps // 20, 5),
                            total_steps=args.steps))
    opt_state = opt.init(params)
    train_step = lm.make_train_step(cfg, opt, remat=False,
                                    grad_accum=args.grad_accum)

    gc_cfg = GradCompressionConfig(method=args.grad_compress)
    gc_state = (init_compression_state(params, gc_cfg)
                if args.grad_compress != "none" else None)

    data = TokenDataset(DataConfig(seq_len=args.seq_len,
                                   global_batch=args.batch,
                                   seed=args.seed))

    ckpt = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    start_step = 0
    if ckpt and args.resume and ckpt.latest_step() is not None:
        (params, opt_state), extra = ckpt.restore((params, opt_state))
        start_step = extra.get("step", 0) + 1
        print(f"[train] resumed from step {start_step - 1}")

    if args.grad_compress != "none":
        # decomposed path so the compressor sits between grad and update
        def loss_and_grads(params, batch):
            (loss, _), grads = jax.value_and_grad(
                lambda p: lm.loss_fn(p, cfg, batch, remat=False),
                has_aux=True)(params)
            return loss, grads
        loss_and_grads = jax.jit(loss_and_grads)

        def step_fn(params, opt_state, gc_state, batch, step):
            loss, grads = loss_and_grads(params, batch)
            grads, gc_state, stats = compress_decompress(grads, gc_state, gc_cfg)
            params, opt_state = jax.jit(opt.update)(grads, opt_state, params,
                                                    step)
            return params, opt_state, gc_state, loss, stats
    else:
        jit_kwargs = {}
        if mesh is not None:
            pspecs = shd.param_specs(jax.eval_shape(lambda: params), mesh)
            pshard = shd.to_named(mesh, pspecs)
            jit_kwargs = dict(in_shardings=(pshard, None, None, None),
                              out_shardings=(pshard, None, None))
        train_step = jax.jit(train_step, donate_argnums=(0, 1), **jit_kwargs)

    ctx = mesh if mesh is not None else _nullcontext()
    losses = []
    with ctx:
        t0 = time.time()
        for step in range(start_step, args.steps):
            batch = jax.tree.map(jnp.asarray, data.batch_at(step))
            sstep = jnp.asarray(step, jnp.int32)
            if args.grad_compress != "none":
                params, opt_state, gc_state, loss, stats = step_fn(
                    params, opt_state, gc_state, batch, sstep)
            else:
                params, opt_state, metrics = train_step(
                    params, opt_state, batch, sstep)
                loss = metrics["loss"]
            losses.append(float(loss))
            if step % args.log_every == 0 or step == args.steps - 1:
                dt = time.time() - t0
                msg = (f"[train] step {step:5d} loss {float(loss):8.4f} "
                       f"({dt / max(step - start_step + 1, 1):.3f}s/step)")
                if args.grad_compress != "none":
                    msg += (f" comm {stats['compressed_bytes'] / 1e6:.1f}MB"
                            f"/{stats['dense_bytes'] / 1e6:.1f}MB")
                print(msg, flush=True)
            if ckpt and (step + 1) % args.ckpt_every == 0:
                path = ckpt.save(step, (params, opt_state), {"step": step})
                print(f"[train] checkpoint -> {path}", flush=True)
    if ckpt:
        ckpt.save(args.steps - 1, (params, opt_state),
                  {"step": args.steps - 1})
    print(f"[train] final loss {losses[-1]:.4f} (start {losses[0]:.4f})")
    return params, losses


class _nullcontext:
    def __enter__(self):
        return None

    def __exit__(self, *a):
        return False


if __name__ == "__main__":
    main()
