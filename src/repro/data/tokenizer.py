"""Byte-level tokenizer (no external vocab files — offline-safe).

Token ids: 0..255 raw bytes, 256 = BOS/pad. Used for the OPT-family
perplexity benchmarks (the paper's C4/WT2/PTB substitutes — see DESIGN §6).
"""
from __future__ import annotations

import numpy as np

VOCAB = 257
BOS = 256


def encode(text: str) -> np.ndarray:
    return np.frombuffer(text.encode("utf-8"), dtype=np.uint8).astype(np.int32)


def decode(ids) -> str:
    b = bytes(int(i) for i in ids if 0 <= int(i) < 256)
    return b.decode("utf-8", errors="replace")
