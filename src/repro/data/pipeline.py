"""Deterministic sharded data pipeline.

Design constraints from the brief (1000+ node operation):
  - deterministic order keyed by (seed, step, shard) — replay after a node
    failure or elastic re-mesh reproduces the exact global batch;
  - host-local sharding: each data shard draws only its slice;
  - double-buffered prefetch via a background thread.

Sources: synthetic text (procedural corpus — offline substitute for C4,
DESIGN §6) or any UTF-8 file.
"""
from __future__ import annotations

import dataclasses
import hashlib
import queue
import threading
from typing import Dict, Iterator, Optional

import numpy as np

from repro.data import tokenizer

_WORDS = (
    "the of and to in a is that for it as was with be by on not he i this "
    "are or his from at which but have an had they you were their one all "
    "we can her has there been if more when will would who so no out up "
    "into them then she may over also new only like time state after made "
    "system model tensor latent attention compression rank joint svd layer "
    "weight matrix value query key output project train step loss grad"
).split()


def synthetic_corpus(n_tokens: int, seed: int = 0) -> str:
    """Markov-ish procedural text: enough structure for byte-LM training."""
    rng = np.random.default_rng(seed)
    out = []
    total = 0
    state = rng.integers(0, len(_WORDS))
    while total < n_tokens:
        # biased bigram: nearby vocabulary entries are likelier
        jump = rng.geometric(0.15) * rng.choice((-1, 1))
        state = int((state + jump) % len(_WORDS))
        w = _WORDS[state]
        out.append(w)
        total += len(w) + 1
        if rng.random() < 0.08:
            out.append(".")
    return " ".join(out)


@dataclasses.dataclass
class DataConfig:
    seq_len: int = 256
    global_batch: int = 8
    seed: int = 0
    n_tokens: int = 2_000_000
    text: Optional[str] = None  # overrides synthetic corpus


class TokenDataset:
    """Deterministic random-crop LM batches over a token buffer."""

    def __init__(self, cfg: DataConfig, shard_index: int = 0,
                 num_shards: int = 1):
        self.cfg = cfg
        text = cfg.text if cfg.text is not None else synthetic_corpus(
            cfg.n_tokens, cfg.seed)
        self.tokens = tokenizer.encode(text)
        assert len(self.tokens) > cfg.seq_len + 1, "corpus too small"
        self.shard_index = shard_index
        self.num_shards = num_shards
        assert cfg.global_batch % num_shards == 0
        self.local_batch = cfg.global_batch // num_shards

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        """Deterministic global batch slice for this shard at ``step``."""
        S = self.cfg.seq_len
        toks = np.empty((self.local_batch, S), np.int32)
        for row in range(self.local_batch):
            gi = self.shard_index * self.local_batch + row
            h = hashlib.sha256(
                f"{self.cfg.seed}:{step}:{gi}".encode()).digest()
            start = int.from_bytes(h[:8], "little") % (len(self.tokens) - S - 1)
            toks[row] = self.tokens[start:start + S]
        return {"tokens": toks, "labels": toks}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


class Prefetcher:
    """Double-buffered background prefetch."""

    def __init__(self, it: Iterator, depth: int = 2):
        self.q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._stop = threading.Event()

        def worker():
            for item in it:
                if self._stop.is_set():
                    return
                self.q.put(item)

        self.t = threading.Thread(target=worker, daemon=True)
        self.t.start()

    def __iter__(self):
        return self

    def __next__(self):
        return self.q.get()

    def close(self):
        self._stop.set()
