from repro.data.pipeline import DataConfig, Prefetcher, TokenDataset, synthetic_corpus
from repro.data import tokenizer

__all__ = ["DataConfig", "Prefetcher", "TokenDataset", "synthetic_corpus",
           "tokenizer"]
