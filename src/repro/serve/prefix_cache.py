"""Radix prefix cache: token-id prefixes -> chains of latent blocks.

SGLang-style prefix reuse for the paged serving engine: after a request
prefills, its prompt's latent ``c_k``/``c_v`` blocks are inserted into a
radix tree keyed by token ids (one node per ``block_size``-token chunk;
a shorter tail chunk may form a partial leaf). Admission walks the tree
with the new prompt and reuses the longest cached prefix — the engine
prefills only the uncached suffix.

Sharing contract (what keeps reuse bit-exact and refcounts sound):
  * the tree holds ONE pool reference per node; a slot that matches a
    chain takes its own reference on every FULL block it shares;
  * a block the new request would continue writing into (the match ends
    mid-block) is never shared in place — the arena copy-on-writes it,
    so tree blocks beyond their matched rows are never clobbered by a
    later request's prefill or decode writes;
  * eviction (LRU, leaves first) only ever frees nodes whose block has
    refcount 1 — i.e. held by the tree alone. A node referenced by a
    live slot has refcount >= 2, and since a slot's chain covers its
    full prefix path, every ancestor of a referenced node is referenced
    too — refcount-1 nodes therefore always peel off leaves-first.

Latent caches are prefix-safe to share because the models served paged
are NoPE/absorbed (no RoPE phase baked into c_k) and causal: the latent
at position t depends only on tokens <= t, so two prompts sharing a
token prefix share those latent rows exactly.
"""
from __future__ import annotations

from typing import List, Optional, Sequence, Tuple

from repro.serve.block_pool import BlockPool


class _Node:
    __slots__ = ("tokens", "block", "children", "parent", "last_used")

    def __init__(self, tokens: Tuple[int, ...], block: int,
                 parent: Optional["_Node"]):
        self.tokens = tokens
        self.block = block
        self.children: List[_Node] = []
        self.parent = parent
        self.last_used = 0


class RadixPrefixCache:
    """Maps token-id prefixes to block chains over a ``BlockPool``.

    ``match`` never mutates refcounts (the caller increfs the blocks it
    decides to share — see ``PagedLatentArena.admit``); ``insert`` takes
    one tree reference per newly adopted block; ``evict`` drops tree
    references LRU leaves-first among refcount-1 nodes."""

    def __init__(self, pool: BlockPool):
        self.pool = pool
        self.block_size = pool.block_size
        self.root = _Node((), -1, None)
        self._clock = 0

    # -- introspection -------------------------------------------------
    def _walk(self):
        stack = list(self.root.children)
        while stack:
            n = stack.pop()
            stack.extend(n.children)
            yield n

    @property
    def num_nodes(self) -> int:
        return sum(1 for _ in self._walk())

    @property
    def num_evictable(self) -> int:
        """Nodes held by the tree alone (refcount 1): the blocks eviction
        can free. Every refcount-1 node IS reachable leaves-first — a
        live slot referencing a descendant references the whole path."""
        return sum(1 for n in self._walk()
                   if self.pool.refcount(n.block) == 1)

    def _tick(self) -> int:
        self._clock += 1
        return self._clock

    # -- longest-prefix match ------------------------------------------
    def match(self, tokens: Sequence[int]) -> Tuple[int, List[int]]:
        """Longest cached prefix of ``tokens``.

        Returns ``(matched, blocks)``: ``matched`` cached token count
        and the chain of block ids covering rows [0, matched) — one per
        ``block_size`` rows, the last possibly partial. Only refreshes
        LRU stamps; takes no references."""
        toks = tuple(int(t) for t in tokens)
        node, matched, blocks = self.root, 0, []
        while True:
            best = None
            for ch in node.children:
                k = len(ch.tokens)
                if toks[matched:matched + k] == ch.tokens and \
                        (best is None or k > len(best.tokens)):
                    best = ch
            if best is None:
                break
            node = best
            node.last_used = self._tick()
            matched += len(node.tokens)
            blocks.append(node.block)
            if len(node.tokens) < self.block_size:
                break  # partial leaves have no children (insert invariant)
        return matched, blocks

    # -- insertion ------------------------------------------------------
    def insert(self, tokens: Sequence[int], blocks: Sequence[int]) -> int:
        """Cache a freshly prefilled prompt: ``tokens`` (length L) whose
        latent rows live in ``blocks`` (ceil(L / block_size) physical
        ids from the owning slot's table). Adopts one tree reference per
        block not already covered by an existing node; returns how many
        new nodes were created. Duplicate paths are deduped (the tree
        keeps its own block; the slot's copy stays private).

        Same-block extension: when an existing PARTIAL child holds the
        SAME physical block and its tokens are a prefix of the new
        chunk, the node is upgraded in place (tokens extended, no new
        reference). This is the preemption-republish path — the owning
        slot kept decoding into its tail block after the original
        insert, so the tree's node now covers more valid rows. Without
        the upgrade a second node would adopt a second tree reference
        on the same block, pinning it unevictable forever (eviction
        requires refcount 1). The in-place extension is sound because
        shared blocks are never written (admission copy-on-writes
        mid-block matches) — only the owning slot filled those rows."""
        toks = tuple(int(t) for t in tokens)
        bs = self.block_size
        node, created = self.root, 0
        n_chunks = (len(toks) + bs - 1) // bs
        for j in range(n_chunks):
            chunk = toks[j * bs:(j + 1) * bs]
            k = len(chunk)
            same_block = next(
                (ch for ch in node.children
                 if ch.block == int(blocks[j]) and len(ch.tokens) < k
                 and chunk[:len(ch.tokens)] == ch.tokens), None)
            if len(chunk) == bs:
                nxt = next((ch for ch in node.children
                            if ch.tokens == chunk), None)
                if nxt is None and same_block is not None:
                    same_block.tokens = chunk  # partial -> full, same ref
                    nxt = same_block
                if nxt is None:
                    nxt = _Node(chunk, int(blocks[j]), node)
                    self.pool.incref(nxt.block)
                    node.children.append(nxt)
                    created += 1
                nxt.last_used = self._tick()
                node = nxt
            else:
                # partial tail: attach only if no existing child already
                # covers it (a longer partial or a full block with the
                # same leading tokens); partial nodes never get children
                covered = next(
                    (ch for ch in node.children
                     if len(ch.tokens) >= k and ch.tokens[:k] == chunk),
                    None)
                if covered is not None:
                    covered.last_used = self._tick()
                elif same_block is not None:
                    same_block.tokens = chunk  # extend partial, same ref
                    same_block.last_used = self._tick()
                else:
                    leaf = _Node(chunk, int(blocks[j]), node)
                    self.pool.incref(leaf.block)
                    leaf.last_used = self._tick()
                    node.children.append(leaf)
                    created += 1
        return created

    # -- eviction -------------------------------------------------------
    def evict(self, n_blocks: int) -> int:
        """Free up to ``n_blocks`` pool blocks by dropping LRU leaves
        whose block the tree alone holds (refcount 1). Evicting a leaf
        may expose its parent as the next candidate. Returns the number
        of blocks actually freed."""
        freed = 0
        while freed < n_blocks:
            victim = None
            for n in self._walk():
                if n.children or self.pool.refcount(n.block) != 1:
                    continue
                if victim is None or n.last_used < victim.last_used:
                    victim = n
            if victim is None:
                break
            self.pool.decref(victim.block)  # refcount 1 -> freed
            victim.parent.children.remove(victim)
            freed += 1
        return freed
