"""Serving engine API: continuous batching over a slot-based latent cache.

    from repro.serve import Engine, Request, SamplingParams

    eng = Engine(cfg, params, num_slots=8, max_len=256)
    req = eng.submit(prompt_tokens,
                     SamplingParams(temperature=0.8, top_p=0.95, seed=7,
                                    max_new_tokens=64, eos_id=EOS))
    eng.run()                      # or eng.step() in your own loop
    print(req.output(), req.finish_reason, eng.last_stats)

Paged mode shares latent blocks across requests through a radix prefix
cache (absorbed/NoPE latent models only):

    eng = Engine(cfg, params, num_slots=8, max_len=256,
                 paged=True, block_size=16)
    ...
    print(eng.cache_report()["prefix_hit_rate"])

Robust serving (fault-tolerant request lifecycle):

    req = eng.submit(toks, priority=1, deadline_s=30.0)   # SLO per request
    eng.cancel(req)                                       # any time
    eng.drain(timeout_s=60.0)                             # graceful stop
    eng.lifecycle_report()["counters"]                    # preemptions, ...

    # deterministic fault injection for tests / chaos drills
    eng = Engine(cfg, params, faults=FaultInjector(seed=0, step_fail_p=0.1))

Async front-end (HTTP + SSE over a scheduler thread that owns the
engine; metrics at /metrics, graceful SIGINT drain):

    srv = ServeServer(Engine(cfg, params, metrics=MetricsRegistry()))
    host, port = srv.start()
    out = ServeClient(host, port).generate([1, 2, 3], max_new_tokens=16)
    srv.stop(drain=True)          # in-flight requests finish first
"""
from repro.serve.arena import (LatentCacheArena, arena_cache_bytes,
                               cache_bytes)
from repro.serve.block_pool import BlockPool
from repro.serve.client import ServeClient, ServeHTTPError
from repro.serve.engine import Engine
from repro.serve.faults import FaultInjector, TransientStepFault
from repro.serve.metrics import MetricsRegistry, RingHistogram
from repro.serve.paged import PagedLatentArena
from repro.serve.prefix_cache import RadixPrefixCache
from repro.serve.request import Request, RequestState, synthetic_prompts
from repro.serve.sampling import SamplingParams, sample_logits
from repro.serve.server import ServeServer

__all__ = ["BlockPool", "Engine", "FaultInjector", "LatentCacheArena",
           "MetricsRegistry", "PagedLatentArena", "RadixPrefixCache",
           "Request", "RequestState", "RingHistogram", "SamplingParams",
           "ServeClient", "ServeHTTPError", "ServeServer",
           "TransientStepFault", "arena_cache_bytes", "cache_bytes",
           "sample_logits", "synthetic_prompts"]
