"""Ref-counted fixed-size block pool backing the paged latent cache.

Pure host-side accounting: which of the ``num_blocks`` physical blocks
are free, and how many holders (live slot tables + radix-tree nodes)
reference each allocated block. The device-resident latent rows the
blocks index into live in ``serve.paged.PagedLatentArena``; the radix
tree that shares blocks across requests is ``serve.prefix_cache``.

Invariants (property-tested in tests/test_paged.py):
  * every block id is free XOR has refcount >= 1;
  * ``alloc`` hands out refcount-1 blocks; ``incref`` adds a holder;
    ``decref`` removes one and returns the block to the free list when
    the last holder drops it;
  * misuse (incref of a free block, decref below zero, double free)
    raises ``ValueError`` instead of silently corrupting the counts.
"""
from __future__ import annotations

from typing import List, Optional


class BlockPool:
    """Free-list allocation + refcounts over ``num_blocks`` blocks of
    ``block_size`` token rows each. Block id ``num_blocks`` is reserved
    as the out-of-bounds sentinel (never allocated)."""

    def __init__(self, num_blocks: int, block_size: int):
        if num_blocks < 1 or block_size < 1:
            raise ValueError("need num_blocks >= 1 and block_size >= 1")
        self.num_blocks, self.block_size = num_blocks, block_size
        self._free: List[int] = list(range(num_blocks - 1, -1, -1))
        self._free_set = set(self._free)
        self._ref = [0] * num_blocks

    # -- accounting ----------------------------------------------------
    @property
    def num_free(self) -> int:
        return len(self._free)

    @property
    def blocks_in_use(self) -> int:
        return self.num_blocks - len(self._free)

    def refcount(self, block: int) -> int:
        self._check(block)
        return self._ref[block]

    def is_free(self, block: int) -> bool:
        self._check(block)
        return block in self._free_set

    # -- allocation ----------------------------------------------------
    def alloc(self) -> Optional[int]:
        """Pop a free block with refcount 1, or None when exhausted."""
        if not self._free:
            return None
        block = self._free.pop()
        self._free_set.discard(block)
        self._ref[block] = 1
        return block

    def incref(self, block: int) -> int:
        self._check(block)
        if block in self._free_set:
            raise ValueError(f"incref of free block {block}")
        self._ref[block] += 1
        return self._ref[block]

    def decref(self, block: int) -> int:
        """Drop one holder; frees the block when the count hits zero.
        Returns the remaining refcount."""
        self._check(block)
        if block in self._free_set or self._ref[block] <= 0:
            raise ValueError(f"decref of free block {block}")
        self._ref[block] -= 1
        if self._ref[block] == 0:
            self._free.append(block)
            self._free_set.add(block)
        return self._ref[block]

    def _check(self, block: int) -> None:
        if not 0 <= block < self.num_blocks:
            raise ValueError(
                f"block {block} out of range [0, {self.num_blocks})")
