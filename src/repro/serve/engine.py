"""Continuous-batching serving engine over the slot-based latent arena.

The redesign ISSUE 3 asks for: requests with per-request sampling params
enter a queue; the engine admits them into free ``LatentCacheArena``
slots with a bucketed ragged prefill, then runs ONE fused decode
dispatch per step across ALL active slots — ragged per-slot positions,
per-slot sampling params and PRNG streams, per-slot finish detection,
streamed token callbacks, and slot recycling. Jit shapes are bucketed
(admission batch and prompt length round up to powers of two; the
decode shape is pinned to ``num_slots``), so mixed traffic never
recompiles per request.

Scope: token-mode attention models, INCLUDING sliding-window families
(gemma2 / danube): windowed layers run over a ring ``CacheLayout`` —
per-slot writes wrap mod ``cache_len``, admission fills each row's own
trailing window (padding can never clobber a shorter row's ring), and the
absorbed decode dispatches the (start, length) ring Pallas kernels, so
windowed configs keep the fast path. Recurrent families (ssm/hybrid) are
still rejected — a right-padded prefill would pollute their recurrent
state.

Paged mode (``paged=True``): the arena becomes a block-table
``PagedLatentArena`` over a shared ref-counted pool, admission
longest-prefix-matches each prompt against a radix tree of previously
served prompts and prefills ONLY the uncached suffix, and decode runs
the same single fused dispatch through a jitted block gather/scatter
(``lm.make_paged_engine_step``). Greedy tokens are bit-identical to the
linear arena; ``cache_report()`` gains prefix-hit and pool-occupancy
fields. Absorbed (NoPE) latent models only — see ``_validate_paged``.

Sharded serving: pass ``mesh=jax.sharding.Mesh(...)`` and the whole hot
path runs tensor/data-parallel — parameters placed by the training
``param_specs`` rules, the arena by ``serve_cache_specs`` (slots on the
data axes, heads on 'model', latent rank dims local), per-slot state
rows replicated, and the prefill/decode/scatter heads jitted with
NamedSharding in/out. Decode stays ONE fused dispatch per step; the
absorbed MLA Pallas kernels run per-shard when the head axis divides
the 'model' axis and fall back to the ref einsum path otherwise.
"""
from __future__ import annotations

import collections
import contextlib
import dataclasses
import time
from typing import Dict, Iterable, List, Optional, Sequence, Union

import jax
import numpy as np

from repro.configs.base import LatentConfig, ModelConfig
from repro.models import lm
from repro.models import sampling as smp
from repro.models import transformer as T
from repro.serve.arena import (LatentCacheArena, arena_cache_bytes,
                               arena_cache_shape)
from repro.serve.paged import PagedLatentArena
from repro.serve.request import Request
from repro.serve.sampling import SamplingParams


def _bucket(n: int, lo: int, hi: int) -> int:
    b = lo
    while b < n:
        b <<= 1
    return min(b, hi)


def _validate(cfg: ModelConfig) -> None:
    if cfg.input_mode != "tokens":
        raise ValueError("Engine serves token-mode models only")
    if cfg.family in ("ssm", "hybrid"):
        raise ValueError(
            "Engine does not serve recurrent (ssm/hybrid) families: "
            "right-padded ragged prefill would pollute the SSM state")
    # sliding-window configs are served: their layers carry a ring
    # CacheLayout (see serve/arena.py) and the decode kernels take the
    # (start, length) ring descriptor instead of a valid_len prefix


def _validate_paged(cfg: ModelConfig) -> None:
    """Paged serving shares position-aligned latent blocks across
    requests, which is only sound for absorbed (NoPE) latent attention:
    no RoPE phase baked into c_k, no qkv bias path, and no sliding
    windows (a ring wraps per slot — checked by the arena)."""
    if not (cfg.latent and cfg.latent.enabled):
        raise ValueError("paged serving needs latent attention "
                         "(cfg.latent.enabled)")
    if cfg.pos_emb == "rope" or cfg.qkv_bias:
        raise ValueError(
            "paged serving needs the absorbed decode path (pos_emb != "
            "'rope', no qkv bias): latent blocks are shared by token "
            "prefix, which RoPE-phased caches would break")


class Engine:
    """Continuous batching: submit() requests, step() until drained.

    One ``step()`` = (a) admit queued requests into free slots via a
    bucketed ragged prefill + arena scatter, then (b) a single fused
    decode dispatch over the whole arena. Finished slots (eos / stop
    token / length cap) are released immediately and refilled on the
    next step. ``run()`` drains everything and reports throughput."""

    def __init__(self, cfg: ModelConfig, params, *, num_slots: int = 4,
                 max_len: int = 128, pad_id: int = 0,
                 min_prompt_bucket: int = 8, mesh=None, paged: bool = False,
                 block_size: int = 16, num_blocks: Optional[int] = None):
        _validate(cfg)
        self.cfg, self.pad_id = cfg, pad_id
        self.min_prompt_bucket = min_prompt_bucket
        self.mesh = mesh
        self.paged = paged
        if paged:
            _validate_paged(cfg)
            self.arena = PagedLatentArena(cfg, num_slots, max_len,
                                          block_size=block_size,
                                          num_blocks=num_blocks, mesh=mesh)
            step = lm.make_paged_engine_step(cfg, self.arena.layout, pad_id)
            step_greedy = lm.make_paged_engine_step(
                cfg, self.arena.layout, pad_id, greedy=True)
            self._prefill_raw = lm.make_paged_engine_prefill(
                cfg, self.arena.layout)
        else:
            self.arena = LatentCacheArena(cfg, num_slots, max_len, mesh=mesh)
            step = lm.make_engine_step(cfg, pad_id)
            step_greedy = lm.make_engine_step(cfg, pad_id, greedy=True)
            self._prefill_raw = lm.make_engine_prefill(cfg, max_len)
        donate = (1,) if jax.default_backend() != "cpu" else ()
        self._prefill_fns: Dict[int, callable] = {}
        if mesh is not None:
            # Tensor/data-parallel serving: parameters placed with the
            # training param rules, the arena with serve_cache_specs,
            # and every per-slot state row replicated. The step heads
            # are jitted with NamedSharding in/out so nothing reshards
            # between steps and decode stays ONE fused dispatch.
            from jax.sharding import NamedSharding, PartitionSpec as P
            from repro.distributed import sharding as shd
            pspecs = shd.param_specs(jax.eval_shape(lambda: params), mesh)
            self._pshard = shd.to_named(mesh, pspecs)
            params = jax.device_put(params, self._pshard)
            rep = NamedSharding(mesh, P())
            self._rep = rep
            state = shd.engine_state_specs(mesh)
            srow = tuple(NamedSharding(mesh, state[k]) for k in
                         ("tok", "base_keys", "gen_count", "temperature",
                          "top_k", "top_p", "active"))
            if paged:
                # pool shards like the arena; tables / positions are
                # replicated indirection; pool shape never varies with
                # the admission bucket, so ONE prefill head serves all
                idx = tuple(NamedSharding(mesh, state[k]) for k in
                            ("block_tables", "pos"))
                step_in = (self._pshard, self.arena.shardings) + idx + srow
                self._prefill_fns[0] = jax.jit(
                    self._prefill_raw, donate_argnums=donate,
                    in_shardings=(self._pshard, self.arena.shardings)
                    + (rep,) * 8,
                    out_shardings=(rep, self.arena.shardings))
            else:
                step_in = (self._pshard, self.arena.shardings) + srow
            self._step_fn = jax.jit(
                step, donate_argnums=donate, in_shardings=step_in,
                out_shardings=(rep, self.arena.shardings))
            self._step_greedy = jax.jit(
                step_greedy, donate_argnums=donate, in_shardings=step_in,
                out_shardings=(rep, self.arena.shardings))
        else:
            self._pshard = None
            self._step_fn = jax.jit(step, donate_argnums=donate)
            self._step_greedy = jax.jit(step_greedy, donate_argnums=donate)
            self._prefill_fns[0] = jax.jit(
                self._prefill_raw, donate_argnums=donate if paged else ())
        self.params = params
        B = num_slots
        self._pos = np.zeros((B,), np.int32)  # paged: per-slot decode pos
        self._hits = 0                 # admissions with a nonzero match
        self._admitted = 0
        self._hit_tokens = 0           # prompt tokens served from cache
        self._prompt_tokens = 0
        self._prefill_computed = 0     # prompt tokens actually prefilled
        self._tok = np.zeros((B, 1), np.int32)
        self._base_keys = np.zeros((B, 2), np.uint32)
        self._gen_count = np.zeros((B,), np.int32)
        self._temp = np.zeros((B,), np.float32)
        self._top_k = np.zeros((B,), np.int32)
        self._top_p = np.ones((B,), np.float32)
        self._active = np.zeros((B,), bool)
        self._slots: List[Optional[Request]] = [None] * B
        self._queue: collections.deque = collections.deque()
        self._next_id = 0
        self.finished: List[Request] = []
        self.last_stats: Dict[str, float] = {}

    # -- intake --------------------------------------------------------
    def submit(self, prompt: Union[Request, Sequence[int], np.ndarray],
               sampling: Optional[SamplingParams] = None,
               on_token=None) -> Request:
        if isinstance(prompt, Request):
            if sampling is not None or on_token is not None:
                raise ValueError(
                    "pass sampling/on_token inside the Request, not "
                    "alongside it")
            req = prompt
        else:
            req = Request(np.asarray(prompt), sampling or SamplingParams(),
                          on_token=on_token)
        need = req.prompt.size + req.sampling.max_new_tokens
        if need > self.arena.max_len:
            raise ValueError(
                f"prompt({req.prompt.size}) + max_new_tokens"
                f"({req.sampling.max_new_tokens}) exceeds arena max_len "
                f"{self.arena.max_len}")
        req.request_id = self._next_id
        self._next_id += 1
        self._queue.append(req)
        return req

    def has_work(self) -> bool:
        return bool(self._queue) or bool(self._active.any())

    # -- the serving loop ----------------------------------------------
    def _ctx(self):
        """Mesh context for tracing: the constrain_* activation hints
        and the per-shard kernel gating read the active mesh at trace
        time, so every jitted head is traced inside ``with mesh:``."""
        return self.mesh if self.mesh is not None else contextlib.nullcontext()

    def _prefill_for(self, nb: int):
        """Jitted prefill head for an admission bucket of ``nb`` rows.

        Without a mesh one jit serves every bucket (shapes re-specialize
        inside it). Under a mesh each bucket needs its own out-shardings
        — the prefill cache batch dim is ``nb``, and whether it divides
        the data axes decides its spec — so heads are cached per bucket
        (a handful: admit buckets are powers of two up to num_slots)."""
        key = nb if self.mesh is not None else 0
        fn = self._prefill_fns.get(key)
        if fn is None:
            from repro.distributed import sharding as shd
            cshape = arena_cache_shape(self.cfg, nb, self.arena.max_len)
            cshard = shd.to_named(
                self.mesh,
                shd.serve_cache_specs(self.mesh, cshape,
                                      layouts=self.arena.layouts))
            fn = jax.jit(self._prefill_raw,
                         in_shardings=(self._pshard,) + (self._rep,) * 6,
                         out_shardings=(self._rep, cshard))
            self._prefill_fns[key] = fn
        return fn

    def step(self) -> bool:
        """Admit what fits, then one fused decode dispatch. Returns
        whether the engine still has queued or resident work."""
        self._admit()
        if self._active.any():
            # all-greedy batches take the argmax-only step (no vocab
            # sort / gumbel in the jaxpr); tokens are bit-identical
            fn = (self._step_greedy
                  if not (self._temp[self._active] > 0).any()
                  else self._step_fn)
            act = np.nonzero(self._active)[0]
            if self.paged:
                # host bookkeeping first: the block each active row
                # writes this step must exist before the fused dispatch
                for s in act:
                    self.arena.ensure(int(s), int(self._pos[s]))
                # jax's CPU runtime zero-copies aligned numpy inputs
                # into the ASYNC dispatch: any array mutated in place
                # while the step is in flight (pos below, tables via
                # release/ensure) is read torn by the compute — snapshot
                # them at the call
                with self._ctx():
                    tok, pool = fn(
                        self.params, self.arena.pool_cache,
                        self.arena.tables.copy(), self._pos.copy(),
                        self._tok, self._base_keys, self._gen_count.copy(),
                        self._temp, self._top_k, self._top_p,
                        self._active.copy())
                self.arena.pool_cache = pool
                self._pos[act] += 1
            else:
                with self._ctx():
                    tok, cache = fn(
                        self.params, self.arena.cache, self._tok,
                        self._base_keys, self._gen_count, self._temp,
                        self._top_k, self._top_p, self._active)
                self.arena.cache = cache
            toks = np.array(tok)  # writable copy: admission patches rows
            self._tok = toks
            for s in act:
                self._emit(int(s), int(toks[s, 0]))
        return self.has_work()

    def run(self, requests: Optional[Iterable] = None) -> List[Request]:
        """Submit ``requests`` (Request objects or raw prompts), drain
        the engine, and return the requests finished by this call in
        completion order. Throughput lands in ``last_stats``."""
        for r in requests or ():
            self.submit(r)
        n0, t0 = len(self.finished), time.perf_counter()
        steps = 0
        while self.has_work():
            self.step()
            steps += 1
        done = self.finished[n0:]
        dt = max(time.perf_counter() - t0, 1e-9)
        toks = sum(r.num_generated for r in done)
        self.last_stats = {
            "requests": len(done), "tokens": toks, "steps": steps,
            "seconds": round(dt, 4),
            "req_per_s": round(len(done) / dt, 3),
            "tok_per_s": round(toks / dt, 3),
        }
        return done

    # -- internals -----------------------------------------------------
    def _admit(self) -> None:
        if self.paged:
            return self._admit_paged()
        batch = []
        while self._queue and self.arena.num_free:
            batch.append((self.arena.acquire(), self._queue.popleft()))
        if not batch:
            return
        n = len(batch)
        nb = _bucket(n, 1, self.arena.num_slots)
        longest = max(r.prompt.size for _, r in batch)
        lb = _bucket(max(longest, self.min_prompt_bucket),
                     self.min_prompt_bucket, self.arena.max_len)
        tokens = np.full((nb, lb), self.pad_id, np.int32)
        lengths = np.ones((nb,), np.int32)
        seeds = np.zeros((nb,), np.int32)
        temp = np.zeros((nb,), np.float32)
        top_k = np.zeros((nb,), np.int32)
        top_p = np.ones((nb,), np.float32)
        # sentinel slot id num_slots -> padded rows dropped by the scatter
        slot_ids = np.full((nb,), self.arena.num_slots, np.int32)
        for i, (slot, req) in enumerate(batch):
            sp = req.sampling
            tokens[i, :req.prompt.size] = req.prompt
            lengths[i] = req.prompt.size
            seeds[i], temp[i] = sp.seed, sp.temperature
            top_k[i], top_p[i] = sp.top_k, sp.top_p
            slot_ids[i] = slot
        keys = np.asarray(smp.make_keys(seeds))
        with self._ctx():
            tok0, pcache = self._prefill_for(nb)(
                self.params, tokens, lengths, keys, temp, top_k, top_p)
        self.arena.write(pcache, slot_ids)
        tok0 = np.array(tok0)
        for i, (slot, req) in enumerate(batch):
            self._base_keys[slot] = keys[i]
            self._temp[slot], self._top_k[slot] = temp[i], top_k[i]
            self._top_p[slot] = top_p[i]
            self._slots[slot] = req
            self._active[slot] = True
            self._tok[slot, 0] = tok0[i, 0]
            self._emit(slot, int(tok0[i, 0]))

    def _admit_paged(self) -> None:
        """Paged admission: longest-prefix-match each prompt against the
        radix tree, build the slot's block table (share / copy-on-write /
        fresh — ``PagedLatentArena.admit``), then prefill ONLY the
        uncached suffixes as one bucketed ragged batch. A prompt the pool
        cannot hold even after eviction goes back to the queue head."""
        batch = []  # (slot, req, cached-prefix length)
        while self._queue and self.arena.num_free:
            req = self._queue.popleft()
            slot = self.arena.acquire()
            base = self.arena.admit(slot, req.prompt)
            if base is None:
                self.arena.release(slot)
                self._queue.appendleft(req)
                break
            batch.append((slot, req, base))
        if not batch:
            return
        n = len(batch)
        nb = _bucket(n, 1, self.arena.num_slots)
        longest = max(r.prompt.size - base for _, r, base in batch)
        lb = _bucket(max(longest, self.min_prompt_bucket),
                     self.min_prompt_bucket, self.arena.max_len)
        tokens = np.full((nb, lb), self.pad_id, np.int32)
        lengths = np.ones((nb,), np.int32)
        bases = np.zeros((nb,), np.int32)
        seeds = np.zeros((nb,), np.int32)
        temp = np.zeros((nb,), np.float32)
        top_k = np.zeros((nb,), np.int32)
        top_p = np.ones((nb,), np.float32)
        # padded rows keep all-sentinel tables: their scatters drop
        tables = np.full((nb, self.arena.layout.blocks_per_slot),
                         self.arena.num_blocks, np.int32)
        for i, (slot, req, base) in enumerate(batch):
            sp = req.sampling
            suffix = req.prompt[base:]
            tokens[i, :suffix.size] = suffix
            lengths[i] = suffix.size
            bases[i] = base
            tables[i] = self.arena.tables[slot]
            seeds[i], temp[i] = sp.seed, sp.temperature
            top_k[i], top_p[i] = sp.top_k, sp.top_p
        keys = np.asarray(smp.make_keys(seeds))
        with self._ctx():
            tok0, pool = self._prefill_fns[0](
                self.params, self.arena.pool_cache, tables, tokens,
                lengths, bases, keys, temp, top_k, top_p)
        self.arena.pool_cache = pool
        tok0 = np.array(tok0)
        for i, (slot, req, base) in enumerate(batch):
            L = int(req.prompt.size)
            self.arena.insert(slot, req.prompt)  # publish to the tree
            self._pos[slot] = L
            self._base_keys[slot] = keys[i]
            self._temp[slot], self._top_k[slot] = temp[i], top_k[i]
            self._top_p[slot] = top_p[i]
            self._slots[slot] = req
            self._active[slot] = True
            self._tok[slot, 0] = tok0[i, 0]
            self._admitted += 1
            self._hits += base > 0
            self._hit_tokens += base
            self._prompt_tokens += L
            self._prefill_computed += L - base
            self._emit(slot, int(tok0[i, 0]))

    def _emit(self, slot: int, tok: int) -> None:
        req = self._slots[slot]
        sp = req.sampling
        if tok in sp.stop_tokens:
            return self._finish(slot, "stop")
        req.output_tokens.append(tok)
        if req.on_token is not None:
            req.on_token(req, tok)
        if sp.eos_id is not None and tok == sp.eos_id:
            return self._finish(slot, "eos")
        if req.num_generated >= sp.max_new_tokens:
            return self._finish(slot, "length")
        self._gen_count[slot] = req.num_generated  # fold index of next token

    def _finish(self, slot: int, reason: str) -> None:
        req = self._slots[slot]
        req.finished, req.finish_reason = True, reason
        self.finished.append(req)
        self._slots[slot] = None
        self._active[slot] = False
        self.arena.release(slot)

    # -- accounting ----------------------------------------------------
    def cache_report(self) -> Dict[str, float]:
        """Per-slot cache bytes, latent vs the dense equivalent.

        Both sides must share one base or the ratio lies: the live
        arena tree per slot vs an arena-SHAPED dense cache at the SAME
        num_slots per slot (per-slot ``pos`` vector included on both
        sides) — a dense config reports ratio exactly 1.0. Ring layers
        are honest too: the dense side inherits the same windows via
        ``group_spec``, so a windowed layer's latent ring slots are
        compared against a dense ring of the WINDOW length, never a
        ``max_len``-long dense cache it would not need (tested)."""
        latent = self.arena.slot_bytes()
        dense_cfg = dataclasses.replace(
            self.cfg, latent=LatentConfig(enabled=False))
        dense = arena_cache_bytes(
            dense_cfg, self.arena.num_slots, self.arena.max_len) \
            // self.arena.num_slots
        report = {"slot_bytes": latent, "dense_slot_bytes": dense,
                  "ratio": round(latent / dense, 4)}
        if self.paged:
            report.update({
                "prefix_hit_rate": round(
                    self._hit_tokens / max(self._prompt_tokens, 1), 4),
                "prefix_hit_requests": self._hits,
                "requests_admitted": self._admitted,
                "blocks_in_use": self.arena.blocks_in_use,
                "num_blocks": self.arena.num_blocks,
                "prefill_tokens_saved": self._hit_tokens,
                "prefill_tokens_computed": self._prefill_computed,
            })
        return report
