"""Continuous-batching serving engine over the slot-based latent arena.

The redesign ISSUE 3 asks for: requests with per-request sampling params
enter a queue; the engine admits them into free ``LatentCacheArena``
slots with a bucketed ragged prefill, then runs ONE fused decode
dispatch per step across ALL active slots — ragged per-slot positions,
per-slot sampling params and PRNG streams, per-slot finish detection,
streamed token callbacks, and slot recycling. Jit shapes are bucketed
(admission batch and prompt length round up to powers of two; the
decode shape is pinned to ``num_slots``), so mixed traffic never
recompiles per request.

Scope: token-mode attention models, INCLUDING sliding-window families
(gemma2 / danube): windowed layers run over a ring ``CacheLayout`` —
per-slot writes wrap mod ``cache_len``, admission fills each row's own
trailing window (padding can never clobber a shorter row's ring), and the
absorbed decode dispatches the (start, length) ring Pallas kernels, so
windowed configs keep the fast path. Recurrent families (ssm/hybrid) are
still rejected — a right-padded prefill would pollute their recurrent
state.

Paged mode (``paged=True``): the arena becomes a block-table
``PagedLatentArena`` over a shared ref-counted pool, admission
longest-prefix-matches each prompt against a radix tree of previously
served prompts and prefills ONLY the uncached suffix, and decode runs
the same single fused dispatch through a jitted block gather/scatter
(``lm.make_paged_engine_step``). Greedy tokens are bit-identical to the
linear arena; ``cache_report()`` gains prefix-hit and pool-occupancy
fields. Absorbed (NoPE) latent models only — see ``_validate_paged``.

Request lifecycle (ISSUE 7): every request moves through explicit
``RequestState``s and always reaches a terminal state exactly once —
nothing raises out of ``step()`` mid-traffic.

  * **Admission control**: ``submit()`` applies a reject-with-reason
    policy (oversized prompt, out-of-vocab token ids, bounded queue,
    draining engine) — rejected requests come back ``REJECTED`` with
    ``finish_reason='rejected'`` and the reason in ``.error``;
    ``strict=True`` restores the old submit-time ``ValueError``.
  * **Preemption under cache pressure**: when the paged pool cannot
    satisfy a mid-decode ``try_ensure`` (or a strictly-higher-priority
    request waits while the pool is full), the engine preempts a victim
    — lowest priority first, youngest first within a priority —
    publishes its prompt+generated prefix into the radix tree, releases
    its blocks, and requeues it. Re-admission longest-prefix-matches
    that published chain and recomputes only the tail; resumed rows
    restore their sampled token / PRNG fold on the host, so a
    preempted-and-resumed request's tokens are bit-identical to an
    uninterrupted run (prefill-recomputed latent rows are bitwise equal
    to decode-written rows — verified by tests/test_faults.py).
  * **Deadlines**: per-request ``ttft_deadline_s`` / ``deadline_s``,
    enforced host-side each step (queued AND running) →
    ``finish_reason='timeout'``.
  * **Cancellation**: ``cancel(req)`` at any non-terminal point.
  * **Transient step failures**: the fused dispatch is retried with
    exponential backoff up to ``max_step_retries`` times; exhaustion
    fails the resident requests (``ERROR``) instead of raising.
  * **Non-finite quarantine**: the step heads return a per-row finite
    flag; a row whose logits went NaN/Inf is quarantined — that one
    request fails (``ERROR``), its cache position does not advance, its
    paged scatter is dropped — and every other slot keeps decoding.
  * **Drain**: ``begin_drain()`` stops admission; ``drain(timeout_s)``
    steps until residents finish, cancelling what remains on timeout.
  * **Fault injection**: pass ``faults=FaultInjector(...)`` (see
    ``serve/faults.py``) to drive all of the above deterministically —
    scheduled dispatch failures, forced pool exhaustion, NaN logits,
    and clock skew. The default (None) costs nothing.
  * **Observability**: pass ``metrics=MetricsRegistry()`` (see
    ``serve/metrics.py``) and the engine observes TTFT at first-token
    emission plus terminal-state counters and e2e/ms-per-token
    histograms at every terminal transition — the registry the HTTP
    front-end (``serve/server.py``) serves at ``GET /metrics``. Every
    engine time read goes through ONE injected clock, so injected skew
    moves these latencies exactly like the deadline sweeps.

Sharded serving: pass ``mesh=jax.sharding.Mesh(...)`` and the whole hot
path runs tensor/data-parallel — parameters placed by the training
``param_specs`` rules, the arena by ``serve_cache_specs`` (slots on the
data axes, heads on 'model', latent rank dims local), per-slot state
rows replicated, and the prefill/decode/scatter heads jitted with
NamedSharding in/out. Decode stays ONE fused dispatch per step; the
absorbed MLA Pallas kernels run per-shard when the head axis divides
the 'model' axis and fall back to the ref einsum path otherwise.
"""
from __future__ import annotations

import collections
import contextlib
import dataclasses
import time
from typing import Dict, Iterable, List, Optional, Sequence, Union

import jax
import numpy as np

from repro.configs.base import LatentConfig, ModelConfig
from repro.models import lm
from repro.models import sampling as smp
from repro.models import transformer as T
from repro.serve.arena import (LatentCacheArena, arena_cache_bytes,
                               arena_cache_shape)
from repro.serve.faults import FaultInjector, TransientStepFault
from repro.serve.metrics import MetricsRegistry
from repro.serve.paged import PagedLatentArena
from repro.serve.request import Request, RequestState
from repro.serve.sampling import SamplingParams


def _bucket(n: int, lo: int, hi: int) -> int:
    b = lo
    while b < n:
        b <<= 1
    return min(b, hi)


def _validate(cfg: ModelConfig) -> None:
    if cfg.input_mode != "tokens":
        raise ValueError("Engine serves token-mode models only")
    if cfg.family in ("ssm", "hybrid"):
        raise ValueError(
            "Engine does not serve recurrent (ssm/hybrid) families: "
            "right-padded ragged prefill would pollute the SSM state")
    # sliding-window configs are served: their layers carry a ring
    # CacheLayout (see serve/arena.py) and the decode kernels take the
    # (start, length) ring descriptor instead of a valid_len prefix


def _validate_paged(cfg: ModelConfig) -> None:
    """Paged serving shares position-aligned latent blocks across
    requests, which is only sound for absorbed (NoPE) latent attention:
    no RoPE phase baked into c_k, no qkv bias path, and no sliding
    windows (a ring wraps per slot — checked by the arena)."""
    if not (cfg.latent and cfg.latent.enabled):
        raise ValueError("paged serving needs latent attention "
                         "(cfg.latent.enabled)")
    if cfg.pos_emb == "rope" or cfg.qkv_bias:
        raise ValueError(
            "paged serving needs the absorbed decode path (pos_emb != "
            "'rope', no qkv bias): latent blocks are shared by token "
            "prefix, which RoPE-phased caches would break")


class Engine:
    """Continuous batching: submit() requests, step() until drained.

    One ``step()`` = (a) advance the fault schedule and enforce
    deadlines, (b) admit queued requests into free slots via a bucketed
    ragged prefill + arena scatter (preempting under cache pressure
    instead of stalling priority traffic), then (c) a single fused
    decode dispatch over the whole arena with bounded retries and a
    per-row non-finite quarantine. Finished slots (eos / stop token /
    length cap / timeout / error) are released immediately and refilled
    on the next step. ``run()`` drains everything and reports
    throughput; ``lifecycle_report()`` exposes the fault counters."""

    def __init__(self, cfg: ModelConfig, params, *, num_slots: int = 4,
                 max_len: int = 128, pad_id: int = 0,
                 min_prompt_bucket: int = 8, mesh=None, paged: bool = False,
                 block_size: int = 16, num_blocks: Optional[int] = None,
                 strict: bool = False, max_queue: Optional[int] = None,
                 faults: Optional[FaultInjector] = None,
                 max_step_retries: int = 3, retry_backoff_s: float = 0.005,
                 admission_patience: int = 512,
                 metrics: Optional[MetricsRegistry] = None,
                 token_budget: Optional[int] = None,
                 prefill_chunk: Optional[int] = None,
                 slo_drift_factor: float = 2.0,
                 cache_dtype: str = "fp"):
        _validate(cfg)
        # int8 latent cache (ISSUE 10): quantize-on-write rows + fp32
        # per-row scales, dequantized inside the absorbed kernels. Only
        # the absorbed path reads int8 latents directly, so the knob is
        # ctor-validated the same way chunked prefill is below.
        if cache_dtype not in ("fp", "int8"):
            raise ValueError(
                f"cache_dtype must be 'fp' or 'int8', got {cache_dtype!r}")
        if cache_dtype == "int8":
            if not (cfg.latent and cfg.latent.enabled
                    and cfg.pos_emb != "rope" and not cfg.qkv_bias):
                raise ValueError(
                    "int8 latent cache (cache_dtype='int8') requires an "
                    "absorbed latent config (latent.enabled, pos_emb != "
                    "'rope', no qkv bias): decode dequantizes int8 latents "
                    "inside the absorbed kernels")
            cfg = dataclasses.replace(
                cfg, latent=dataclasses.replace(cfg.latent,
                                                cache_dtype="int8"))
        self.cache_dtype = cache_dtype
        self.cfg, self.pad_id = cfg, pad_id
        self.min_prompt_bucket = min_prompt_bucket
        self.mesh = mesh
        self.paged = paged
        self.strict = strict
        self.max_queue = max_queue
        self.faults = faults
        self.metrics = metrics
        self.max_step_retries = max_step_retries
        self.retry_backoff_s = retry_backoff_s
        self.admission_patience = admission_patience
        # unified token-budget scheduler (ISSUE 9): setting either knob
        # turns on chunked prefill — each step spends ``token_budget``
        # first on resident decode rows (1 token each), then on bounded
        # ``prefill_chunk``-sized chunks of pending prefills, so a long
        # prompt prefills incrementally instead of monopolizing a
        # dispatch. Carry-in chunks need the absorbed latent path.
        if token_budget is not None and token_budget < 1:
            raise ValueError(f"token_budget must be >= 1, got {token_budget}")
        if prefill_chunk is not None and prefill_chunk < 1:
            raise ValueError(
                f"prefill_chunk must be >= 1, got {prefill_chunk}")
        self.token_budget = token_budget
        self.prefill_chunk = prefill_chunk
        self.slo_drift_factor = slo_drift_factor
        self._chunked = token_budget is not None or prefill_chunk is not None
        if self._chunked and not (cfg.latent and cfg.latent.enabled
                                  and cfg.pos_emb != "rope"
                                  and not cfg.qkv_bias):
            raise ValueError(
                "chunked prefill (token_budget/prefill_chunk) requires an "
                "absorbed latent config (latent.enabled, pos_emb != 'rope', "
                "no qkv bias): a chunk resumes mid-prompt through the "
                "carry-in latent prefill path")
        # EVERY engine time read routes through this one injected clock
        # (timestamps, deadline sweeps, AND throughput stats), so
        # FaultInjector clock skew exercises TTFT/latency accounting too
        self._now = faults.now if faults is not None else time.monotonic
        self._sleep = faults.sleep if faults is not None else time.sleep
        if paged:
            _validate_paged(cfg)
            self.arena = PagedLatentArena(cfg, num_slots, max_len,
                                          block_size=block_size,
                                          num_blocks=num_blocks, mesh=mesh)
            step = lm.make_paged_engine_step(cfg, self.arena.layout, pad_id)
            step_greedy = lm.make_paged_engine_step(
                cfg, self.arena.layout, pad_id, greedy=True)
            self._prefill_raw = lm.make_paged_engine_prefill(
                cfg, self.arena.layout)
        else:
            self.arena = LatentCacheArena(cfg, num_slots, max_len, mesh=mesh)
            step = lm.make_engine_step(cfg, pad_id)
            step_greedy = lm.make_engine_step(cfg, pad_id, greedy=True)
            self._prefill_raw = lm.make_engine_prefill(cfg, max_len)
            if self._chunked:
                self._chunk_raw = lm.make_engine_prefill(cfg, max_len,
                                                         carry=True)
        # static byte baselines for cache_report()/gauges: the dense
        # (uncompressed) and fp-latent equivalents of this arena, both
        # computed once — shapes never change after construction
        dense_cfg = dataclasses.replace(
            self.cfg, latent=LatentConfig(enabled=False))
        self._dense_slot_bytes = arena_cache_bytes(
            dense_cfg, num_slots, max_len) // num_slots
        fp_cfg = dataclasses.replace(
            self.cfg, latent=dataclasses.replace(self.cfg.latent,
                                                 cache_dtype="fp"))
        self._fp_slot_bytes = arena_cache_bytes(
            fp_cfg, num_slots, max_len) // num_slots
        donate = (1,) if jax.default_backend() != "cpu" else ()
        # The carry-in chunk head always donates its cache arg: the
        # arena cache is a jit output (never a zero-copied host numpy
        # buffer, unlike the snapshotted _pos/table arrays), and every
        # reader rebinds arena.cache right after the call — so in-place
        # reuse is safe even on CPU, where it saves a full arena copy
        # per chunk step.
        chunk_donate = (1,)
        self._prefill_fns: Dict[int, callable] = {}
        if mesh is not None:
            # Tensor/data-parallel serving: parameters placed with the
            # training param rules, the arena with serve_cache_specs,
            # and every per-slot state row replicated. The step heads
            # are jitted with NamedSharding in/out so nothing reshards
            # between steps and decode stays ONE fused dispatch.
            from jax.sharding import NamedSharding, PartitionSpec as P
            from repro.distributed import sharding as shd
            pspecs = shd.param_specs(jax.eval_shape(lambda: params), mesh)
            self._pshard = shd.to_named(mesh, pspecs)
            params = jax.device_put(params, self._pshard)
            rep = NamedSharding(mesh, P())
            self._rep = rep
            state = shd.engine_state_specs(mesh)
            srow = tuple(NamedSharding(mesh, state[k]) for k in
                         ("tok", "base_keys", "gen_count", "temperature",
                          "top_k", "top_p", "active"))
            if paged:
                # pool shards like the arena; tables / positions are
                # replicated indirection; pool shape never varies with
                # the admission bucket, so ONE prefill head serves all
                idx = tuple(NamedSharding(mesh, state[k]) for k in
                            ("block_tables", "pos"))
                step_in = (self._pshard, self.arena.shardings) + idx \
                    + srow + (rep,)
                self._prefill_fns[0] = jax.jit(
                    self._prefill_raw, donate_argnums=donate,
                    in_shardings=(self._pshard, self.arena.shardings)
                    + (rep,) * 8,
                    out_shardings=(rep, self.arena.shardings))
            else:
                step_in = (self._pshard, self.arena.shardings) + srow + (rep,)
                if self._chunked:
                    # ONE jitted carry-in head serves every chunk batch:
                    # it reads/writes the arena in place, so its
                    # shardings never vary with the admission bucket
                    # (unlike the per-bucket legacy heads)
                    self._chunk_fn = jax.jit(
                        self._chunk_raw, donate_argnums=chunk_donate,
                        in_shardings=(self._pshard, self.arena.shardings)
                        + (rep,) * 8,
                        out_shardings=(rep, self.arena.shardings))
            self._step_fn = jax.jit(
                step, donate_argnums=donate, in_shardings=step_in,
                out_shardings=(rep, rep, self.arena.shardings))
            self._step_greedy = jax.jit(
                step_greedy, donate_argnums=donate, in_shardings=step_in,
                out_shardings=(rep, rep, self.arena.shardings))
        else:
            self._pshard = None
            self._step_fn = jax.jit(step, donate_argnums=donate)
            self._step_greedy = jax.jit(step_greedy, donate_argnums=donate)
            self._prefill_fns[0] = jax.jit(
                self._prefill_raw, donate_argnums=donate if paged else ())
            if self._chunked and not paged:
                self._chunk_fn = jax.jit(self._chunk_raw,
                                         donate_argnums=chunk_donate)
        self.params = params
        B = num_slots
        self._pos = np.zeros((B,), np.int32)  # paged: per-slot decode pos
        self._hits = 0                 # admissions with a nonzero match
        self._admitted = 0
        self._hit_tokens = 0           # prompt tokens served from cache
        self._prompt_tokens = 0
        self._prefill_computed = 0     # prompt tokens actually prefilled
        self._tok = np.zeros((B, 1), np.int32)
        self._base_keys = np.zeros((B, 2), np.uint32)
        self._gen_count = np.zeros((B,), np.int32)
        self._temp = np.zeros((B,), np.float32)
        self._top_k = np.zeros((B,), np.int32)
        self._top_p = np.ones((B,), np.float32)
        self._active = np.zeros((B,), bool)
        self._no_poison = np.zeros((B,), bool)
        self._slots: List[Optional[Request]] = [None] * B
        self._queue: collections.deque = collections.deque()
        self._next_id = 0
        self._draining = False
        self._starved_steps = 0
        # chunked-scheduler state: slot -> in-flight prefill bookkeeping
        # (admission tokens, cached base, chunk progress, PRNG key row)
        self._prefilling: Dict[int, dict] = {}
        self._prefill_share = 1.0     # SLO backoff: fraction of budget
        self._decode_ema: Optional[float] = None  # s/token, chunk-free steps
        self.finished: List[Request] = []
        self.rejected: List[Request] = []
        self.counters: collections.Counter = collections.Counter()
        self.last_stats: Dict[str, float] = {}

    # -- intake --------------------------------------------------------
    def submit(self, prompt: Union[Request, Sequence[int], np.ndarray],
               sampling: Optional[SamplingParams] = None,
               on_token=None, *, priority: int = 0,
               ttft_deadline_s: Optional[float] = None,
               deadline_s: Optional[float] = None) -> Request:
        """Queue one request. Admission policy violations (see
        ``_admission_error``) return a terminal ``REJECTED`` request
        with the reason in ``.error`` — or raise ``ValueError`` when
        the engine was built with ``strict=True``."""
        if isinstance(prompt, Request):
            if sampling is not None or on_token is not None or priority \
                    or ttft_deadline_s is not None or deadline_s is not None:
                raise ValueError(
                    "pass sampling/on_token/priority/deadlines inside the "
                    "Request, not alongside it")
            req = prompt
        else:
            req = Request(np.asarray(prompt), sampling or SamplingParams(),
                          on_token=on_token, priority=priority,
                          ttft_deadline_s=ttft_deadline_s,
                          deadline_s=deadline_s)
        req.request_id = self._next_id
        self._next_id += 1
        req.submit_time = self._now()
        reason = self._admission_error(req)
        if reason is not None:
            if self.strict:
                raise ValueError(reason)
            self.counters["rejections"] += 1
            self._terminalize(req, RequestState.REJECTED, "rejected",
                              error=reason)
            return req
        req.enqueue_time = req.submit_time
        self._queue.append(req)
        return req

    def _admission_error(self, req: Request) -> Optional[str]:
        if self._draining:
            return "engine is draining: not accepting new requests"
        sp = req.sampling
        # defense in depth: SamplingParams validates at construction, but
        # a Request can arrive carrying params built around it — catch
        # degenerate values HERE with a REJECTED reason (HTTP 400 at the
        # server) instead of failing mid-step for the whole batch
        if sp.max_new_tokens <= 0:
            return (f"max_new_tokens must be >= 1, got "
                    f"{sp.max_new_tokens}")
        if not 0.0 < sp.top_p <= 1.0:
            return f"top_p must lie in (0, 1], got {sp.top_p}"
        vocab = self.cfg.vocab_size
        lo, hi = int(req.prompt.min()), int(req.prompt.max())
        if lo < 0 or hi >= vocab:
            return (f"prompt token ids must lie in [0, {vocab}), got "
                    f"range [{lo}, {hi}]")
        need = req.prompt.size + req.sampling.max_new_tokens
        if need > self.arena.max_len:
            return (f"prompt({req.prompt.size}) + max_new_tokens"
                    f"({req.sampling.max_new_tokens}) exceeds arena max_len "
                    f"{self.arena.max_len}")
        if self.max_queue is not None and len(self._queue) >= self.max_queue:
            return (f"admission queue full ({self.max_queue} waiting): "
                    f"retry later")
        return None

    def has_work(self) -> bool:
        return bool(self._queue) or bool(self._active.any()) \
            or bool(self._prefilling)

    # -- lifecycle control ---------------------------------------------
    def cancel(self, req: Request) -> bool:
        """Cancel a request at any non-terminal point: drop it from the
        queue, or release its slot mid-decode. Returns False if it had
        already reached a terminal state."""
        if req.is_terminal:
            return False
        slot = self._slot_of(req)
        if slot is not None:
            self._release_slot(slot)
        else:
            self._queue_discard(req)
        self.counters["cancellations"] += 1
        self._terminalize(req, RequestState.CANCELLED, "cancelled")
        return True

    def preempt(self, req: Request) -> bool:
        """Explicitly pause a RUNNING request: its prefix is published
        (paged) / its slot released, and it requeues to resume
        bit-identically. Returns False unless the request was resident."""
        slot = self._slot_of(req)
        if slot is None:
            return False
        self._preempt(slot)
        return True

    def begin_drain(self, cancel_queued: bool = False) -> None:
        """Stop admitting new submissions; residents keep decoding.
        ``cancel_queued=True`` also cancels everything still waiting."""
        self._draining = True
        if cancel_queued:
            for req in list(self._queue):
                self.cancel(req)

    def abort(self) -> None:
        """Hard stop: close admission and cancel every queued AND
        resident request (the server's second-SIGINT path). Admission
        stays closed — reopen by clearing the drain with ``drain()``."""
        self.begin_drain(cancel_queued=True)
        for req in list(self._slots):  # active AND mid-prefill residents
            if req is not None:
                self.cancel(req)

    def drain(self, timeout_s: Optional[float] = None) -> bool:
        """Step until all queued + resident work completes. On timeout
        the leftovers are cancelled. Returns True on a clean drain;
        admission reopens either way."""
        self.begin_drain()
        deadline = None if timeout_s is None else self._now() + timeout_s
        clean = True
        try:
            while self.has_work():
                if deadline is not None and self._now() >= deadline:
                    for req in list(self._queue):
                        self.cancel(req)
                    for req in list(self._slots):
                        if req is not None:
                            self.cancel(req)
                    clean = False
                    break
                self.step()
        finally:
            self._draining = False
        return clean

    def _slot_of(self, req: Request) -> Optional[int]:
        for s, r in enumerate(self._slots):
            if r is req:
                return s
        return None

    def _queue_discard(self, req: Request) -> None:
        for i, q in enumerate(self._queue):
            if q is req:
                del self._queue[i]
                return

    def _terminalize(self, req: Request, state: RequestState, reason: str,
                     error: Optional[str] = None) -> None:
        """The ONLY way a request becomes terminal — asserts
        exactly-once, stamps the finish time, and files the request."""
        if req.is_terminal:
            raise RuntimeError(
                f"request {req.request_id} is already terminal "
                f"({req.state.value}): double-finish bug")
        req.state = state
        req.finished = True
        req.finish_reason = reason
        if error is not None:
            req.error = error
        req.finish_time = self._now()
        (self.rejected if state is RequestState.REJECTED
         else self.finished).append(req)
        if self.metrics is not None:
            self.metrics.on_terminal(req)

    # -- the serving loop ----------------------------------------------
    def _ctx(self):
        """Mesh context for tracing: the constrain_* activation hints
        and the per-shard kernel gating read the active mesh at trace
        time, so every jitted head is traced inside ``with mesh:``."""
        return self.mesh if self.mesh is not None else contextlib.nullcontext()

    def _prefill_for(self, nb: int):
        """Jitted prefill head for an admission bucket of ``nb`` rows.

        Without a mesh one jit serves every bucket (shapes re-specialize
        inside it). Under a mesh each bucket needs its own out-shardings
        — the prefill cache batch dim is ``nb``, and whether it divides
        the data axes decides its spec — so heads are cached per bucket
        (a handful: admit buckets are powers of two up to num_slots)."""
        key = nb if self.mesh is not None else 0
        fn = self._prefill_fns.get(key)
        if fn is None:
            from repro.distributed import sharding as shd
            cshape = arena_cache_shape(self.cfg, nb, self.arena.max_len)
            cshard = shd.to_named(
                self.mesh,
                shd.serve_cache_specs(self.mesh, cshape,
                                      layouts=self.arena.layouts))
            fn = jax.jit(self._prefill_raw,
                         in_shardings=(self._pshard,) + (self._rep,) * 6,
                         out_shardings=(self._rep, cshard))
            self._prefill_fns[key] = fn
        return fn

    def step(self) -> bool:
        """One engine step: fault schedule + deadlines + admission +
        one fused decode dispatch (with retries and per-row
        quarantine). Never raises on cache pressure, injected faults,
        poisoned rows, or callback errors — the affected requests reach
        terminal states instead. Returns whether the engine still has
        queued or resident work.

        Chunked mode (``token_budget``/``prefill_chunk`` set) assembles
        each step from one token budget: resident decode rows spend 1
        token each first, the remainder buys bounded chunks of pending
        prefills (one bucketed carry-in dispatch), and the prefill share
        backs off when resident ms/token drifts past
        ``slo_drift_factor``x the chunk-free baseline. Decode is STILL
        one fused dispatch per step."""
        t0 = self._now()
        chunks0 = self.counters["prefill_chunks"]
        if self.faults is not None:
            self.faults.begin_step(self.arena.pool if self.paged else None)
        self._enforce_deadlines()
        if self._chunked:
            self._admit_chunked()
        else:
            self._admit()
        self._check_starvation()
        self._publish_gauges()
        decode_rows = int(self._active.sum())
        if self._active.any():
            if self.paged:
                # host bookkeeping first: the block each active row
                # writes this step must exist before the fused dispatch
                # — under pool pressure this preempts victims instead
                # of raising, and may deactivate rows (incl. self)
                self._ensure_blocks()
            if not self._active.any():
                return self.has_work()
            # all-greedy batches take the argmax-only step (no vocab
            # sort / gumbel in the jaxpr); tokens are bit-identical
            fn = (self._step_greedy
                  if not (self._temp[self._active] > 0).any()
                  else self._step_fn)
            act = np.nonzero(self._active)[0]
            poison = (self.faults.poison_mask(self._active.size, self._active)
                      if self.faults is not None else self._no_poison)
            out = self._dispatch(fn, poison)
            if out is None:
                return self.has_work()  # retries exhausted: residents failed
            toks, fin = out
            self._tok = toks  # writable copy: admission patches rows
            for s in act:
                s = int(s)
                if not fin[s]:
                    self.counters["quarantined"] += 1
                    self._fail_slot(s, "non-finite logits: slot quarantined")
                else:
                    self._emit(s, int(toks[s, 0]))
        if self._chunked:
            self._update_prefill_share(
                self._now() - t0, decode_rows,
                self.counters["prefill_chunks"] - chunks0)
        return self.has_work()

    def _publish_gauges(self) -> None:
        """Scheduler observability: queued + in-flight prefill backlog
        and decode batch occupancy, refreshed every step."""
        if self.metrics is None:
            return
        backlog = sum(q.prompt.size + q.num_generated for q in self._queue)
        backlog += sum(e["toks"].size - e["base"] - e["done"]
                       for e in self._prefilling.values())
        slot_bytes = self.arena.slot_bytes()
        self.metrics.set_gauges({
            "prefill_backlog_tokens": float(backlog),
            "decode_batch_occupancy":
                float(self._active.sum()) / self.arena.num_slots,
            # cache observability (ISSUE 10): live arena footprint and
            # how much smaller it is than the dense-equivalent cache
            # (int8 caches push this past the latent-rank win alone)
            "cache_bytes_in_use":
                float(slot_bytes * self.arena.num_slots),
            "cache_compression_ratio":
                float(self._dense_slot_bytes) / max(slot_bytes, 1),
        })

    def _update_prefill_share(self, dt: float, decode_rows: int,
                              chunks_issued: int) -> None:
        """SLO-aware batch shaping, the feedback half: chunk-free steps
        set an EMA baseline of resident seconds/token; when a
        chunk-carrying step exceeds ``slo_drift_factor``x that baseline,
        the prefill share halves (floor 1/8) — long-prompt chunks yield
        to resident decode SLOs — and recovers by 1.25x per clean
        step."""
        if decode_rows <= 0:
            return
        per_tok = dt / decode_rows
        if chunks_issued == 0:
            self._decode_ema = per_tok if self._decode_ema is None \
                else 0.9 * self._decode_ema + 0.1 * per_tok
            self._prefill_share = min(1.0, self._prefill_share * 1.25)
        elif self._decode_ema is not None:
            if per_tok > self.slo_drift_factor * self._decode_ema:
                self._prefill_share = max(0.125, self._prefill_share * 0.5)
                self.counters["slo_backoffs"] += 1
            else:
                self._prefill_share = min(1.0, self._prefill_share * 1.25)

    def _dispatch(self, fn, poison):
        """The fused decode dispatch with bounded retries. Injected /
        transient failures fire BEFORE the jitted call (no device state
        has moved), so a retry re-runs the identical step. Returns
        (tokens (B,1) writable, finite (B,) bool) or None when retries
        were exhausted (residents are failed, queue left intact)."""
        attempt = 0
        while True:
            try:
                if self.faults is not None:
                    self.faults.maybe_fail_dispatch()
                if self.paged:
                    # jax's CPU runtime zero-copies aligned numpy inputs
                    # into the ASYNC dispatch: any array mutated in place
                    # while the step is in flight (pos below, tables via
                    # release/ensure) is read torn by the compute —
                    # snapshot them at the call
                    with self._ctx():
                        tok, finite, pool = fn(
                            self.params, self.arena.pool_cache,
                            self.arena.tables.copy(), self._pos.copy(),
                            self._tok, self._base_keys,
                            self._gen_count.copy(), self._temp, self._top_k,
                            self._top_p, self._active.copy(), poison)
                    self.arena.pool_cache = pool
                    fin = np.array(finite)
                    adv = self._active & fin
                    self._pos[adv] += 1
                else:
                    with self._ctx():
                        tok, finite, cache = fn(
                            self.params, self.arena.cache, self._tok,
                            self._base_keys, self._gen_count, self._temp,
                            self._top_k, self._top_p, self._active, poison)
                    self.arena.cache = cache
                    fin = np.array(finite)
                return np.array(tok), fin
            except TransientStepFault as e:
                attempt += 1
                self.counters["step_retries"] += 1
                if attempt > self.max_step_retries:
                    self.counters["step_failures"] += 1
                    self._fail_all_active(
                        f"step dispatch failed after {attempt} attempts: {e}")
                    return None
                self._sleep(self.retry_backoff_s * (2 ** (attempt - 1)))

    def run(self, requests: Optional[Iterable] = None) -> List[Request]:
        """Submit ``requests`` (Request objects or raw prompts), drain
        the engine, and return the requests finished by this call in
        completion order. Throughput lands in ``last_stats``."""
        for r in requests or ():
            self.submit(r)
        n0, t0 = len(self.finished), self._now()
        steps = 0
        while self.has_work():
            self.step()
            steps += 1
        done = self.finished[n0:]
        dt = max(self._now() - t0, 1e-9)
        toks = sum(r.num_generated for r in done)
        self.last_stats = {
            "requests": len(done), "tokens": toks, "steps": steps,
            "seconds": round(dt, 4),
            "req_per_s": round(len(done) / dt, 3),
            "tok_per_s": round(toks / dt, 3),
        }
        return done

    # -- internals -----------------------------------------------------
    def _pop_best(self) -> Optional[Request]:
        """Next request to admit: highest priority, oldest within a
        priority (preempted requests keep their original id, so they
        re-admit ahead of younger traffic)."""
        if not self._queue:
            return None
        best = min(range(len(self._queue)),
                   key=lambda i: (-self._queue[i].priority,
                                  self._queue[i].request_id))
        req = self._queue[best]
        del self._queue[best]
        return req

    def _victim_slot(self) -> Optional[int]:
        """Preemption victim among residents: lowest priority first,
        youngest (largest request_id) within a priority."""
        cands = [s for s in range(self._active.size) if self._active[s]]
        if not cands:
            return None
        return min(cands, key=lambda s: (self._slots[s].priority,
                                         -self._slots[s].request_id))

    def _preempt(self, slot: int) -> None:
        """Pause the resident at ``slot``: publish its prompt+generated
        prefix (paged — so re-admission prefix-matches it), release the
        slot, and requeue. Cache rows [0, pos) hold exactly
        prompt + output[:-1]; the final sampled token (``_tok``) is not
        in the cache yet and is restored host-side at resume."""
        req = self._slots[slot]
        entry = self._prefilling.get(slot)
        if self.paged:
            if entry is not None:  # mid-prefill: publish the chunked part
                pos = int(entry["base"] + entry["done"])
                self.arena.insert(slot, entry["toks"][:pos])
            else:
                pos = int(self._pos[slot])
                full = np.concatenate(
                    [req.prompt, req.output()]).astype(np.int32)[:pos]
                self.arena.insert(slot, full)
        self._release_slot(slot)
        req.state = RequestState.PREEMPTED
        req.num_preemptions += 1
        req.prefill_pos = 0  # linear chunks restart; paged prefix-matches
        req.enqueue_time = self._now()
        self.counters["preemptions"] += 1
        self._queue.append(req)

    def _ensure_blocks(self) -> None:
        """Paged pre-dispatch bookkeeping: every active row's write
        block must exist. Pool exhaustion preempts victims (lowest
        priority, then youngest) until the allocation succeeds — the
        needy row preempts ITSELF when it is the best victim — so
        mid-decode pressure never raises out of ``step()``."""
        for s in np.nonzero(self._active)[0]:
            s = int(s)
            while self._active[s] and \
                    not self.arena.try_ensure(s, int(self._pos[s])):
                victim = self._victim_slot()
                if victim is None:
                    break
                self.counters["pressure_preemptions"] += 1
                self._preempt(victim)
                if victim == s:
                    break  # self-preempted: row sits out this dispatch

    def _preempt_for_priority(self) -> None:
        """Admission-time preemption: ONLY a strictly-higher-priority
        waiter may displace a resident (equal priority waits its turn —
        strict inequality is what prevents preemption livelock)."""
        if not self._queue:
            return
        can_admit = self.arena.num_free > 0 and (
            not self.paged or self.arena.pool.num_free > 0
            or self.arena.prefix.num_evictable > 0)
        if can_admit:
            return
        waiting = max(q.priority for q in self._queue)
        victim = self._victim_slot()
        if victim is not None and self._slots[victim].priority < waiting:
            self.counters["priority_preemptions"] += 1
            self._preempt(victim)

    def _check_starvation(self) -> None:
        """Backstop against a permanently exhausted pool (e.g. a fault
        hog that never releases): after ``admission_patience``
        consecutive steps with waiters, zero residents, and zero
        admissions, the best waiter fails with ERROR instead of
        spinning forever."""
        if self._queue and not self._active.any() and not self._prefilling:
            self._starved_steps += 1
            if self._starved_steps > self.admission_patience:
                req = self._pop_best()
                self.counters["starvation_failures"] += 1
                self._terminalize(
                    req, RequestState.ERROR, "error",
                    error="admission starved: cache pool exhausted for "
                          f"{self._starved_steps} consecutive steps")
                self._starved_steps = 0
        else:
            self._starved_steps = 0

    def _enforce_deadlines(self) -> None:
        """Host-side deadline sweep (queued AND running requests):
        ``ttft_deadline_s`` bounds submit -> first token,
        ``deadline_s`` bounds submit -> completion."""
        now = self._now()

        def expired(req: Request) -> bool:
            if req.submit_time is None:
                return False
            age = now - req.submit_time
            if req.deadline_s is not None and age >= req.deadline_s:
                return True
            return (req.ttft_deadline_s is not None
                    and req.num_generated == 0
                    and age >= req.ttft_deadline_s)

        for req in [q for q in self._queue if expired(q)]:
            self._queue_discard(req)
            self.counters["timeouts"] += 1
            self._terminalize(req, RequestState.TIMEOUT, "timeout")
        for s, req in enumerate(self._slots):  # active AND mid-prefill
            if req is not None and expired(req):
                self._release_slot(s)
                self.counters["timeouts"] += 1
                self._terminalize(req, RequestState.TIMEOUT, "timeout")

    def _admission_tokens(self, req: Request) -> np.ndarray:
        """What admission prefills: the prompt, or — resuming a
        preempted request — prompt + output[:-1], i.e. exactly the rows
        its cache held at preemption. Recomputed latent rows are
        bitwise identical to the decode-written originals, and in paged
        mode the published chain prefix-matches so only the tail (at
        least one token — the radix match is capped at len-1) is
        recomputed."""
        if req.num_generated:
            return np.concatenate(
                [req.prompt, req.output()[:-1]]).astype(np.int32)
        return req.prompt

    def _bind_slot(self, slot: int, req: Request, keys_row) -> None:
        """Common post-prefill host state for a newly admitted row."""
        sp = req.sampling
        self._base_keys[slot] = keys_row
        self._temp[slot], self._top_k[slot] = sp.temperature, sp.top_k
        self._top_p[slot] = sp.top_p
        self._slots[slot] = req
        self._active[slot] = True
        req.state = RequestState.RUNNING

    def _resume_or_emit(self, slot: int, req: Request, tok0: int) -> None:
        """First-token handling. Fresh requests emit the prefill-sampled
        token. Resumed requests DISCARD it and restore the host state
        the slot had at preemption — the pending sampled token and the
        PRNG fold index — which is what makes resume bit-identical (for
        greedy rows tok0 equals the restored token anyway; sampled rows
        need the original fold index, not fold 0)."""
        if req.num_generated:
            self._tok[slot, 0] = req.output_tokens[-1]
            self._gen_count[slot] = req.num_generated
            self.counters["resumes"] += 1
        else:
            self._tok[slot, 0] = tok0
            self._emit(slot, tok0)

    def _admit(self) -> None:
        if self.paged:
            return self._admit_paged()
        self._preempt_for_priority()
        batch = []
        while self._queue and self.arena.num_free:
            batch.append((self.arena.acquire(), self._pop_best()))
        if not batch:
            return
        n = len(batch)
        nb = _bucket(n, 1, self.arena.num_slots)
        adm = [self._admission_tokens(r) for _, r in batch]
        longest = max(a.size for a in adm)
        lb = _bucket(max(longest, self.min_prompt_bucket),
                     self.min_prompt_bucket, self.arena.max_len)
        tokens = np.full((nb, lb), self.pad_id, np.int32)
        lengths = np.ones((nb,), np.int32)
        seeds = np.zeros((nb,), np.int32)
        temp = np.zeros((nb,), np.float32)
        top_k = np.zeros((nb,), np.int32)
        top_p = np.ones((nb,), np.float32)
        # sentinel slot id num_slots -> padded rows dropped by the scatter
        slot_ids = np.full((nb,), self.arena.num_slots, np.int32)
        for i, (slot, req) in enumerate(batch):
            sp = req.sampling
            tokens[i, :adm[i].size] = adm[i]
            lengths[i] = adm[i].size
            seeds[i], temp[i] = sp.seed, sp.temperature
            top_k[i], top_p[i] = sp.top_k, sp.top_p
            slot_ids[i] = slot
        keys = np.asarray(smp.make_keys(seeds))
        with self._ctx():
            tok0, pcache = self._prefill_for(nb)(
                self.params, tokens, lengths, keys, temp, top_k, top_p)
        self.arena.write(pcache, slot_ids)
        tok0 = np.array(tok0)
        for i, (slot, req) in enumerate(batch):
            self._bind_slot(slot, req, keys[i])
            self._resume_or_emit(slot, req, int(tok0[i, 0]))

    def _admit_paged(self) -> None:
        """Paged admission: longest-prefix-match each prompt against the
        radix tree, build the slot's block table (share / copy-on-write /
        fresh — ``PagedLatentArena.admit``), then prefill ONLY the
        uncached suffixes as one bucketed ragged batch. A prompt the pool
        cannot hold even after eviction requeues — preempting a resident
        first when (and only when) the waiter outranks it."""
        self._preempt_for_priority()
        batch = []  # (slot, req, admission tokens, cached-prefix length)
        guard = 0
        while self._queue and self.arena.num_free:
            req = self._pop_best()
            toks = self._admission_tokens(req)
            slot = self.arena.acquire()
            base = self.arena.admit(slot, toks)
            if base is None:
                self.arena.release(slot)
                self._queue.append(req)  # stays QUEUED/PREEMPTED
                victim = self._victim_slot()
                if victim is not None and guard < self.arena.num_slots \
                        and self._slots[victim].priority < req.priority:
                    guard += 1
                    self.counters["priority_preemptions"] += 1
                    self._preempt(victim)
                    continue  # freed blocks are evictable: retry
                break
            batch.append((slot, req, toks, base))
        if not batch:
            return
        n = len(batch)
        nb = _bucket(n, 1, self.arena.num_slots)
        longest = max(t.size - base for _, _, t, base in batch)
        lb = _bucket(max(longest, self.min_prompt_bucket),
                     self.min_prompt_bucket, self.arena.max_len)
        tokens = np.full((nb, lb), self.pad_id, np.int32)
        lengths = np.ones((nb,), np.int32)
        bases = np.zeros((nb,), np.int32)
        seeds = np.zeros((nb,), np.int32)
        temp = np.zeros((nb,), np.float32)
        top_k = np.zeros((nb,), np.int32)
        top_p = np.ones((nb,), np.float32)
        # padded rows keep all-sentinel tables: their scatters drop
        tables = np.full((nb, self.arena.layout.blocks_per_slot),
                         self.arena.num_blocks, np.int32)
        for i, (slot, req, toks, base) in enumerate(batch):
            sp = req.sampling
            suffix = toks[base:]
            tokens[i, :suffix.size] = suffix
            lengths[i] = suffix.size
            bases[i] = base
            tables[i] = self.arena.tables[slot]
            seeds[i], temp[i] = sp.seed, sp.temperature
            top_k[i], top_p[i] = sp.top_k, sp.top_p
        keys = np.asarray(smp.make_keys(seeds))
        with self._ctx():
            tok0, pool = self._prefill_fns[0](
                self.params, self.arena.pool_cache, tables, tokens,
                lengths, bases, keys, temp, top_k, top_p)
        self.arena.pool_cache = pool
        tok0 = np.array(tok0)
        for i, (slot, req, toks, base) in enumerate(batch):
            L = int(toks.size)
            self.arena.insert(slot, toks)  # publish to the tree
            self._pos[slot] = L
            self._bind_slot(slot, req, keys[i])
            self._admitted += 1
            self._hits += base > 0
            self._hit_tokens += base
            self._prompt_tokens += L
            self._prefill_computed += L - base
            self._resume_or_emit(slot, req, int(tok0[i, 0]))

    # -- chunked token-budget admission --------------------------------
    def _chunk_budget(self) -> Optional[int]:
        """This step's prefill token budget: ``token_budget`` minus the
        resident decode spend (1 token per active row), scaled by the
        SLO prefill share. None = unlimited (no token_budget set)."""
        if self.token_budget is None:
            return None
        left = self.token_budget - int(self._active.sum())
        return max(0, int(left * self._prefill_share))

    def _chunk_cap(self) -> Optional[int]:
        """Per-row chunk bound, SLO-scaled (never below one token — a
        fully backed-off scheduler still makes progress)."""
        if self.prefill_chunk is None:
            return None
        return max(1, int(self.prefill_chunk * self._prefill_share))

    def _chunk_order(self, slots: List[int]) -> List[int]:
        """Chunk-budget priority: TTFT-at-risk rows first (past half
        their ``ttft_deadline_s``, smallest slack first), then request
        priority, then age — the SLO-aware half of batch shaping."""
        now = self._now()

        def key(slot):
            req = self._prefilling[slot]["req"]
            at_risk, slack = 1, float("inf")
            if req.ttft_deadline_s is not None \
                    and req.submit_time is not None:
                slack = req.ttft_deadline_s - (now - req.submit_time)
                if slack <= 0.5 * req.ttft_deadline_s:
                    at_risk = 0
            return (at_risk, slack, -req.priority, req.request_id)

        ordered = sorted(slots, key=key)
        self.counters["ttft_risk_boosts"] += sum(
            1 for s in ordered if key(s)[0] == 0)
        return ordered

    def _admit_chunked(self) -> None:
        """Token-budget admission: queued requests become mid-prefill
        residents while slots (and, paged, pool blocks) allow, then this
        step's prefill budget buys bounded chunks across ALL mid-prefill
        rows in ONE bucketed carry-in dispatch. A row whose prefill
        completes activates for decode the same step and emits its
        prefill-sampled first token — bit-identical to unchunked, since
        only the FINAL chunk's sample (same PRNG fold 0) is used."""
        self._preempt_for_priority()
        guard = 0
        while self._queue and self.arena.num_free:
            req = self._pop_best()
            toks = self._admission_tokens(req)
            slot = self.arena.acquire()
            base = 0
            if self.paged:
                b = self.arena.admit(slot, toks)
                if b is None:  # pool pressure: same policy as _admit_paged
                    self.arena.release(slot)
                    self._queue.append(req)
                    victim = self._victim_slot()
                    if victim is not None and guard < self.arena.num_slots \
                            and self._slots[victim].priority < req.priority:
                        guard += 1
                        self.counters["priority_preemptions"] += 1
                        self._preempt(victim)
                        continue
                    break
                base = int(b)
            keys_row = np.asarray(smp.make_keys(
                np.asarray([req.sampling.seed], np.int32)))[0]
            self._prefilling[slot] = {"req": req, "toks": toks,
                                      "base": base, "done": 0,
                                      "keys": keys_row}
            self._slots[slot] = req  # resident for deadlines/cancel/abort
            req.state = RequestState.RUNNING
            req.prefill_total = int(toks.size)
            req.prefill_pos = base
            if self.metrics is not None and req.enqueue_time is not None:
                self.metrics.observe("queue_wait_s",
                                     self._now() - req.enqueue_time)
        if not self._prefilling:
            return
        cap = self._chunk_cap()
        left = self._chunk_budget()
        takes: Dict[int, int] = {}
        for slot in self._chunk_order(list(self._prefilling)):
            e = self._prefilling[slot]
            rem = int(e["toks"].size - e["base"] - e["done"])
            take = rem if cap is None else min(rem, cap)
            if left is not None:
                take = min(take, left)
            if take <= 0:
                continue
            if left is not None:
                left -= take
            takes[slot] = take
        if takes:
            self._dispatch_chunks(takes)

    def _dispatch_chunks(self, takes: Dict[int, int]) -> None:
        """One bucketed carry-in prefill dispatch over every row that
        won chunk budget this step (linear: the arena-resident carry
        head; paged: the suffix head at base = cached prefix + chunk
        progress). Completed rows bind, publish (paged), and emit."""
        order = list(takes)
        n = len(order)
        nb = _bucket(n, 1, self.arena.num_slots)
        lb = _bucket(max(max(takes.values()), self.min_prompt_bucket),
                     self.min_prompt_bucket, self.arena.max_len)
        tokens = np.full((nb, lb), self.pad_id, np.int32)
        lengths = np.ones((nb,), np.int32)
        bases = np.zeros((nb,), np.int32)
        keys = np.zeros((nb, 2), np.uint32)
        temp = np.zeros((nb,), np.float32)
        top_k = np.zeros((nb,), np.int32)
        top_p = np.ones((nb,), np.float32)
        # sentinel slot ids / tables: padded rows' scatters drop
        slot_ids = np.full((nb,), self.arena.num_slots, np.int32)
        if self.paged:
            tables = np.full((nb, self.arena.layout.blocks_per_slot),
                             self.arena.num_blocks, np.int32)
        for i, slot in enumerate(order):
            e = self._prefilling[slot]
            sp = e["req"].sampling
            start = int(e["base"] + e["done"])
            take = takes[slot]
            tokens[i, :take] = e["toks"][start:start + take]
            lengths[i] = take
            bases[i] = start
            keys[i] = e["keys"]
            temp[i], top_k[i], top_p[i] = sp.temperature, sp.top_k, sp.top_p
            slot_ids[i] = slot
            if self.paged:
                tables[i] = self.arena.tables[slot]
        with self._ctx():
            if self.paged:
                tok0, pool = self._prefill_fns[0](
                    self.params, self.arena.pool_cache, tables, tokens,
                    lengths, bases, keys, temp, top_k, top_p)
                self.arena.pool_cache = pool
            else:
                tok0, cache = self._chunk_fn(
                    self.params, self.arena.cache, slot_ids, tokens,
                    lengths, bases, keys, temp, top_k, top_p)
                self.arena.cache = cache
        self.counters["prefill_chunks"] += n
        self.counters["prefill_chunk_tokens"] += int(sum(takes.values()))
        done_rows = []
        for i, slot in enumerate(order):
            e = self._prefilling[slot]
            e["done"] += takes[slot]
            e["req"].prefill_pos = int(e["base"] + e["done"])
            if e["req"].prefill_pos < e["toks"].size:
                continue  # still mid-prefill: next step buys more
            done_rows.append((i, slot))
        if done_rows:
            # Sync only when a row finished prefill and needs its first
            # token; mid-prefill chunks stay async and overlap with the
            # decode dispatch that follows.
            tok0 = np.array(tok0)
        for i, slot in done_rows:
            e = self._prefilling[slot]
            req = e["req"]
            del self._prefilling[slot]
            if self.paged:
                L = int(e["toks"].size)
                self.arena.insert(slot, e["toks"])  # publish to the tree
                self._pos[slot] = L
                self._admitted += 1
                self._hits += e["base"] > 0
                self._hit_tokens += e["base"]
                self._prompt_tokens += L
                self._prefill_computed += L - e["base"]
            self._bind_slot(slot, req, e["keys"])
            self._resume_or_emit(slot, req, int(tok0[i, 0]))

    def _emit(self, slot: int, tok: int) -> None:
        req = self._slots[slot]
        sp = req.sampling
        if tok in sp.stop_tokens:
            return self._finish(slot, "stop")
        if req.first_token_time is None:  # stamp-once: resumes keep TTFT
            req.first_token_time = self._now()
            if self.metrics is not None and req.ttft_s is not None:
                self.metrics.observe("ttft_s", req.ttft_s)
        req.output_tokens.append(tok)
        if req.on_token is not None:
            try:
                req.on_token(req, tok)
            except Exception as e:  # a bad callback fails ONE request
                self.counters["callback_failures"] += 1
                return self._fail_slot(
                    slot, f"on_token callback raised: {e!r}")
        if sp.eos_id is not None and tok == sp.eos_id:
            return self._finish(slot, "eos")
        if req.num_generated >= sp.max_new_tokens:
            return self._finish(slot, "length")
        self._gen_count[slot] = req.num_generated  # fold index of next token

    def _release_slot(self, slot: int) -> Request:
        req = self._slots[slot]
        self._slots[slot] = None
        self._active[slot] = False
        self._prefilling.pop(slot, None)
        self.arena.release(slot)
        return req

    def _finish(self, slot: int, reason: str) -> None:
        req = self._release_slot(slot)
        self._terminalize(req, RequestState.FINISHED, reason)

    def _fail_slot(self, slot: int, msg: str) -> None:
        req = self._release_slot(slot)
        self._terminalize(req, RequestState.ERROR, "error", error=msg)

    def _fail_all_active(self, msg: str) -> None:
        for s in np.nonzero(self._active)[0]:
            self._fail_slot(int(s), msg)

    # -- accounting ----------------------------------------------------
    def lifecycle_report(self) -> Dict[str, object]:
        """Robustness counters + live occupancy (the metrics a fleet
        scheduler watches): preemptions/resumes, timeouts,
        cancellations, rejections, retries, quarantines."""
        return {
            "queued": len(self._queue),
            "running": int(self._active.sum()),
            "prefilling": len(self._prefilling),
            "finished": len(self.finished),
            "rejected": len(self.rejected),
            "draining": self._draining,
            "counters": dict(self.counters),
        }

    def scheduler_report(self) -> Dict[str, object]:
        """Chunked-scheduler stats for the CLI end-of-run report: chunks
        issued, tokens chunk-prefilled, live backlog, and the current
        SLO prefill share."""
        backlog = sum(q.prompt.size + q.num_generated for q in self._queue)
        backlog += sum(int(e["toks"].size - e["base"] - e["done"])
                       for e in self._prefilling.values())
        return {
            "chunked": self._chunked,
            "token_budget": self.token_budget,
            "prefill_chunk": self.prefill_chunk,
            "prefill_chunks": int(self.counters["prefill_chunks"]),
            "prefill_chunk_tokens":
                int(self.counters["prefill_chunk_tokens"]),
            "prefill_backlog_tokens": int(backlog),
            "prefilling": len(self._prefilling),
            "prefill_share": round(self._prefill_share, 4),
            "slo_backoffs": int(self.counters["slo_backoffs"]),
            "ttft_risk_boosts": int(self.counters["ttft_risk_boosts"]),
        }

    def cache_report(self) -> Dict[str, float]:
        """Per-slot cache bytes, latent vs the dense equivalent.

        Both sides must share one base or the ratio lies: the live
        arena tree per slot vs an arena-SHAPED dense cache at the SAME
        num_slots per slot (per-slot ``pos`` vector included on both
        sides) — a dense config reports ratio exactly 1.0. Ring layers
        are honest too: the dense side inherits the same windows via
        ``group_spec``, so a windowed layer's latent ring slots are
        compared against a dense ring of the WINDOW length, never a
        ``max_len``-long dense cache it would not need (tested)."""
        latent = self.arena.slot_bytes()
        dense = self._dense_slot_bytes
        report = {"slot_bytes": latent, "dense_slot_bytes": dense,
                  "ratio": round(latent / dense, 4),
                  # int8 observability: the fp-latent equivalent of this
                  # arena and the dense-vs-live shrink factor (>1 =
                  # smaller than dense; int8 roughly 2-4x the fp ratio)
                  "cache_dtype": self.cache_dtype,
                  "fp_slot_bytes": self._fp_slot_bytes,
                  "compression_vs_dense": round(dense / max(latent, 1), 4)}
        if self.paged:
            report.update({
                "prefix_hit_rate": round(
                    self._hit_tokens / max(self._prompt_tokens, 1), 4),
                "prefix_hit_requests": self._hits,
                "requests_admitted": self._admitted,
                "blocks_in_use": self.arena.blocks_in_use,
                "num_blocks": self.arena.num_blocks,
                "prefill_tokens_saved": self._hit_tokens,
                "prefill_tokens_computed": self._prefill_computed,
            })
        return report
