"""Async serving front-end: HTTP + SSE over the continuous-batching Engine.

The Engine is single-threaded by design — one step loop owns the device
state. This module turns it into a network service WITHOUT giving up
that invariant:

  * a **scheduler thread** owns the Engine and is the only thread that
    ever touches it (submit/step/cancel/drain all happen here);
  * HTTP handler threads (one per connection, ``ThreadingHTTPServer``)
    talk to the scheduler through a thread-safe **command queue** —
    submissions and cancels are enqueued, acknowledged with an Event,
    and the handler blocks on its own per-request token queue while the
    scheduler streams tokens into it via the Engine's ``on_token``
    callback;
  * a shared ``MetricsRegistry`` (``serve/metrics.py``) is written by
    the scheduler (gauges refreshed every loop, histograms via the
    engine hooks) and snapshot by handler threads at ``GET /metrics``.

Endpoints (stdlib only — ``http.server`` / ``socketserver``):

  * ``POST /v1/generate`` — body ``{"prompt": [ids]}`` or
    ``{"text": "..."}`` plus sampling fields (``temperature``,
    ``top_k``, ``top_p``, ``seed``, ``max_new_tokens``, ``eos_id``,
    ``stop_tokens``, ``priority``, ``deadline_s``,
    ``ttft_deadline_s``). ``"stream": true`` (default) answers
    ``text/event-stream``: one ``start`` event (request id), one
    ``token`` event per generated token, one final ``done`` event with
    the full result. ``"stream": false`` blocks and answers one JSON
    result. Admission rejections map to HTTP errors WITH the engine's
    reject reason: 429 (queue full), 503 (draining), 400 (bad prompt /
    bad sampling params).
  * ``DELETE /v1/requests/<id>`` — ``Engine.cancel`` by request id
    (live streams receive their terminal ``done`` event).
  * ``GET /metrics`` — registry snapshot as JSON, or Prometheus text
    with ``?format=prometheus`` (or ``Accept: text/plain``).
  * ``GET /healthz`` — liveness + queue/slot occupancy at a glance.

Shutdown: ``stop(drain=True)`` (the serve CLI maps the first SIGINT to
it) stops admission and keeps stepping until every in-flight request
reaches a terminal state — streaming clients see their ``done`` events
before the listener closes. ``stop(drain=False)`` cancels everything
instead (second SIGINT).
"""
from __future__ import annotations

import http.server
import json
import queue
import re
import threading
import time
from typing import Dict, Optional, Tuple

import numpy as np

from repro.serve.engine import Engine
from repro.serve.metrics import MetricsRegistry
from repro.serve.request import Request, RequestState
from repro.serve.sampling import SamplingParams

_DONE = object()          # token-queue sentinel: request reached terminal
_SAMPLING_FIELDS = ("temperature", "top_k", "top_p", "seed",
                    "max_new_tokens", "eos_id", "stop_tokens")
_REQUEST_FIELDS = ("priority", "ttft_deadline_s", "deadline_s")


class BadRequest(ValueError):
    """Client-side error in a /v1/generate body (HTTP 400)."""


def build_request(body: dict, on_token=None) -> Request:
    """A ``Request`` from a JSON body — raises ``BadRequest`` on
    malformed prompts or sampling fields (the HTTP 400 class; admission
    policy violations like out-of-vocab ids are the ENGINE's call and
    come back as rejected requests instead)."""
    if not isinstance(body, dict):
        raise BadRequest("body must be a JSON object")
    unknown = set(body) - set(_SAMPLING_FIELDS) - set(_REQUEST_FIELDS) \
        - {"prompt", "text", "stream"}
    if unknown:
        raise BadRequest(f"unknown fields: {sorted(unknown)}")
    if ("prompt" in body) == ("text" in body):
        raise BadRequest("provide exactly one of 'prompt' (token ids) "
                         "or 'text'")
    if "text" in body:
        from repro.data import tokenizer
        if not isinstance(body["text"], str):
            raise BadRequest("'text' must be a string")
        prompt = tokenizer.encode(body["text"])
    else:
        prompt = body["prompt"]
        if not isinstance(prompt, (list, tuple)) \
                or not all(isinstance(t, int) for t in prompt):
            raise BadRequest("'prompt' must be a list of integer token ids")
        prompt = np.asarray(prompt, np.int64)
    sp_kw = {k: body[k] for k in _SAMPLING_FIELDS if body.get(k) is not None}
    if "stop_tokens" in sp_kw:
        sp_kw["stop_tokens"] = tuple(sp_kw["stop_tokens"])
    rq_kw = {k: body[k] for k in _REQUEST_FIELDS if body.get(k) is not None}
    try:
        return Request(prompt, SamplingParams(**sp_kw), on_token=on_token,
                       **rq_kw)
    except (ValueError, TypeError) as e:
        raise BadRequest(str(e))


def request_result(req: Request) -> dict:
    """The terminal JSON payload (the ``done`` SSE event / the whole
    non-streaming response). Only read once ``req.is_terminal`` — the
    scheduler never mutates a terminal request."""
    return {
        "request_id": req.request_id,
        "tokens": [int(t) for t in req.output_tokens],
        "num_generated": req.num_generated,
        "finish_reason": req.finish_reason,
        "state": req.state.value,
        "error": req.error,
        "num_preemptions": req.num_preemptions,
        "ttft_s": req.ttft_s,
        "latency_s": req.latency_s,
    }


class _Stream:
    """Handler-side view of one in-flight request: the token queue the
    scheduler feeds and the terminal event the non-streaming path waits
    on."""

    def __init__(self, want_stream: bool):
        self.tokens: queue.Queue = queue.Queue()
        self.terminal = threading.Event()
        self.on_token = (lambda req, tok: self.tokens.put(tok)) \
            if want_stream else None

    def finish(self) -> None:
        self.tokens.put(_DONE)
        self.terminal.set()


class _Submission:
    """One command through the scheduler queue; ``done`` is set after
    the scheduler executed it and ``result`` holds the answer."""

    def __init__(self, kind: str, payload):
        self.kind, self.payload = kind, payload
        self.done = threading.Event()
        self.result = None


class _HTTPServer(http.server.ThreadingHTTPServer):
    daemon_threads = True
    allow_reuse_address = True
    app: "ServeServer"


class ServeServer:
    """The HTTP+SSE front-end over one Engine.

        srv = ServeServer(engine)           # engine must be idle
        host, port = srv.start()
        ... ServeClient(host, port).generate([1, 2, 3]) ...
        srv.stop(drain=True)                # in-flight requests finish

    After ``start()`` the engine belongs to the scheduler thread —
    drive all traffic through HTTP (or ``serve/client.py``)."""

    def __init__(self, engine: Engine, host: str = "127.0.0.1",
                 port: int = 0, *, metrics: Optional[MetricsRegistry] = None,
                 poll_s: float = 0.02, stream_timeout_s: float = 300.0,
                 verbose: bool = False):
        if engine.has_work():
            raise ValueError("attach the server to an idle engine")
        self.engine = engine
        self.metrics = metrics or engine.metrics or MetricsRegistry()
        engine.metrics = self.metrics
        self.host, self.port = host, port
        self.poll_s = poll_s
        self.stream_timeout_s = stream_timeout_s
        self.verbose = verbose
        self._cmds: queue.Queue = queue.Queue()
        self._live: Dict[int, _Stream] = {}   # request_id -> stream
        self._reqs: Dict[int, Request] = {}   # request_id -> request
        self._stopping = False
        self._stopped = threading.Event()
        self._httpd: Optional[_HTTPServer] = None
        self._threads = []
        self._static_gauges = False

    # -- lifecycle -----------------------------------------------------
    def start(self) -> Tuple[str, int]:
        """Bind (port 0 = ephemeral), spawn the HTTP listener and the
        scheduler thread, return the bound (host, port)."""
        if self._httpd is not None:
            raise RuntimeError("server already started")
        self._httpd = _HTTPServer((self.host, self.port), _Handler)
        self._httpd.app = self
        self.host, self.port = self._httpd.server_address[:2]
        self._threads = [
            threading.Thread(target=self._httpd.serve_forever,
                             kwargs={"poll_interval": 0.05},
                             name="serve-http", daemon=True),
            threading.Thread(target=self._scheduler, name="serve-scheduler",
                             daemon=True),
        ]
        for t in self._threads:
            t.start()
        return self.host, self.port

    def request_stop(self, drain: bool = True) -> None:
        """Signal-handler-safe shutdown request (just a queue put)."""
        self._cmds.put(_Submission("stop", drain))

    def wait(self, timeout_s: Optional[float] = None) -> bool:
        """Block until the scheduler exits. Poll-waits in short slices
        so the MAIN thread keeps receiving SIGINT (a bare Event.wait can
        sit in C and starve the handler on some platforms). True if the
        scheduler stopped."""
        deadline = None if timeout_s is None \
            else time.monotonic() + timeout_s
        while True:
            if self._stopped.wait(0.1):
                return True
            if deadline is not None and time.monotonic() >= deadline:
                return False

    def stop(self, drain: bool = True, timeout_s: Optional[float] = None) \
            -> bool:
        """Stop serving. ``drain=True``: admission closes and residents
        run to completion (their streams get ``done`` events) before the
        listener shuts down; ``drain=False`` cancels everything. Returns
        True when the scheduler exited within ``timeout_s``."""
        self.request_stop(drain)
        clean = self.wait(timeout_s)
        if self._httpd is not None:
            self._httpd.shutdown()
            self._httpd.server_close()
        for t in self._threads:
            t.join(timeout=5.0)
        return clean

    # -- handler-thread API (everything bridges via the command queue) --
    def submit(self, req: Request, stream: _Stream,
               timeout_s: float = 60.0) -> Request:
        sub = _Submission("submit", (req, stream))
        self._cmds.put(sub)
        if not sub.done.wait(timeout_s):
            raise TimeoutError("scheduler did not acknowledge the "
                               "submission (engine wedged?)")
        return sub.result

    def cancel(self, request_id: int, timeout_s: float = 60.0) -> bool:
        sub = _Submission("cancel", request_id)
        self._cmds.put(sub)
        return bool(sub.done.wait(timeout_s) and sub.result)

    # -- the scheduler thread ------------------------------------------
    def _scheduler(self) -> None:
        eng = self.engine
        try:
            while True:
                # block only when idle; drain every queued command
                timeout = self.poll_s if not eng.has_work() \
                    and not self._stopping else 0.0
                try:
                    cmd = self._cmds.get(timeout=timeout)
                except queue.Empty:
                    cmd = None
                while cmd is not None:
                    self._execute(cmd)
                    try:
                        cmd = self._cmds.get_nowait()
                    except queue.Empty:
                        cmd = None
                if eng.has_work():
                    eng.step()
                self._notify_terminal()
                self._refresh_gauges()
                if self._stopping and not eng.has_work():
                    break
        finally:
            # unblock every waiter: reject queued commands, close live
            # streams (normally empty after a clean drain)
            self._stopping = True
            while True:
                try:
                    self._execute(self._cmds.get_nowait(), stopped=True)
                except queue.Empty:
                    break
            for stream in self._live.values():
                stream.finish()
            self._live.clear()
            self._reqs.clear()
            self._refresh_gauges()
            self._stopped.set()

    def _execute(self, cmd: _Submission, stopped: bool = False) -> None:
        eng = self.engine
        if cmd.kind == "submit":
            req, stream = cmd.payload
            if stopped:
                # never reached the engine: synthesize the reject the
                # draining engine would have issued
                req.state = RequestState.REJECTED
                req.finished, req.finish_reason = True, "rejected"
                req.error = "server stopped"
                cmd.result = req
            else:
                cmd.result = eng.submit(req)
                if not req.is_terminal:
                    self._live[req.request_id] = stream
                    self._reqs[req.request_id] = req
        elif cmd.kind == "cancel":
            req = self._reqs.get(cmd.payload)
            cmd.result = eng.cancel(req) if req is not None else False
        elif cmd.kind == "stop":
            self._stopping = True
            if cmd.payload:                       # drain
                eng.begin_drain()
            else:                                 # abort: cancel the world
                eng.abort()
        cmd.done.set()

    def _notify_terminal(self) -> None:
        done = [rid for rid, req in self._reqs.items() if req.is_terminal]
        for rid in done:
            self._live.pop(rid).finish()
            del self._reqs[rid]

    def _refresh_gauges(self) -> None:
        eng = self.engine
        if not self._static_gauges:
            # slot_bytes / dense base never change for a live engine;
            # computing them re-traces eval_shape, so stamp them ONCE
            rep = eng.cache_report()
            self.metrics.set_gauges({
                "slot_bytes": rep["slot_bytes"],
                "dense_slot_bytes": rep["dense_slot_bytes"],
                "cache_ratio": rep["ratio"],
                "slots_total": eng.arena.num_slots,
            })
            if eng.paged:
                self.metrics.set_gauge("num_blocks", eng.arena.num_blocks)
            self._static_gauges = True
        life = eng.lifecycle_report()
        self.metrics.set_gauges({
            "queue_depth": life["queued"],
            "running": life["running"],
            "slots_free": eng.arena.num_free,
            "draining": int(life["draining"]),
        })
        for k, v in life["counters"].items():
            self.metrics.set_counter(k, v)
        self.metrics.set_counter("requests_submitted",
                                 life["finished"] + life["rejected"]
                                 + life["queued"] + life["running"])
        if eng.paged:
            self.metrics.set_gauges({
                "blocks_in_use": eng.arena.blocks_in_use,
                "prefix_hit_rate": round(
                    eng._hit_tokens / max(eng._prompt_tokens, 1), 4),
            })

    # -- handler-thread reads ------------------------------------------
    def health(self) -> dict:
        g = self.metrics.snapshot()["gauges"]
        status = "stopped" if self._stopped.is_set() else \
            "draining" if self._stopping or g.get("draining") else "ok"
        return {"status": status,
                "queued": int(g.get("queue_depth", 0)),
                "running": int(g.get("running", 0)),
                "slots_free": int(g.get("slots_free", 0)),
                "slots_total": int(g.get("slots_total", 0))}


def _reject_status(reason: str) -> int:
    """Map an engine admission-reject reason to an HTTP status: bounded
    queue -> 429 Too Many Requests, draining -> 503, anything else
    (oversized prompt, out-of-vocab ids) is the client's fault -> 400."""
    if "queue full" in reason:
        return 429
    if "draining" in reason or "stopped" in reason:
        return 503
    return 400


class _Handler(http.server.BaseHTTPRequestHandler):
    server_version = "repro-serve/1.0"
    _CANCEL_RE = re.compile(r"^/v1/requests/(\d+)$")

    @property
    def app(self) -> ServeServer:
        return self.server.app

    def log_message(self, fmt, *args):          # default: silent server
        if self.app.verbose:
            super().log_message(fmt, *args)

    def _json(self, code: int, obj: dict) -> None:
        body = (json.dumps(obj) + "\n").encode()
        self.send_response(code)
        self.send_header("Content-Type", "application/json")
        self.send_header("Content-Length", str(len(body)))
        self.end_headers()
        self.wfile.write(body)

    def _text(self, code: int, text: str, ctype: str) -> None:
        body = text.encode()
        self.send_response(code)
        self.send_header("Content-Type", ctype)
        self.send_header("Content-Length", str(len(body)))
        self.end_headers()
        self.wfile.write(body)

    # -- routes --------------------------------------------------------
    def do_GET(self):
        path, _, query = self.path.partition("?")
        if path == "/healthz":
            return self._json(200, self.app.health())
        if path == "/metrics":
            want_prom = "format=prometheus" in query or (
                "format=" not in query
                and "text/plain" in self.headers.get("Accept", ""))
            if want_prom:
                return self._text(200, self.app.metrics.to_prometheus(),
                                  "text/plain; version=0.0.4")
            return self._json(200, self.app.metrics.snapshot())
        self._json(404, {"error": f"no route GET {path}"})

    def do_POST(self):
        path = self.path.partition("?")[0]
        if path == "/v1/generate":
            return self._generate()
        self._json(404, {"error": f"no route POST {path}"})

    def do_DELETE(self):
        m = self._CANCEL_RE.match(self.path.partition("?")[0])
        if not m:
            return self._json(404, {"error": "DELETE /v1/requests/<id>"})
        rid = int(m.group(1))
        self._json(200, {"request_id": rid,
                         "cancelled": self.app.cancel(rid)})

    # -- generation ----------------------------------------------------
    def _generate(self) -> None:
        try:
            n = int(self.headers.get("Content-Length") or 0)
            body = json.loads(self.rfile.read(n) or b"{}")
        except (ValueError, json.JSONDecodeError):
            return self._json(400, {"error": "body must be valid JSON"})
        want_stream = bool(body.get("stream", True)) \
            if isinstance(body, dict) else True
        stream = _Stream(want_stream)
        try:
            req = build_request(body, on_token=stream.on_token)
        except BadRequest as e:
            return self._json(400, {"error": str(e)})
        try:
            self.app.submit(req, stream)
        except TimeoutError as e:
            return self._json(503, {"error": str(e)})
        if req.state is RequestState.REJECTED:
            return self._json(_reject_status(req.error or ""),
                              {"error": req.error,
                               "finish_reason": "rejected"})
        if not want_stream:
            if not stream.terminal.wait(self.app.stream_timeout_s):
                return self._json(504, {"error": "generation timed out"})
            return self._json(200, request_result(req))
        self._stream_sse(req, stream)

    def _stream_sse(self, req: Request, stream: _Stream) -> None:
        self.send_response(200)
        self.send_header("Content-Type", "text/event-stream")
        self.send_header("Cache-Control", "no-cache")
        self.send_header("X-Request-Id", str(req.request_id))
        self.end_headers()

        def event(name: str, payload: dict) -> None:
            self.wfile.write(f"event: {name}\ndata: "
                             f"{json.dumps(payload)}\n\n".encode())
            self.wfile.flush()

        try:
            event("start", {"request_id": req.request_id})
            idx = 0
            while True:
                try:
                    tok = stream.tokens.get(timeout=self.app.stream_timeout_s)
                except queue.Empty:
                    event("error", {"error": "token stream timed out"})
                    return
                if tok is _DONE:
                    event("done", request_result(req))
                    return
                event("token", {"index": idx, "token": int(tok)})
                idx += 1
        except (BrokenPipeError, ConnectionResetError):
            # client went away mid-stream: free its slot for real traffic
            self.app.cancel(req.request_id)
