"""Per-request sampling configuration for the serving engine.

The numeric sampling itself lives in ``repro.models.sampling`` (one
fused batched primitive); this module is the user-facing request-level
API that the engine packs into per-slot arrays.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

from repro.models.sampling import sample_logits  # noqa: F401  (re-export)


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """How one request's tokens are chosen and when it stops.

    temperature: 0 = greedy (bit-identical argmax); > 0 samples from the
        temperature-scaled distribution.
    top_k: keep only the k highest logits before sampling (0 = off).
    top_p: nucleus sampling — keep the smallest probability-sorted
        prefix whose mass reaches p (1.0 = off).
    seed: per-request PRNG seed. Token i is sampled with
        fold_in(PRNGKey(seed), i), so the same (prompt, params, seed)
        reproduces the same tokens regardless of which arena slot the
        request lands in or what else is in the batch.
    max_new_tokens: hard output-length cap (finish_reason 'length').
    eos_id: finishing token — it is emitted, then the slot is released
        (finish_reason 'eos').
    stop_tokens: extra terminators that are NOT emitted
        (finish_reason 'stop').
    """
    temperature: float = 0.0
    top_k: int = 0
    top_p: float = 1.0
    seed: int = 0
    max_new_tokens: int = 16
    eos_id: Optional[int] = None
    stop_tokens: Tuple[int, ...] = ()

    def __post_init__(self):
        if not math.isfinite(self.temperature) or self.temperature < 0:
            raise ValueError("temperature must be finite and >= 0")
        if self.top_k < 0:
            raise ValueError("top_k must be >= 0 (0 disables)")
        if not 0 < self.top_p <= 1.0:
            raise ValueError("top_p must be in (0, 1]")
        if self.max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1")
