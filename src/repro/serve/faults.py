"""Deterministic fault injection for the serving engine.

A ``FaultInjector`` is a seeded schedule of the failures a production
serving deployment actually sees, threaded through ``Engine`` behind a
no-op default (``Engine(..., faults=None)`` pays nothing):

  * **transient dispatch failures** — ``maybe_fail_dispatch()`` raises
    ``TransientStepFault`` for the first N attempts of a scheduled step;
    the engine's bounded-retry loop must absorb them without corrupting
    any request (faults fire BEFORE the jitted call, so no device state
    moves on a failed attempt);
  * **NaN / non-finite logits** — ``poison_mask()`` names arena rows
    whose logits the fused step head overwrites with NaN *inside the
    jit* (the ``poison`` argument of ``lm.make_engine_step``), so the
    per-row finite guard is exercised end to end;
  * **forced pool exhaustion** — on scheduled steps the injector
    allocates every free block of the paged ``BlockPool`` and holds
    them for ``hold`` steps ("the hog"), forcing admission pressure and
    mid-decode ``ensure`` failures through the REAL allocation paths so
    preemption fires;
  * **clock skew** — ``now()`` is the engine's clock; scheduled skews
    jump it forward so deadline enforcement is testable without real
    sleeping, and ``sleep()`` (used for retry backoff under injection)
    advances the virtual clock instead of blocking the test.

Explicit schedules (``fail_attempts`` / ``nan_rows`` / ``hog_steps`` /
``skew_steps``: dicts keyed by engine step index) make single-fault
regression tests deterministic; the seeded Bernoulli rates layer random
soak traffic on top. Two injectors with the same constructor arguments
produce the same schedule.
"""
from __future__ import annotations

import collections
import time
from typing import Dict, Iterable, List, Optional, Tuple

import numpy as np


class TransientStepFault(RuntimeError):
    """A retryable serving-step dispatch failure (injected or real)."""


class FaultInjector:
    """Seeded fault schedule the engine consults once per ``step()``.

    The engine calls, in order: ``begin_step(pool)`` (advance the
    schedule: release expired hogs, start new ones, apply clock skew),
    ``maybe_fail_dispatch()`` before every dispatch attempt, and
    ``poison_mask(num_slots, active)`` to build the step's NaN rows.
    ``stats`` counts every fault actually delivered."""

    def __init__(self, seed: int = 0, *,
                 step_fail_p: float = 0.0, fail_burst: int = 1,
                 nan_p: float = 0.0,
                 hog_p: float = 0.0, hog_hold_steps: int = 2,
                 skew_p: float = 0.0, skew_s: float = 0.0,
                 fail_attempts: Optional[Dict[int, int]] = None,
                 nan_rows: Optional[Dict[int, Iterable[int]]] = None,
                 hog_steps: Optional[Dict[int, int]] = None,
                 skew_steps: Optional[Dict[int, float]] = None):
        self.rng = np.random.RandomState(seed)
        self.step_fail_p, self.fail_burst = step_fail_p, fail_burst
        self.nan_p = nan_p
        self.hog_p, self.hog_hold_steps = hog_p, hog_hold_steps
        self.skew_p, self.skew_s = skew_p, skew_s
        self.fail_attempts = dict(fail_attempts or {})
        self.nan_rows = {int(k): tuple(v) for k, v in (nan_rows or {}).items()}
        self.hog_steps = dict(hog_steps or {})
        self.skew_steps = dict(skew_steps or {})
        self.stats: collections.Counter = collections.Counter()
        self._skew = 0.0
        self._step = -1
        self._fail_left = 0             # failing attempts left this step
        self._hogs: List[Tuple[int, List[int], object]] = []
        self._pool = None

    # -- clock ---------------------------------------------------------
    def now(self) -> float:
        """The engine's clock: wall monotonic time plus injected skew."""
        return time.monotonic() + self._skew

    def sleep(self, seconds: float) -> None:
        """Virtual sleep: retry backoff under injection advances the
        clock instead of blocking the test suite."""
        self._skew += seconds
        self.stats["virtual_sleep_s"] += seconds

    def advance(self, seconds: float) -> None:
        """Jump the clock forward (deadline tests)."""
        self._skew += seconds

    @property
    def step_index(self) -> int:
        return self._step

    # -- engine hooks --------------------------------------------------
    def begin_step(self, pool=None) -> None:
        """Advance the schedule one engine step. ``pool`` is the paged
        ``BlockPool`` (or None for the linear arena — hogs are skipped)."""
        self._step += 1
        self._pool = pool
        # scheduled + random clock skew
        skew = self.skew_steps.get(self._step, 0.0)
        if self.skew_p and self.rng.random_sample() < self.skew_p:
            skew += self.skew_s
        if skew:
            self._skew += skew
            self.stats["clock_skews"] += 1
        # release hogs whose hold expired
        keep = []
        for release_at, blocks, hpool in self._hogs:
            if self._step >= release_at:
                for b in blocks:
                    hpool.decref(b)
            else:
                keep.append((release_at, blocks, hpool))
        self._hogs = keep
        # start a new hog: grab EVERY free block for ``hold`` steps
        hold = self.hog_steps.get(self._step, 0)
        if not hold and self.hog_p and self.rng.random_sample() < self.hog_p:
            hold = self.hog_hold_steps
        if hold and pool is not None:
            blocks = []
            while True:
                b = pool.alloc()
                if b is None:
                    break
                blocks.append(b)
            if blocks:
                self._hogs.append((self._step + hold, blocks, pool))
                self.stats["hogs"] += 1
                self.stats["hogged_blocks"] += len(blocks)
        # arm this step's dispatch-failure burst
        self._fail_left = self.fail_attempts.get(self._step, 0)
        if not self._fail_left and self.step_fail_p \
                and self.rng.random_sample() < self.step_fail_p:
            self._fail_left = self.fail_burst

    def maybe_fail_dispatch(self) -> None:
        """Raise ``TransientStepFault`` while this step's burst lasts.
        Called before EVERY dispatch attempt, so a burst of k exercises
        k retries."""
        if self._fail_left > 0:
            self._fail_left -= 1
            self.stats["dispatch_faults"] += 1
            raise TransientStepFault(
                f"injected transient dispatch failure at step {self._step}")

    def poison_mask(self, num_slots: int, active: np.ndarray) -> np.ndarray:
        """(num_slots,) bool: rows whose logits this step's fused head
        overwrites with NaN. Always a subset of ``active``."""
        mask = np.zeros((num_slots,), bool)
        for r in self.nan_rows.get(self._step, ()):
            if 0 <= r < num_slots:
                mask[r] = True
        if self.nan_p:
            mask |= self.rng.random_sample(num_slots) < self.nan_p
        mask &= np.asarray(active, bool)
        self.stats["nan_rows"] += int(mask.sum())
        return mask

    def release_hogs(self) -> int:
        """Return every held block to its pool (end-of-test cleanup when
        the engine drained before a scheduled release step arrived)."""
        n = 0
        for _, blocks, hpool in self._hogs:
            for b in blocks:
                hpool.decref(b)
                n += 1
        self._hogs = []
        return n

    @property
    def holding_blocks(self) -> int:
        return sum(len(blocks) for _, blocks, _ in self._hogs)
