"""Slot-based latent KV-cache arena for continuous batching.

The arena owns ONE batched model cache of shape ``(num_slots,
cache_len, …)`` per layer (latent ``c_k``/``c_v`` of rank r_k/r_v for
LatentLLM configs — the paper's serving payoff) with a per-slot position
vector ``cache['pos'] (num_slots,)``: every slot sits at its own ragged
position. How positions map to physical slots is each layer's
``CacheLayout`` (``self.layouts``): linear layers span ``max_len`` and
mask a ``valid_len`` prefix in the decode kernels; sliding-window layers
hold a ``min(max_len, window)``-slot RING whose writes wrap mod
``cache_len`` and whose kernels mask a per-slot (start, length) ring
descriptor. Slots are acquired at admission, written by one
ragged-prefill scatter, and recycled when a request finishes — the
decode dispatch shape never changes, so nothing recompiles as traffic
churns.

With a ``jax.sharding.Mesh`` the arena is laid out for tensor/data-
parallel serving (distributed.sharding.serve_cache_specs): slots on the
data axes, heads on 'model' where they divide, latent rank dims local.
The scatter is jitted with NamedSharding in/out so admission writes
never reshard the resident cache.
"""
from __future__ import annotations

from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import transformer as T


def cache_bytes(cfg: ModelConfig, batch: int, max_len: int) -> int:
    """Total cache bytes for ``batch`` slots of ``max_len`` tokens."""
    tree = jax.eval_shape(lambda: T.init_cache(cfg, batch, max_len))
    return sum(int(np.prod(l.shape)) * l.dtype.itemsize
               for l in jax.tree.leaves(tree))


def arena_cache_shape(cfg: ModelConfig, num_slots: int, max_len: int):
    """Abstract shape tree of an ARENA cache: the model cache plus the
    per-slot ragged ``pos`` vector (eval_shape of ``init_cache`` alone
    would silently report the scalar ``pos`` the lockstep paths use)."""

    def build():
        cache = T.init_cache(cfg, num_slots, max_len)
        cache["pos"] = jnp.zeros((num_slots,), jnp.int32)
        return cache

    return jax.eval_shape(build)


def arena_cache_bytes(cfg: ModelConfig, num_slots: int, max_len: int) -> int:
    """Total bytes of an arena-shaped cache (per-slot pos included)."""
    return sum(int(np.prod(l.shape)) * l.dtype.itemsize
               for l in jax.tree.leaves(
                   arena_cache_shape(cfg, num_slots, max_len)))


class LatentCacheArena:
    """Owns the slot-batched cache plus slot bookkeeping.

    ``acquire()``/``release()`` recycle slot ids; ``write()`` scatters a
    freshly prefilled (n_admit, …) cache into arena slots in one jitted
    dispatch (compiled once per admission-batch bucket). The arena never
    moves a resident request: a slot's latent cache stays in place from
    admission to finish."""

    def __init__(self, cfg: ModelConfig, num_slots: int, max_len: int,
                 mesh=None):
        if num_slots < 1 or max_len < 2:
            raise ValueError("need num_slots >= 1 and max_len >= 2")
        self.cfg, self.num_slots, self.max_len = cfg, num_slots, max_len
        self.mesh = mesh
        # one CacheLayout per block: linear vs ring slot arithmetic
        self.layouts = T.cache_layouts(cfg, max_len)
        cache = T.init_cache(cfg, num_slots, max_len)
        cache["pos"] = jnp.zeros((num_slots,), jnp.int32)  # per-slot ragged
        donate = (0,) if jax.default_backend() != "cpu" else ()
        if mesh is not None:
            from jax.sharding import NamedSharding, PartitionSpec as P
            from repro.distributed import sharding as shd
            specs = shd.serve_cache_specs(
                mesh, arena_cache_shape(cfg, num_slots, max_len),
                layouts=self.layouts)
            self.shardings = shd.to_named(mesh, specs)
            cache = jax.device_put(cache, self.shardings)
            rep = NamedSharding(mesh, P())
            self._write_fn = jax.jit(
                self._scatter, donate_argnums=donate,
                in_shardings=(self.shardings, None, rep),
                out_shardings=self.shardings)
        else:
            self.shardings = None
            self._write_fn = jax.jit(self._scatter, donate_argnums=donate)
        self.cache = cache
        self._free: List[int] = list(range(num_slots - 1, -1, -1))
        self._free_set = set(self._free)  # O(1) double-release detection

    # -- slot recycling ------------------------------------------------
    @property
    def num_free(self) -> int:
        return len(self._free)

    def acquire(self) -> Optional[int]:
        if not self._free:
            return None
        slot = self._free.pop()
        self._free_set.discard(slot)
        return slot

    def release(self, slot: int) -> None:
        if not 0 <= slot < self.num_slots:
            raise ValueError(
                f"slot {slot} out of range [0, {self.num_slots})")
        if slot in self._free_set:
            raise ValueError(f"double release of slot {slot}")
        self._free.append(slot)
        self._free_set.add(slot)

    # -- cache writes --------------------------------------------------
    def write(self, new_cache, slot_ids: np.ndarray) -> None:
        """Scatter prefill-cache rows into arena slots.

        ``slot_ids`` (n_admit,) int32 may contain the sentinel
        ``num_slots`` on padded admission rows — out-of-bounds scatter
        rows are dropped, which is how a bucketed admission batch avoids
        one compile per batch size."""
        self.cache = self._write_fn(self.cache, new_cache,
                                    jnp.asarray(slot_ids, jnp.int32))

    @staticmethod
    def _scatter(arena, new, slot_ids):
        def rows(a, b):  # batch axis 0 (trailing blocks, pos)
            return a.at[slot_ids].set(b.astype(a.dtype), mode="drop")

        def stacked(a, b):  # (n_layers, batch, …) group-stacked leaves
            return a.at[:, slot_ids].set(b.astype(a.dtype), mode="drop")

        return {
            "pos": rows(arena["pos"], new["pos"]),
            "groups": [jax.tree.map(stacked, ag, ng)
                       for ag, ng in zip(arena["groups"], new["groups"])],
            "trailing": [jax.tree.map(rows, at_, nt)
                         for at_, nt in zip(arena["trailing"],
                                            new["trailing"])],
        }

    # -- accounting ----------------------------------------------------
    def slot_bytes(self) -> int:
        """Cache bytes held per slot, measured on the LIVE cache tree
        (the latent r_k+r_v win shows here). Counting the live tree —
        not an ``init_cache`` eval_shape — keeps the per-slot ``pos``
        vector and any layout changes in the same base that
        ``Engine.cache_report`` compares against."""
        total = sum(int(l.size) * l.dtype.itemsize
                    for l in jax.tree.leaves(self.cache))
        return total // self.num_slots
