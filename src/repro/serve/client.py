"""Stdlib HTTP/SSE client for the async serving front-end.

The consumer half of ``serve/server.py`` — used by tests, the
``make serve-smoke`` target, and the server-mode serving benchmark, and
small enough to crib for a real deployment. ``http.client`` only.

    from repro.serve.client import ServeClient

    c = ServeClient(host, port)
    out = c.generate([1, 2, 3], max_new_tokens=16)       # streams SSE
    out["tokens"], out["finish_reason"], out["client_ttft_s"]

    c.generate([1, 2, 3], stream=False)                  # one JSON blob
    c.cancel(request_id)                                 # DELETE
    c.metrics()            # JSON dict   (c.metrics("prometheus") -> str)
    c.healthz()

``generate`` raises ``ServeHTTPError`` (with ``.status`` and the
server's reject reason) on non-200 responses — 429 queue-full, 400 bad
prompt, 503 draining.
"""
from __future__ import annotations

import http.client
import json
import time
from typing import Callable, Iterator, Optional, Sequence, Tuple

_GEN_FIELDS = ("temperature", "top_k", "top_p", "seed", "max_new_tokens",
               "eos_id", "stop_tokens", "priority", "deadline_s",
               "ttft_deadline_s")


class ServeHTTPError(RuntimeError):
    """A non-200 response; ``status`` + the server's ``error`` reason."""

    def __init__(self, status: int, reason: str):
        super().__init__(f"HTTP {status}: {reason}")
        self.status = status
        self.reason = reason


def sse_events(resp) -> Iterator[Tuple[str, dict]]:
    """Parse a ``text/event-stream`` response into (event, payload)
    pairs. Handles multi-line ``data:`` fields; the stream ends when
    the server closes the connection."""
    event, data = None, []
    for raw in resp:
        line = raw.decode("utf-8").rstrip("\r\n")
        if not line:
            if event is not None or data:
                yield event or "message", json.loads("\n".join(data) or "{}")
            event, data = None, []
        elif line.startswith("event:"):
            event = line[len("event:"):].strip()
        elif line.startswith("data:"):
            data.append(line[len("data:"):].strip())


class ServeClient:
    """One serving endpoint; a fresh connection per call (the server
    speaks HTTP/1.0 close-delimited streams)."""

    def __init__(self, host: str, port: int, timeout_s: float = 300.0):
        self.host, self.port, self.timeout_s = host, port, timeout_s

    def _conn(self) -> http.client.HTTPConnection:
        return http.client.HTTPConnection(self.host, self.port,
                                          timeout=self.timeout_s)

    def _request(self, method: str, path: str, body: Optional[dict] = None):
        conn = self._conn()
        conn.request(method, path,
                     None if body is None else json.dumps(body),
                     {"Content-Type": "application/json"})
        return conn, conn.getresponse()

    def _json_call(self, method: str, path: str,
                   body: Optional[dict] = None) -> dict:
        conn, resp = self._request(method, path, body)
        try:
            payload = json.loads(resp.read() or b"{}")
            if resp.status != 200:
                raise ServeHTTPError(resp.status,
                                     payload.get("error", resp.reason))
            return payload
        finally:
            conn.close()

    # -- generation ----------------------------------------------------
    def generate(self, prompt: Optional[Sequence[int]] = None, *,
                 text: Optional[str] = None, stream: bool = True,
                 on_token: Optional[Callable[[int], None]] = None,
                 **params) -> dict:
        """POST /v1/generate. Returns the terminal result dict (the
        server's ``done`` payload); streaming adds client-side
        ``client_ttft_s`` / ``client_latency_s`` wall timings and calls
        ``on_token(tok)`` per streamed token."""
        unknown = set(params) - set(_GEN_FIELDS)
        if unknown:
            raise TypeError(f"unknown generate() fields: {sorted(unknown)}")
        body = {k: v for k, v in params.items() if v is not None}
        body["stream"] = stream
        if text is not None:
            body["text"] = text
        else:
            body["prompt"] = [int(t) for t in (prompt or ())]
        t0 = time.perf_counter()
        conn, resp = self._request("POST", "/v1/generate", body)
        try:
            if resp.status != 200:
                payload = json.loads(resp.read() or b"{}")
                raise ServeHTTPError(resp.status,
                                     payload.get("error", resp.reason))
            if not stream:
                return json.loads(resp.read())
            ttft = None
            tokens = []
            for event, payload in sse_events(resp):
                if event == "token":
                    if ttft is None:
                        ttft = time.perf_counter() - t0
                    tokens.append(payload["token"])
                    if on_token is not None:
                        on_token(payload["token"])
                elif event == "done":
                    payload["client_ttft_s"] = ttft
                    payload["client_latency_s"] = time.perf_counter() - t0
                    assert payload["tokens"] == tokens, \
                        "SSE token events disagree with the done payload"
                    return payload
                elif event == "error":
                    raise ServeHTTPError(500, payload.get("error", "stream "
                                                          "failed"))
            raise ServeHTTPError(500, "stream ended without a done event")
        finally:
            conn.close()

    # -- control / observability ---------------------------------------
    def cancel(self, request_id: int) -> bool:
        return bool(self._json_call(
            "DELETE", f"/v1/requests/{int(request_id)}")["cancelled"])

    def metrics(self, fmt: str = "json"):
        if fmt == "prometheus":
            conn, resp = self._request("GET", "/metrics?format=prometheus")
            try:
                body = resp.read().decode()
                if resp.status != 200:
                    raise ServeHTTPError(resp.status, body[:200])
                return body
            finally:
                conn.close()
        return self._json_call("GET", "/metrics")

    def healthz(self) -> dict:
        return self._json_call("GET", "/healthz")
