"""Serving metrics: counters, gauges, and ring-buffer histograms.

A ``MetricsRegistry`` is the observability surface the async front-end
(``serve/server.py``) exposes at ``GET /metrics``: monotonic counters
(request terminal states, mirrored engine lifecycle counters), gauges
(queue depth, slot/block occupancy, prefix hit rate), and fixed-window
ring-buffer histograms with p50/p99 — TTFT, ms/token, and end-to-end
latency. Everything is stdlib + numpy and thread-safe: the engine's
scheduler thread writes while HTTP handler threads snapshot.

Two render formats:

  * ``snapshot()`` — one JSON-serializable dict
    ``{"counters", "gauges", "histograms"}`` (each histogram summarized
    as count/window/p50/p99/mean/max);
  * ``to_prometheus()`` — Prometheus text exposition (counters,
    gauges, and summaries with ``quantile`` labels), every name
    prefixed ``serve_`` and sanitized.

The engine reports into the registry through two hooks (both no-ops
when ``Engine(..., metrics=None)``): ``observe("ttft_s", …)`` at
first-token emission and ``on_terminal(req)`` when a request reaches a
terminal state (state counters + e2e/ms-per-token histograms).
"""
from __future__ import annotations

import collections
import re
import threading
from typing import Dict, List, Optional

import numpy as np

from repro.serve.request import Request, RequestState


class RingHistogram:
    """Fixed-capacity ring buffer over the most recent observations.

    Serving latency distributions drift with traffic; a ring window
    keeps p50/p99 representative of RECENT requests while ``count``
    stays the all-time total. Not thread-safe on its own — the registry
    serializes access."""

    def __init__(self, capacity: int = 512):
        if capacity < 1:
            raise ValueError("histogram capacity must be >= 1")
        self.capacity = capacity
        self._buf: List[float] = []
        self._next = 0          # ring write cursor once the buffer fills
        self.count = 0          # all-time observation count
        self.total = 0.0        # all-time sum (running mean)

    def observe(self, value: float) -> None:
        value = float(value)
        self.count += 1
        self.total += value
        if len(self._buf) < self.capacity:
            self._buf.append(value)
        else:
            self._buf[self._next] = value
            self._next = (self._next + 1) % self.capacity

    def percentile(self, p: float) -> Optional[float]:
        if not self._buf:
            return None
        return float(np.percentile(np.asarray(self._buf), p))

    def summary(self) -> Dict[str, float]:
        """count (all-time), window (retained), p50/p99/mean/max over
        the retained window."""
        if not self._buf:
            return {"count": self.count, "window": 0}
        arr = np.asarray(self._buf)
        p50, p99 = np.percentile(arr, (50, 99))
        return {"count": self.count, "window": int(arr.size),
                "p50": round(float(p50), 6), "p99": round(float(p99), 6),
                "mean": round(float(arr.mean()), 6),
                "max": round(float(arr.max()), 6)}


def _prom_name(name: str) -> str:
    return "serve_" + re.sub(r"[^a-zA-Z0-9_]", "_", name)


class MetricsRegistry:
    """Thread-safe metrics store shared by the engine thread (writes)
    and HTTP handler threads (snapshots).

    ``inc`` accumulates a counter; ``set_counter`` mirrors an external
    monotonic counter by absolute value (the engine's lifecycle
    ``Counter``); ``set_gauge``/``set_gauges`` overwrite point-in-time
    values; ``observe`` appends to a named ring histogram."""

    def __init__(self, histogram_window: int = 512):
        self._lock = threading.Lock()
        self._counters: collections.Counter = collections.Counter()
        self._gauges: Dict[str, float] = {}
        self._hists: Dict[str, RingHistogram] = {}
        self._window = histogram_window

    # -- writes --------------------------------------------------------
    def inc(self, name: str, value: float = 1) -> None:
        with self._lock:
            self._counters[name] += value

    def set_counter(self, name: str, value: float) -> None:
        with self._lock:
            self._counters[name] = value

    def set_gauge(self, name: str, value: float) -> None:
        with self._lock:
            self._gauges[name] = value

    def set_gauges(self, values: Dict[str, float]) -> None:
        with self._lock:
            self._gauges.update(values)

    def observe(self, name: str, value: float) -> None:
        with self._lock:
            hist = self._hists.get(name)
            if hist is None:
                hist = self._hists[name] = RingHistogram(self._window)
            hist.observe(value)

    # -- engine hooks --------------------------------------------------
    def on_terminal(self, req: Request) -> None:
        """Terminal-state accounting: one ``requests_<state>`` count per
        request, plus end-to-end latency and steady-state ms/token
        histograms for requests that actually FINISHED. (TTFT is
        observed at first-token emission, not here, so it is live while
        long requests are still streaming.)"""
        self.inc(f"requests_{req.state.value}")
        if req.state is not RequestState.FINISHED:
            return
        if req.latency_s is not None:
            self.observe("e2e_s", req.latency_s)
        if req.num_generated >= 2 and req.first_token_time is not None \
                and req.finish_time is not None:
            self.observe("ms_per_token",
                         (req.finish_time - req.first_token_time)
                         / (req.num_generated - 1) * 1e3)

    # -- reads ---------------------------------------------------------
    def snapshot(self) -> Dict[str, Dict]:
        with self._lock:
            return {
                "counters": dict(self._counters),
                "gauges": dict(self._gauges),
                "histograms": {k: h.summary()
                               for k, h in sorted(self._hists.items())},
            }

    def to_prometheus(self) -> str:
        """Prometheus text exposition format (0.0.4): counters, gauges,
        and histograms as summaries with p50/p99 quantile labels."""
        snap = self.snapshot()
        lines: List[str] = []
        for name, val in sorted(snap["counters"].items()):
            pn = _prom_name(name)
            lines += [f"# TYPE {pn}_total counter",
                      f"{pn}_total {val}"]
        for name, val in sorted(snap["gauges"].items()):
            pn = _prom_name(name)
            lines += [f"# TYPE {pn} gauge", f"{pn} {float(val)}"]
        for name, s in snap["histograms"].items():
            pn = _prom_name(name)
            lines.append(f"# TYPE {pn} summary")
            if s.get("window"):
                lines += [f'{pn}{{quantile="0.5"}} {s["p50"]}',
                          f'{pn}{{quantile="0.99"}} {s["p99"]}']
            lines.append(f"{pn}_count {s['count']}")
        return "\n".join(lines) + "\n"
