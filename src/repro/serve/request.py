"""Request objects flowing through the serving engine."""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional

import jax
import numpy as np

from repro.serve.sampling import SamplingParams


def synthetic_prompts(key, n: int, max_prompt: int, vocab: int):
    """Mixed-length benchmark/CLI traffic: ``n`` random prompts whose
    lengths sweep max_prompt//2 … max_prompt. The one traffic shape the
    serve CLI and the serving benchmark share."""
    lo = max(1, max_prompt // 2)
    lengths = [lo + (i * (max_prompt - lo)) // max(n - 1, 1)
               for i in range(n)]
    toks = jax.random.randint(key, (n, max_prompt), 0, min(vocab, 256))
    return [np.asarray(toks[i, :L]) for i, L in enumerate(lengths)]


@dataclasses.dataclass
class Request:
    """One generation request and its accumulated output.

    ``prompt`` is a 1-D int32 token array; ``sampling`` fixes how the
    continuation is chosen and when it stops. The engine appends to
    ``output_tokens`` as slots step (calling ``on_token(request, tok)``
    per streamed token) and sets ``finished`` / ``finish_reason``
    ('eos' | 'stop' | 'length') when the slot is released."""
    prompt: np.ndarray
    sampling: SamplingParams = dataclasses.field(default_factory=SamplingParams)
    request_id: int = -1
    on_token: Optional[Callable[["Request", int], None]] = None
    output_tokens: List[int] = dataclasses.field(default_factory=list)
    finished: bool = False
    finish_reason: Optional[str] = None

    def __post_init__(self):
        self.prompt = np.asarray(self.prompt, np.int32)
        if self.prompt.ndim != 1:
            raise ValueError(
                f"prompt must be a 1-D token sequence, got shape "
                f"{self.prompt.shape}; submit one Request per sequence "
                f"instead of a batched array")
        if self.prompt.size < 1:
            raise ValueError("empty prompt")

    @property
    def num_generated(self) -> int:
        return len(self.output_tokens)

    def output(self) -> np.ndarray:
        return np.asarray(self.output_tokens, np.int32)
