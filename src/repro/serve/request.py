"""Request objects flowing through the serving engine."""
from __future__ import annotations

import dataclasses
import enum
from typing import Callable, List, Optional

import jax
import numpy as np

from repro.serve.sampling import SamplingParams


class RequestState(enum.Enum):
    """Lifecycle of a request inside the engine.

    ::

        QUEUED ──admit──▶ RUNNING ──▶ FINISHED (eos | stop | length)
          ▲                 │    └──▶ TIMEOUT | ERROR
          └───preempt───────┘
        QUEUED | RUNNING ──cancel──▶ CANCELLED
        submit ──admission policy──▶ REJECTED

    Terminal states (``FINISHED``/``CANCELLED``/``REJECTED``/``TIMEOUT``/
    ``ERROR``) are entered exactly once; ``PREEMPTED`` requests go back
    to the queue and resume bit-identically (the engine republishes
    their prefix and re-prefills only the uncached tail)."""
    QUEUED = "queued"
    RUNNING = "running"
    PREEMPTED = "preempted"
    FINISHED = "finished"
    CANCELLED = "cancelled"
    REJECTED = "rejected"
    TIMEOUT = "timeout"
    ERROR = "error"


#: States a request never leaves once entered.
TERMINAL_STATES = frozenset({
    RequestState.FINISHED, RequestState.CANCELLED, RequestState.REJECTED,
    RequestState.TIMEOUT, RequestState.ERROR,
})


def synthetic_prompts(key, n: int, max_prompt: int, vocab: int):
    """Mixed-length benchmark/CLI traffic: ``n`` random prompts whose
    lengths sweep max_prompt//2 … max_prompt. The one traffic shape the
    serve CLI and the serving benchmark share."""
    lo = max(1, max_prompt // 2)
    lengths = [lo + (i * (max_prompt - lo)) // max(n - 1, 1)
               for i in range(n)]
    toks = jax.random.randint(key, (n, max_prompt), 0, min(vocab, 256))
    return [np.asarray(toks[i, :L]) for i, L in enumerate(lengths)]


@dataclasses.dataclass(eq=False)  # identity eq: requests live in queues
class Request:
    """One generation request and its accumulated output.

    ``prompt`` is a 1-D integer token array; ``sampling`` fixes how the
    continuation is chosen and when it stops. The engine appends to
    ``output_tokens`` as slots step (calling ``on_token(request, tok)``
    per streamed token) and sets ``finished`` / ``finish_reason``
    ('eos' | 'stop' | 'length' | 'timeout' | 'cancelled' | 'rejected'
    | 'error') when the request reaches a terminal ``state``.

    Lifecycle controls: ``priority`` (higher preempts lower under cache
    pressure), ``ttft_deadline_s`` (seconds from submit to FIRST token,
    else finish_reason='timeout'), ``deadline_s`` (seconds from submit
    to completion). ``num_preemptions`` counts pause/resume cycles;
    ``error`` carries the reject/failure reason for REJECTED/ERROR.

    Timing: the engine stamps ``submit_time`` at submit,
    ``first_token_time`` when the first token is emitted, and
    ``finish_time`` at the terminal transition — all through its ONE
    injectable clock (``FaultInjector`` skew moves them too).
    ``ttft_s`` / ``latency_s`` derive the per-request latencies the
    metrics layer aggregates into p50/p99.

    Chunked-prefill progress (token-budget scheduler): ``prefill_pos``
    counts prompt tokens already resident in the cache (cached prefix +
    completed chunks), ``prefill_total`` the admission-token target —
    equal once the request starts decoding. ``enqueue_time`` is the
    latest entry into the admission queue (submit, or requeue after
    preemption) and feeds the queue-wait histogram."""
    prompt: np.ndarray
    sampling: SamplingParams = dataclasses.field(default_factory=SamplingParams)
    request_id: int = -1
    on_token: Optional[Callable[["Request", int], None]] = None
    output_tokens: List[int] = dataclasses.field(default_factory=list)
    finished: bool = False
    finish_reason: Optional[str] = None
    priority: int = 0
    ttft_deadline_s: Optional[float] = None
    deadline_s: Optional[float] = None
    state: RequestState = RequestState.QUEUED
    error: Optional[str] = None
    num_preemptions: int = 0
    submit_time: Optional[float] = None
    first_token_time: Optional[float] = None
    finish_time: Optional[float] = None
    enqueue_time: Optional[float] = None
    prefill_pos: int = 0
    prefill_total: int = 0

    def __post_init__(self):
        arr = np.asarray(self.prompt)
        if not (np.issubdtype(arr.dtype, np.integer)
                or arr.dtype == np.bool_):
            raise ValueError(
                f"prompt must hold integer token ids, got dtype {arr.dtype}; "
                f"refusing to silently truncate to int32")
        self.prompt = arr.astype(np.int32)
        if self.prompt.ndim != 1:
            raise ValueError(
                f"prompt must be a 1-D token sequence, got shape "
                f"{self.prompt.shape}; submit one Request per sequence "
                f"instead of a batched array")
        if self.prompt.size < 1:
            raise ValueError("empty prompt")

    @property
    def num_generated(self) -> int:
        return len(self.output_tokens)

    @property
    def is_terminal(self) -> bool:
        return self.state in TERMINAL_STATES

    @property
    def ttft_s(self) -> Optional[float]:
        """Submit -> first emitted token, in engine-clock seconds
        (None until the first token lands)."""
        if self.submit_time is None or self.first_token_time is None:
            return None
        return self.first_token_time - self.submit_time

    @property
    def latency_s(self) -> Optional[float]:
        """Submit -> terminal state, in engine-clock seconds."""
        if self.submit_time is None or self.finish_time is None:
            return None
        return self.finish_time - self.submit_time

    def output(self) -> np.ndarray:
        return np.asarray(self.output_tokens, np.int32)
