"""Paged latent cache arena: block-table slots over a shared block pool.

The linear ``LatentCacheArena`` gives every slot a private contiguous
``max_len`` strip, so shared prefixes (system prompts, few-shot
templates) are recomputed and stored once per request. Here a slot is a
block TABLE instead: ``max_len // block_size`` entries mapping logical
block index to physical blocks in one flat device pool shaped
``(num_blocks, block_size, …)`` per cache leaf. Admission longest-
prefix-matches the prompt against a radix tree (``prefix_cache``),
shares the matched full blocks (refcount++), copy-on-writes the block
the suffix continues into, allocates fresh blocks for the rest, and
prefills ONLY the uncached suffix. Decode stays one fused dispatch: the
step gathers each slot's table into a contiguous linear view, runs the
unchanged absorbed kernels, and scatters the one newly written row per
slot back through the table — all inside a single jit.

Host/device split: block ids, refcounts, and the radix tree are pure
host bookkeeping (``BlockPool`` / ``RadixPrefixCache``); the pool tree
of latent rows lives on device (sharded like the linear arena via
``serve_cache_specs`` — blocks on the data axes, rank dims local). With
``cfg=None`` the arena runs accounting-only (no device state) — that is
what the property tests drive through thousands of admit/release/evict
sequences.
"""
from __future__ import annotations

from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer as T
from repro.models.cache_layout import PagedCacheLayout
from repro.serve.block_pool import BlockPool
from repro.serve.prefix_cache import RadixPrefixCache


class PagedLatentArena:
    """Slot bookkeeping + block tables + the device block pool.

    ``admit(slot, tokens)`` builds the slot's table (share / copy-on-
    write / fresh) and returns the cached-prefix length the engine skips
    at prefill; ``insert`` publishes the prefilled prompt blocks to the
    radix tree; ``ensure`` extends a table when decode crosses a block
    boundary; ``release`` drops the slot's references (tree-cached
    blocks survive for future hits)."""

    def __init__(self, cfg, num_slots: int, max_len: int,
                 block_size: int = 16, num_blocks: Optional[int] = None,
                 mesh=None):
        if num_slots < 1 or max_len < 2:
            raise ValueError("need num_slots >= 1 and max_len >= 2")
        if max_len % block_size != 0:
            raise ValueError(
                f"max_len ({max_len}) must be a multiple of block_size "
                f"({block_size}): the gathered decode view must tile "
                f"exactly into blocks")
        blocks_per_slot = max_len // block_size
        if num_blocks is None:
            # 2x the slots' worst-case demand: slots can hold at most
            # num_slots * blocks_per_slot references, so free + evictable
            # (tree-only) blocks always cover a full admission — the
            # RuntimeError in ensure() is unreachable at this sizing
            num_blocks = 2 * num_slots * blocks_per_slot
        self.cfg, self.num_slots, self.max_len = cfg, num_slots, max_len
        self.block_size, self.num_blocks = block_size, num_blocks
        self.mesh = mesh
        self.layout = PagedCacheLayout(max_len, block_size, num_blocks)
        self.pool = BlockPool(num_blocks, block_size)
        self.prefix = RadixPrefixCache(self.pool)
        # block id num_blocks = the unallocated-entry sentinel
        self.tables = np.full((num_slots, blocks_per_slot), num_blocks,
                              np.int32)
        self._free: List[int] = list(range(num_slots - 1, -1, -1))
        self._free_set = set(self._free)

        if cfg is None:  # accounting-only mode (property tests)
            self.layouts = None
            self.pool_cache = None
            self.shardings = None
            return
        self.layouts = T.cache_layouts(cfg, max_len)
        if any(l is not None and l.is_ring
               for l in self.layouts[0] + self.layouts[1]):
            raise ValueError(
                "paged arena serves full-attention layers only: a "
                "sliding-window ring wraps per slot and cannot share "
                "position-aligned blocks across requests")
        pool_cache = T.init_cache(cfg, num_blocks, block_size)
        pool_cache.pop("pos")  # positions are per-slot, not per-block
        donate = (0,) if jax.default_backend() != "cpu" else ()
        if mesh is not None:
            from repro.distributed import sharding as shd
            specs = shd.serve_cache_specs(
                mesh, jax.eval_shape(lambda: pool_cache))
            self.shardings = shd.to_named(mesh, specs)
            pool_cache = jax.device_put(pool_cache, self.shardings)
            self._copy_fn = jax.jit(
                self._copy, donate_argnums=donate,
                in_shardings=(self.shardings, None, None),
                out_shardings=self.shardings)
        else:
            self.shardings = None
            self._copy_fn = jax.jit(self._copy, donate_argnums=donate)
        self.pool_cache = pool_cache

    # -- slot recycling ------------------------------------------------
    @property
    def num_free(self) -> int:
        return len(self._free)

    def acquire(self) -> Optional[int]:
        if not self._free:
            return None
        slot = self._free.pop()
        self._free_set.discard(slot)
        return slot

    def release(self, slot: int) -> None:
        """Free the slot and drop its block references. Blocks the radix
        tree also holds stay resident (refcount 1, evictable) — that is
        the cache surviving the request."""
        if not 0 <= slot < self.num_slots:
            raise ValueError(
                f"slot {slot} out of range [0, {self.num_slots})")
        if slot in self._free_set:
            raise ValueError(f"double release of slot {slot}")
        for b in self.tables[slot]:
            if b != self.num_blocks:
                self.pool.decref(int(b))
        self.tables[slot] = self.num_blocks
        self._free.append(slot)
        self._free_set.add(slot)

    # -- admission -----------------------------------------------------
    def admit(self, slot: int, tokens) -> Optional[int]:
        """Build ``slot``'s block table for a prompt.

        Longest-prefix-match against the radix tree; share matched FULL
        blocks, copy-on-write the block the suffix continues into (its
        remaining rows belong to other holders), allocate fresh blocks
        for the rest — evicting LRU tree chains when the free list runs
        short. Returns the number of cached prefix tokens (the prefill
        resumes there), capped at len - 1 so the last prompt token is
        always recomputed (its logits seed the first sampled token). On
        None the pool cannot cover the prompt even after eviction; the
        caller keeps the request queued (the table is untouched)."""
        L = len(tokens)
        bs = self.block_size
        n_used = -(-L // bs)
        matched, chain = self.prefix.match(tokens)
        matched = min(matched, L - 1)
        n_share = matched // bs
        cow = matched % bs != 0
        need = n_used - n_share
        # protect the chain before any eviction runs: shared blocks and
        # the copy-on-write SOURCE must not be LRU victims mid-admission
        held = chain[:n_share]
        for b in held:
            self.pool.incref(b)
        src = None
        if cow:
            src = chain[n_share]
            self.pool.incref(src)
        if self.pool.num_free < need:
            self.prefix.evict(need - self.pool.num_free)
        if self.pool.num_free < need:
            for b in held:
                self.pool.decref(b)
            if src is not None:
                self.pool.decref(src)
            return None
        table = self.tables[slot]
        table[:n_share] = held
        fresh = [self.pool.alloc() for _ in range(need)]
        start = n_share
        if cow:
            table[start] = fresh[0]
            self._run_copy([src], [fresh[0]])
            self.pool.decref(src)
            fresh = fresh[1:]
            start += 1
        table[start:n_used] = fresh
        return matched

    def insert(self, slot: int, tokens) -> int:
        """Publish a prefilled prompt to the radix tree (tree takes its
        own references). Call once per request, after its prefill."""
        n_used = -(-len(tokens) // self.block_size)
        blocks = [int(b) for b in self.tables[slot, :n_used]]
        return self.prefix.insert(tokens, blocks)

    def try_ensure(self, slot: int, pos: int) -> bool:
        """Make sure the block holding row ``pos`` is allocated — decode
        calls this before each step (the step writes at ``pos``).
        Returns False when the pool is exhausted even after evicting
        tree-only chains, so the engine can preempt a victim and retry
        instead of dying mid-traffic."""
        b = pos // self.block_size
        if self.tables[slot, b] != self.num_blocks:
            return True
        if self.pool.num_free == 0:
            self.prefix.evict(1)
        blk = self.pool.alloc()
        if blk is None:
            return False
        self.tables[slot, b] = blk
        return True

    def ensure(self, slot: int, pos: int) -> None:
        """Raising wrapper around ``try_ensure`` for callers with no
        preemption path (property-test driver, direct arena users)."""
        if not self.try_ensure(slot, pos):
            raise RuntimeError(
                f"block pool exhausted mid-decode (num_blocks="
                f"{self.num_blocks}): size the pool at >= 2 * num_slots "
                f"* (max_len // block_size) blocks")

    # -- device copy (copy-on-write) ------------------------------------
    def _run_copy(self, src: List[int], dst: List[int]) -> None:
        """Copy pool blocks src[i] -> dst[i] on device. The count is
        bucketed to powers of two (padding pairs scatter out of bounds)
        so admission churn never compiles a new copy shape."""
        if self.pool_cache is None:  # accounting-only mode
            return
        nb = 1
        while nb < len(src):
            nb <<= 1
        s = np.zeros((nb,), np.int32)
        d = np.full((nb,), self.num_blocks, np.int32)  # OOB: dropped
        s[:len(src)], d[:len(dst)] = src, dst
        self.pool_cache = self._copy_fn(self.pool_cache, jnp.asarray(s),
                                        jnp.asarray(d))

    @staticmethod
    def _copy(pool, src, dst):
        def rows(a):  # trailing leaves: block axis 0
            return a.at[dst].set(a[src], mode="drop")

        def stacked(a):  # (n_layers, num_blocks, …) group-stacked leaves
            return a.at[:, dst].set(a[:, src], mode="drop")

        return {"groups": [jax.tree.map(stacked, g) for g in pool["groups"]],
                "trailing": [jax.tree.map(rows, t) for t in pool["trailing"]]}

    # -- accounting ----------------------------------------------------
    @property
    def blocks_in_use(self) -> int:
        return self.pool.blocks_in_use

    def pool_bytes(self) -> int:
        total = 0
        for leaf in jax.tree.leaves(self.pool_cache):
            total += int(leaf.size) * leaf.dtype.itemsize
        return total

    def slot_bytes(self) -> int:
        """Bytes of one slot's worth of blocks (blocks_per_slot out of
        the pool) — the per-request footprint a full table pins, same
        base as the linear arena's per-slot strip."""
        return self.pool_bytes() * self.layout.blocks_per_slot \
            // self.num_blocks
