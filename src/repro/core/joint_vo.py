"""Value/Output compression (paper §4.2, App. G).

Two modes:
  - split (paper default; Remark 11 finds joint VO not better): V heads are
    compressed JOINTLY-OVER-HEADS (shared A_v, per-head B_v — the MLA
    structure) by activation-aware SVD; W_o is compressed locally with the
    attention-aware output covariance C_o = W_v C W_vᵀ (App. G.2).
  - joint: the HOSVD of G_i = W_{o,i} W_{v,i} C^{1/2} (Eqs. 185–188).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import jax.numpy as jnp

from repro.core.precond import psd_pinv, psd_sqrt
from repro.core.svd import LowRank, weighted_svd


@dataclasses.dataclass
class JointVO:
    A_v: jnp.ndarray                     # (r_v, d)
    B_v: jnp.ndarray                     # (Hk, d_h, r_v)
    A_o: jnp.ndarray                     # (r_o, Hq*d_h)   (W_o ≈ B_o · A_o)
    B_o: jnp.ndarray                     # (d, r_o)
    b_v: Optional[jnp.ndarray] = None
    b_o: Optional[jnp.ndarray] = None
    losses: Optional[List[float]] = None


def split_vo(Wv: jnp.ndarray, Wo: jnp.ndarray, P: jnp.ndarray,
             r_v: int, r_o: int, C: Optional[jnp.ndarray] = None,
             bv: Optional[jnp.ndarray] = None,
             bo: Optional[jnp.ndarray] = None,
             mu: Optional[jnp.ndarray] = None,
             P_pinv: Optional[jnp.ndarray] = None) -> JointVO:
    """Wv: (Hk, d_h, d); Wo: (d, Hq*d_h). Paper-default split compression."""
    Hk, dh, d = Wv.shape
    d_out, hd = Wo.shape
    Wv32 = Wv.astype(jnp.float32)
    Wo32 = Wo.astype(jnp.float32)

    # ----- V: joint-over-heads activation-aware SVD (shared A_v) -----
    Wv_flat = Wv32.reshape(Hk * dh, d)
    lrv = weighted_svd(Wv_flat, P, r_v, junction="left", P_pinv=P_pinv)
    A_v = lrv.A
    B_v = lrv.B.reshape(Hk, dh, r_v)

    # ----- O: local ASVD under attention-aware covariance C_o ----------
    # the o-projection consumes attention-weighted V outputs; App. G.2:
    # C_o,i ≈ W_v,i C W_v,iᵀ (uncorrelated-token assumption). With GQA the
    # query heads in group i share the kv head's statistics.
    if C is None:
        C = P @ P
    rep = hd // (Hk * dh)
    Cv = jnp.einsum("hqd,de,hpe->hqp", Wv32, C, Wv32)  # (Hk, dh, dh)
    # block-diagonal over Hq heads (repeat kv groups)
    blocks = [Cv[i // rep] for i in range(hd // dh)]
    P_o_blocks = [psd_sqrt(b) for b in blocks]
    P_o = jnp.zeros((hd, hd), jnp.float32)
    for i, pb in enumerate(P_o_blocks):
        P_o = P_o.at[i * dh:(i + 1) * dh, i * dh:(i + 1) * dh].set(pb)
    # factor Wo (d, hd) ≈ B_o A_o with A_o (r_o, hd), B_o (d, r_o), under
    # the block-diagonal head-space preconditioner P_o (hd, hd)
    lro = weighted_svd(Wo32, P_o, r_o, junction="left")
    B_o, A_o = lro.B, lro.A

    new_bo = None
    if bo is not None or bv is not None:
        # b_v is absorbed into b_o (App. G.1: b̂_v has no impact); the
        # o-bias update keeps the mean output exact
        new_bo = bo.astype(jnp.float32) if bo is not None else jnp.zeros((d_out,))
    return JointVO(A_v=A_v, B_v=B_v, A_o=A_o, B_o=B_o,
                   b_v=bv, b_o=new_bo)


def joint_vo_hosvd(Wv: jnp.ndarray, Wo: jnp.ndarray, P: jnp.ndarray,
                   r_v: int, r_o: int, iters: int = 4,
                   P_pinv: Optional[jnp.ndarray] = None) -> JointVO:
    """App. G Eqs. 185–188: alternating HOSVD on G_i = W_o,i W_v,i C^{1/2}."""
    Hk, dh, d = Wv.shape
    d_out, hd = Wo.shape
    Hq = hd // dh
    rep = Hq // Hk
    Wv32 = Wv.astype(jnp.float32)
    Wo_heads = Wo.astype(jnp.float32).reshape(d_out, Hq, dh).transpose(1, 0, 2)
    if P_pinv is None:
        P_pinv = psd_pinv(P)

    # G_i = W_o,i W_v,{g(i)} P : (Hq, d_out, d)
    kv = jnp.arange(Hq) // rep
    WvP = jnp.einsum("hqd,de->hqe", Wv32, P)
    G = jnp.einsum("hoq,hqd->hod", Wo_heads, WvP[kv])

    def top_eig(M, r):
        w, V = jnp.linalg.eigh(M)
        return V[:, -r:].T[::-1]

    Av = top_eig(jnp.einsum("hod,hoe->de", G, G), r_v)  # init (r_v, d)
    losses = []
    Bo = None
    for _ in range(iters):
        GA = jnp.einsum("hod,rd->hor", G, Av)
        Bo = top_eig(jnp.einsum("hor,hpr->op", GA, GA), r_o).T  # (d_out, r_o)
        GB = jnp.einsum("hod,or->hrd", G, Bo)
        Av = top_eig(jnp.einsum("hrd,hre->de", GB, GB), r_v)
        H = jnp.einsum("or,hod,vd->hrv", Bo, G, Av)
        losses.append(float(jnp.sum(G * G) - jnp.sum(H * H)))

    A_o = jnp.einsum("or,hoq->rhq", Bo, Wo_heads).reshape(r_o, hd)
    B_v = jnp.einsum("hqd,rd->hqr", WvP, Av)
    A_v = Av @ P_pinv
    return JointVO(A_v=A_v, B_v=B_v, A_o=A_o, B_o=Bo, losses=losses)


def vo_output_loss(Wv, Wo, vo: JointVO, X: jnp.ndarray) -> float:
    """Σᵢ‖W_o,i W_v,i X − Ŵ_o,i Ŵ_v,i X‖² (Eq. 15) on held-out X."""
    Hk, dh, d = Wv.shape
    d_out, hd = Wo.shape
    Hq = hd // dh
    rep = Hq // Hk
    X = X.astype(jnp.float32)
    total = 0.0
    cv = vo.A_v @ X
    for i in range(Hq):
        g = i // rep
        Woi = Wo[:, i * dh:(i + 1) * dh].astype(jnp.float32)
        ref = Woi @ (Wv[g].astype(jnp.float32) @ X)
        vh = vo.B_v[g] @ cv
        # Ŵ_o,i = B_o A_o[:, i-block]
        Aoi = vo.A_o[:, i * dh:(i + 1) * dh]
        approx = vo.B_o @ (Aoi @ vh)
        total += float(jnp.sum((ref - approx) ** 2))
    return total
