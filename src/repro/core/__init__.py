"""LatentLLM core: attention-aware joint tensor compression (the paper)."""
from repro.core.compress import (METHODS, CompressionMethod, CompressionPlan,
                                 Compressor, PlanRule, StreamingStats,
                                 available_methods, compress_model,
                                 get_method, register_method,
                                 register_module_compressor)
from repro.core.joint_qk import JointQK, attention_map_loss, joint_qk_svd
from repro.core.joint_vo import JointVO, joint_vo_hosvd, split_vo, vo_output_loss
from repro.core.mlp_ud import JointUD, joint_ud, local_ud, mlp_output_loss
from repro.core.precond import (KINDS, activation_stats, preconditioner,
                                psd_inv_sqrt, psd_pinv, psd_sqrt)
from repro.core.ranks import latent_ranks, rank_for_reduction
from repro.core.svd import JUNCTIONS, LowRank, activation_loss, weighted_svd

__all__ = [
    "METHODS", "compress_model", "Compressor", "CompressionPlan", "PlanRule",
    "CompressionMethod", "StreamingStats", "available_methods", "get_method",
    "register_method", "register_module_compressor", "JointQK",
    "attention_map_loss", "joint_qk_svd", "JointVO", "joint_vo_hosvd",
    "split_vo", "vo_output_loss", "JointUD", "joint_ud", "local_ud",
    "mlp_output_loss", "KINDS", "activation_stats", "preconditioner",
    "psd_inv_sqrt", "psd_pinv", "psd_sqrt", "latent_ranks",
    "rank_for_reduction", "JUNCTIONS", "LowRank", "activation_loss",
    "weighted_svd",
]
