"""Latent rank selection from a target size-reduction ratio.

With the paper's block-identity junction (§3.3), a d'×d weight compressed
to rank r costs ``r(d+d') − r²`` params. Given target reduction ``c``
(params' = (1−c)·d·d'), solve the quadratic for r:

    r² − r(d+d') + (1−c)·d·d' = 0
    r = ((d+d') − sqrt((d+d')² − 4(1−c)dd')) / 2

Without block-identity the linear relation r = (1−c)dd'/(d+d') applies.
"""
from __future__ import annotations

import math
from typing import Dict

from repro.configs.base import ModelConfig


def rank_for_reduction(d_in: int, d_out: int, compression: float,
                       block_identity: bool = True) -> int:
    target = (1.0 - compression) * d_in * d_out
    s = d_in + d_out
    if block_identity:
        disc = s * s - 4.0 * target
        if disc <= 0:  # cannot hit target even at r = s/2; use max saving point
            r = s // 2
        else:
            r = (s - math.sqrt(disc)) / 2.0
    else:
        r = target / s
    r = int(max(8, min(min(d_in, d_out) - 1, round(r))))
    # MXU alignment: multiples of 8 keep lanes happy without losing ratio
    return max(8, (r // 8) * 8)


def latent_ranks(cfg: ModelConfig) -> Dict[str, int]:
    """Per-module latent ranks for a model config at cfg.latent.compression."""
    c = cfg.latent.compression
    bi = cfg.latent.junction == "block_identity"
    d = cfg.d_model
    ranks = {}
    if cfg.num_heads:
        ranks["r_q"] = rank_for_reduction(d, cfg.q_dim, c, bi)
        ranks["r_k"] = rank_for_reduction(d, cfg.kv_dim, c, bi)
        ranks["r_v"] = rank_for_reduction(d, cfg.kv_dim, c, bi)
        ranks["r_o"] = rank_for_reduction(cfg.q_dim, d, c, bi)
        # joint QK must keep rank >= head_dim or heads go redundant (App. E)
        ranks["r_q"] = max(ranks["r_q"], cfg.head_dim)
        ranks["r_k"] = max(ranks["r_k"], cfg.head_dim)
        ranks["r_v"] = max(ranks["r_v"], cfg.head_dim)
    if cfg.d_ff:
        ranks["r_u"] = rank_for_reduction(d, cfg.d_ff, c, bi)
        ranks["r_d"] = rank_for_reduction(cfg.d_ff, d, c, bi)
    if cfg.has_ssm:
        di = cfg.d_inner
        proj_out = 2 * di + 2 * cfg.ssm_ngroups * cfg.ssm_state + cfg.ssm_nheads
        ranks["r_in"] = rank_for_reduction(d, proj_out, c, bi)
        ranks["r_out"] = rank_for_reduction(di, d, c, bi)
    return ranks
