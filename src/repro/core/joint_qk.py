"""Attention-aware joint QK compression (paper §4.1, Alg. 1, App. E).

Minimizes the ATTENTION-MAP error Σᵢ‖Mᵢ−M̂ᵢ‖² (not per-matrix activation
error) over all heads jointly. With Gᵢ = C^{1/2}W_{q,i}ᵀW_{k,i}C^{1/2}
this is a 3-mode Tucker decomposition: shared planes A_q, A_k, per-head
cores Hᵢ = A_q Gᵢ A_kᵀ — solved by alternating symmetric
eigendecompositions (HOSVD-ALS). This is the paper's principled MHA→MLA
conversion; GQA (App. E.3) and QKV biases (App. E.2) are handled.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.precond import psd_pinv, psd_sqrt


@dataclasses.dataclass
class JointQK:
    """Ŵ_q,i = B_q,i A_q ; Ŵ_k,i = B_k,i A_k (shared A, per-head B)."""

    A_q: jnp.ndarray          # (r_q, d)
    A_k: jnp.ndarray          # (r_k, d)
    B_q: jnp.ndarray          # (Hq, d_h, r_q)
    B_k: jnp.ndarray          # (Hk, d_h, r_k)
    b_q: Optional[jnp.ndarray] = None  # (Hq, d_h) updated biases
    b_k: Optional[jnp.ndarray] = None  # (Hk, d_h)
    losses: Optional[List[float]] = None  # per-iteration HOSVD loss


def _top_eigvecs(M: jnp.ndarray, r: int) -> jnp.ndarray:
    """Top-r eigenvectors of symmetric PSD M, as rows (r, d)."""
    w, V = jnp.linalg.eigh(M)  # ascending
    return V[:, -r:].T[::-1]


def _rope_rotation(dh: int, offset: int, theta: float) -> jnp.ndarray:
    """Θ_{n−m}: block-diagonal 2×2 rotation for token offset (App. F.3)."""
    freqs = theta ** (-jnp.arange(0, dh, 2, dtype=jnp.float32) / dh)
    ang = offset * freqs
    c, s = jnp.cos(ang), jnp.sin(ang)
    R = jnp.zeros((dh, dh), jnp.float32)
    idx = jnp.arange(dh // 2)
    R = R.at[2 * idx, 2 * idx].set(c)
    R = R.at[2 * idx + 1, 2 * idx + 1].set(c)
    R = R.at[2 * idx, 2 * idx + 1].set(-s)
    R = R.at[2 * idx + 1, 2 * idx].set(s)
    return R


def joint_qk_svd(
    Wq: jnp.ndarray,          # (Hq, d_h, d) query heads
    Wk: jnp.ndarray,          # (Hk, d_h, d) key heads (Hk | Hq, GQA)
    P: jnp.ndarray,           # (d, d) preconditioner (C^{1/2} optimal)
    r_q: int,
    r_k: int,
    iters: int = 8,
    bq: Optional[jnp.ndarray] = None,   # (Hq, d_h) original biases
    bk: Optional[jnp.ndarray] = None,
    mu: Optional[jnp.ndarray] = None,   # (d,) activation mean (bias path)
    C0: Optional[jnp.ndarray] = None,   # centered covariance (bias path)
    P_pinv: Optional[jnp.ndarray] = None,
    rope_window: int = 0,               # App. F.3: average the loss over
    rope_theta: float = 1e4,            # Θ_{n−m}, |n−m| <= window
) -> JointQK:
    Hq, dh, d = Wq.shape
    Hk = Wk.shape[0]
    rep = Hq // Hk
    Wq32 = Wq.astype(jnp.float32)
    Wk32 = Wk.astype(jnp.float32)
    if P_pinv is None:
        P_pinv = psd_pinv(P)

    if rope_window:
        # RoPE-aware objective (App. F.3 / Fig. 12): sum the attention-map
        # loss over token offsets, i.e. replace each query head W_q,i by
        # the family {Θ_{o}ᵀ W_q,i : |o| <= window}. Equivalent to
        # stacking rotated copies of the query heads (the key side keeps
        # one copy since Θ_mᵀΘ_n = Θ_{n−m} folds onto the query).
        assert bq is None and bk is None, "rope_window + biases unsupported"
        rots = [_rope_rotation(dh, o, rope_theta)
                for o in range(rope_window + 1)]
        Wq32 = jnp.concatenate(
            [jnp.einsum("pq,hqd->hpd", R.T, Wq32) for R in rots], axis=0)
        # re-pair: rotated copy c of q-head i pairs with kv head i//rep
        Hq_eff = Wq32.shape[0]
    else:
        Hq_eff = Hq

    # whitened heads; GQA pairs query head (i,j) with kv head i (App. E.3)
    Wqp = jnp.einsum("hqd,de->hqe", Wq32, P)   # (Hq_eff, dh, d)
    Wkp = jnp.einsum("hqd,de->hqe", Wk32, P)

    # G_{i} for each q-head: G = Wq'ᵀ Wk'(paired)  (Hq, d, d) — formed
    # lazily inside the contractions to avoid Hq·d² memory when d large.
    kv_index = (jnp.arange(Hq_eff) % Hq) // rep

    def sum_GGt(Ak: Optional[jnp.ndarray]) -> jnp.ndarray:
        """Σᵢ Gᵢ Mₖ Gᵢᵀ with Mₖ = AkᵀAk (or I)."""
        # Gᵢ = Wq'ᵢᵀ Wk'_{g(i)} ; Gᵢ Mₖ Gᵢᵀ = Wq'ᵢᵀ (Wk' Mₖ Wk'ᵀ) Wq'ᵢ
        Wk_sel = Wkp[kv_index]  # (Hq, dh, d)
        if Ak is None:
            inner = jnp.einsum("hqd,hpd->hqp", Wk_sel, Wk_sel)
        else:
            WkA = jnp.einsum("hqd,rd->hqr", Wk_sel, Ak)
            inner = jnp.einsum("hqr,hpr->hqp", WkA, WkA)
        return jnp.einsum("hqd,hqp,hpe->de", Wqp, inner, Wqp)

    def sum_GtG(Aq: Optional[jnp.ndarray]) -> jnp.ndarray:
        Wk_sel = Wkp[kv_index]
        if Aq is None:
            inner = jnp.einsum("hqd,hpd->hqp", Wqp, Wqp)
        else:
            WqA = jnp.einsum("hqd,rd->hqr", Wqp, Aq)
            inner = jnp.einsum("hqr,hpr->hqp", WqA, WqA)
        return jnp.einsum("hqd,hqp,hpe->de", Wk_sel, inner, Wk_sel)

    def bias_terms():
        """Rank-1 additions from biases (App. E.2, Eqs. 140/142)."""
        if bq is None and bk is None:
            return 0.0, 0.0
        bq_ = jnp.zeros((Hq, dh)) if bq is None else bq.astype(jnp.float32)
        bk_ = jnp.zeros((Hk, dh)) if bk is None else bk.astype(jnp.float32)
        mu_ = jnp.zeros((d,)) if mu is None else mu.astype(jnp.float32)
        # uk_i = W_k,i μ + b_k,i  (per q-head via pairing)
        uk = jnp.einsum("hqd,d->hq", Wk32[kv_index], mu_) + bk_[kv_index]
        uq = jnp.einsum("hqd,d->hq", Wq32, mu_) + bq_
        # Σ C½ Wqᵀ uk ukᵀ Wq C½ and symmetric partner
        Wq_uk = jnp.einsum("hqd,hq->hd", Wqp, uk)   # rows already whitened
        Wk_uq = jnp.einsum("hqd,hq->hd", Wkp[kv_index], uq)
        q_term = jnp.einsum("hd,he->de", Wq_uk, Wq_uk)
        k_term = jnp.einsum("hd,he->de", Wk_uq, Wk_uq)
        return q_term, k_term

    q_bias_term, k_bias_term = bias_terms()

    total = None
    losses: List[float] = []
    Aq = _top_eigvecs(sum_GGt(None) + q_bias_term, r_q)
    Ak = None
    for _ in range(iters):
        Ak = _top_eigvecs(sum_GtG(Aq) + k_bias_term, r_k)
        Aq = _top_eigvecs(sum_GGt(Ak) + q_bias_term, r_q)
        losses.append(float(hosvd_loss(Wqp, Wkp, kv_index, Aq, Ak)))

    # decompression per head: B = (whitened W) Aᵀ  (J_i = I, Eq. 79/80).
    # With rope_window the planes were fit over rotated copies; the
    # decompression uses the offset-0 (unrotated) heads.
    B_q = jnp.einsum("hqd,rd->hqr", Wqp[:Hq], Aq)     # (Hq, dh, r_q)
    B_k = jnp.einsum("hqd,rd->hqr", Wkp, Ak)          # (Hk, dh, r_k)
    # unwhitened shared compression planes
    A_q = Aq @ P_pinv
    A_k = Ak @ P_pinv

    new_bq = new_bk = None
    if bq is not None or bk is not None:
        # Eq. (121)/(122) with J = I and C₀-orthonormal planes
        C0_ = C0 if C0 is not None else P @ P  # P = C₀^{1/2}
        mu_ = jnp.zeros((d,)) if mu is None else mu.astype(jnp.float32)
        bq_ = jnp.zeros((Hq, dh)) if bq is None else bq.astype(jnp.float32)
        bk_ = jnp.zeros((Hk, dh)) if bk is None else bk.astype(jnp.float32)
        proj_q = C0_ @ A_q.T @ A_q @ mu_
        proj_k = C0_ @ A_k.T @ A_k @ mu_
        new_bq = bq_ + jnp.einsum("hqd,d->hq", Wq32, mu_ - proj_q)
        new_bk = bk_ + jnp.einsum("hqd,d->hq", Wk32, mu_ - proj_k)

    return JointQK(A_q=A_q, A_k=A_k, B_q=B_q, B_k=B_k,
                   b_q=new_bq, b_k=new_bk, losses=losses)


def hosvd_loss(Wqp, Wkp, kv_index, Aq, Ak) -> jnp.ndarray:
    """L = Σᵢ ‖Gᵢ‖² − ‖Aq Gᵢ Akᵀ‖² (Eq. 68), without materializing Gᵢ."""
    Wk_sel = Wkp[kv_index]
    # ‖G‖² = tr(Wq'Wq'ᵀ · Wk'Wk'ᵀ) per head
    qq = jnp.einsum("hqd,hpd->hqp", Wqp, Wqp)
    kk = jnp.einsum("hqd,hpd->hqp", Wk_sel, Wk_sel)
    norm_G = jnp.einsum("hqp,hqp->", qq, kk)
    # Hᵢ = Aq Gᵢ Akᵀ = (Wq'Aqᵀ)ᵀ (Wk'Akᵀ)
    WqA = jnp.einsum("hqd,rd->hqr", Wqp, Aq)
    WkA = jnp.einsum("hqd,rd->hqr", Wk_sel, Ak)
    H = jnp.einsum("hqr,hqs->hrs", WqA, WkA)
    return norm_G - jnp.sum(H * H)


def attention_map_loss(Wq, Wk, jqk: JointQK, X: jnp.ndarray,
                       bq=None, bk=None) -> float:
    """Direct Σᵢ‖Mᵢ−M̂ᵢ‖² on held-out activations X (d, l) — the quantity
    the method optimizes; used by tests/benchmarks as the oracle."""
    Hq, dh, d = Wq.shape
    Hk = Wk.shape[0]
    rep = Hq // Hk
    X = X.astype(jnp.float32)
    total = 0.0
    cq = jqk.A_q @ X
    ck = jqk.A_k @ X
    for i in range(Hq):
        g = i // rep
        q = Wq[i].astype(jnp.float32) @ X
        k = Wk[g].astype(jnp.float32) @ X
        if bq is not None:
            q = q + bq[i][:, None]
            k = k + bk[g][:, None]
        M = q.T @ k
        qh = jqk.B_q[i] @ cq
        kh = jqk.B_k[g] @ ck
        if jqk.b_q is not None:
            qh = qh + jqk.b_q[i][:, None]
            kh = kh + jqk.b_k[g][:, None]
        Mh = qh.T @ kh
        total += float(jnp.sum((M - Mh) ** 2))
    return total
