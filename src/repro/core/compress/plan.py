"""Per-layer / per-module compression plans.

A :class:`CompressionPlan` replaces the seed's single ``method`` string +
uniform ``cfg.latent.compression`` with a declarative policy: a default
method and ratio, plus an ordered list of :class:`PlanRule` overrides
matched against (block index, module kind). Later rules win, so plans
read top-down like a config file::

    plan = CompressionPlan(
        method="latentllm", compression=0.2,
        rules=(
            PlanRule(blocks="1:-1", compression=0.4),      # middle: harder
            PlanRule(blocks=-1, module="mlp",
                     method="asvd_rootcov", ranks={"r_d": 48}),
        ))

Block specs: ``None`` (all), an int (negative = from the end), a
``"first:k"`` / ``"last:k"`` / ``"a:b"`` slice string, or a tuple of any
of these.

Because the transformer scans STACKED group params (one compiled body
for all layers) and the latent KV cache is sized from
``latent_ranks(cfg)``, per-layer rank overrides may only *reduce* ranks
below the config-uniform ones; the driver zero-pads the factors back to
the uniform shapes (numerically exact — padded rows/cols are zero).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Mapping, Optional, Tuple, Union

from repro.configs.base import ModelConfig
from repro.core import ranks as ranks_lib
from repro.core.compress.registry import CompressionMethod, get_method

BlockSpec = Union[None, int, str, Tuple[Any, ...]]

__all__ = ["PlanRule", "CompressionPlan", "ResolvedModulePlan"]


def _match_blocks(spec: BlockSpec, idx: int, n_blocks: int) -> bool:
    if spec is None:
        return True
    if isinstance(spec, (tuple, list)):
        return any(_match_blocks(s, idx, n_blocks) for s in spec)
    if isinstance(spec, int):
        return (spec + n_blocks if spec < 0 else spec) == idx
    if isinstance(spec, str):
        if spec.startswith("first:"):
            return idx < int(spec.split(":", 1)[1])
        if spec.startswith("last:"):
            return idx >= n_blocks - int(spec.split(":", 1)[1])
        if ":" in spec:
            a_s, b_s = spec.split(":", 1)
            a = int(a_s) if a_s else 0
            b = int(b_s) if b_s else n_blocks
            a = a + n_blocks if a < 0 else a
            b = b + n_blocks if b < 0 else b
            return a <= idx < b
        return _match_blocks(int(spec), idx, n_blocks)
    raise TypeError(f"bad block spec {spec!r}")


@dataclasses.dataclass(frozen=True)
class PlanRule:
    """Override (method / compression / explicit ranks) for matching sites."""

    blocks: BlockSpec = None          # None = every block
    module: Optional[str] = None      # attention | mlp | ssd | moe | None=all
    method: Optional[str] = None
    compression: Optional[float] = None
    ranks: Optional[Mapping[str, int]] = None   # e.g. {"r_q": 32}

    def matches(self, block_idx: int, n_blocks: int, module: str) -> bool:
        if self.module is not None and self.module != module:
            return False
        return _match_blocks(self.blocks, block_idx, n_blocks)

    def to_dict(self) -> Dict[str, Any]:
        return {
            "blocks": list(self.blocks) if isinstance(self.blocks, tuple)
            else self.blocks,
            "module": self.module,
            "method": self.method,
            "compression": self.compression,
            "ranks": dict(self.ranks) if self.ranks is not None else None,
        }

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "PlanRule":
        blocks = d.get("blocks")
        if isinstance(blocks, list):
            blocks = tuple(blocks)
        return cls(blocks=blocks, module=d.get("module"),
                   method=d.get("method"), compression=d.get("compression"),
                   ranks=dict(d["ranks"]) if d.get("ranks") else None)


@dataclasses.dataclass(frozen=True)
class ResolvedModulePlan:
    """The plan's verdict for one (block, module) site."""

    block: int
    module: str
    method: CompressionMethod
    compression: float
    ranks: Dict[str, int]


@dataclasses.dataclass(frozen=True)
class CompressionPlan:
    """Default method/ratio plus ordered per-site override rules."""

    method: str = "latentllm"
    compression: Optional[float] = None   # None -> cfg.latent.compression
    rules: Tuple[PlanRule, ...] = ()

    # -- construction ------------------------------------------------------
    @classmethod
    def from_config(cls, cfg: ModelConfig,
                    method: Optional[str] = None) -> "CompressionPlan":
        return cls(method=method or cfg.latent.method,
                   compression=cfg.latent.compression)

    @classmethod
    def spare_ends(cls, method: str = "latentllm",
                   compression: float = 0.2, spare: int = 1,
                   middle_compression: Optional[float] = None
                   ) -> "CompressionPlan":
        """Non-uniform schedule: first/last ``spare`` blocks stay at the
        (lighter) base ratio; the middle is compressed harder. The model's
        ``cfg.latent.compression`` should equal the base ratio, which sizes
        the stacked params and latent cache."""
        if middle_compression is None:
            middle_compression = min(0.9, compression * 1.5)
        return cls(method=method, compression=compression,
                   rules=(PlanRule(blocks=f"{spare}:{-spare}",
                                   compression=middle_compression),))

    # -- resolution --------------------------------------------------------
    def resolve(self, cfg: ModelConfig, block_idx: int, n_blocks: int,
                module: str) -> ResolvedModulePlan:
        method_name = self.method
        comp = (self.compression if self.compression is not None
                else cfg.latent.compression)
        rank_over: Dict[str, int] = {}
        for rule in self.rules:
            if not rule.matches(block_idx, n_blocks, module):
                continue
            if rule.method is not None:
                method_name = rule.method
            if rule.compression is not None:
                comp = rule.compression
            if rule.ranks:
                rank_over.update(rule.ranks)
        eff_cfg = dataclasses.replace(
            cfg, latent=dataclasses.replace(cfg.latent, compression=comp))
        ranks = ranks_lib.latent_ranks(eff_cfg)
        for k, v in rank_over.items():
            if k not in ranks:
                raise ValueError(
                    f"rank override {k!r} not applicable to this model "
                    f"(known: {', '.join(ranks)})")
            ranks[k] = int(v)
        return ResolvedModulePlan(block=block_idx, module=module,
                                  method=get_method(method_name),
                                  compression=comp, ranks=ranks)

    # -- serialization -----------------------------------------------------
    def to_dict(self) -> Dict[str, Any]:
        return {"method": self.method, "compression": self.compression,
                "rules": [r.to_dict() for r in self.rules]}

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "CompressionPlan":
        return cls(method=d.get("method", "latentllm"),
                   compression=d.get("compression"),
                   rules=tuple(PlanRule.from_dict(r)
                               for r in d.get("rules", ())))

    # -- reporting ---------------------------------------------------------
    def summary_rows(self, cfg: ModelConfig,
                     report: Optional[Dict[str, Any]] = None
                     ) -> List[Dict[str, Any]]:
        """Per-block rows of method/ranks/params/FLOPs, merged with a
        compression report's recon-loss and wall-clock when given."""
        from repro.models import transformer as T
        group, n, trailing = T.group_spec(cfg)
        descs: List[Any] = []
        for _ in range(n):
            descs.extend(group)
        descs.extend(trailing)
        n_blocks = len(descs)
        entries = {e["block"]: e for e in (report or {}).get("entries", [])}

        rows: List[Dict[str, Any]] = []
        seen_shared = False
        for idx, desc in enumerate(descs):
            kind = desc.kind
            if kind == "shared_attn":
                if seen_shared:
                    continue
                seen_shared = True
                kind = "attn"
            if kind == "ssd":
                modules = ["ssd"]
            elif getattr(desc, "moe", False):
                modules = ["attention", "moe"]
            else:
                modules = ["attention", "mlp"]
            row: Dict[str, Any] = {"block": idx, "kind": desc.kind,
                                   "modules": {}}
            dense_total = lat_total = 0
            for mod in modules:
                res = self.resolve(cfg, idx, n_blocks, mod)
                dense_p, lat_p = _module_params(cfg, mod, res.ranks)
                row["modules"][mod] = {
                    "method": res.method.name,
                    "compression": res.compression,
                    "ranks": {k: v for k, v in res.ranks.items()
                              if k in RANK_KEYS.get(mod, ())},
                    "params_dense": dense_p,
                    "params_latent": lat_p,
                }
                dense_total += dense_p
                lat_total += lat_p
            row["params_dense"] = dense_total
            row["params_latent"] = lat_total
            row["flops_dense"] = 2 * dense_total
            row["flops_latent"] = 2 * lat_total
            ent = entries.get(idx)
            if ent is not None:
                row["seconds"] = ent.get("seconds")
                for mod, mi in ent.get("modules", {}).items():
                    if mod in row["modules"] and "recon" in mi:
                        row["modules"][mod]["recon"] = mi["recon"]
            rows.append(row)
        return rows

    def summary(self, cfg: ModelConfig,
                report: Optional[Dict[str, Any]] = None) -> str:
        rows = self.summary_rows(cfg, report)
        lines = [f"CompressionPlan(method={self.method!r}, "
                 f"compression={self.compression}) on {cfg.name}:"]
        td = tl = 0
        for row in rows:
            td += row["params_dense"]
            tl += row["params_latent"]
            mods = []
            for mod, mi in row["modules"].items():
                rk = " ".join(f"{k.split('_', 1)[1]}={v}"
                              for k, v in mi["ranks"].items())
                s = f"{mod}[{mi['method']}@{mi['compression']:.0%} {rk}]"
                if "recon" in mi:
                    worst = max(mi["recon"].values())
                    s += f" recon≤{worst:.3f}"
                mods.append(s)
            ratio = (1 - row["params_latent"] / row["params_dense"]
                     if row["params_dense"] else 0.0)
            sec = (f"  {row['seconds']:.2f}s"
                   if row.get("seconds") is not None else "")
            lines.append(f"  blk {row['block']:3d} {row['kind']:<11s} "
                         f"{row['params_dense']:>10,d} -> "
                         f"{row['params_latent']:>10,d} (-{ratio:.0%})"
                         f"{sec}  {' '.join(mods)}")
        if td:
            lines.append(f"  total block params {td:,d} -> {tl:,d} "
                         f"(-{1 - tl / td:.0%}); "
                         f"block FLOPs/token {2 * td:,d} -> {2 * tl:,d}")
        return "\n".join(lines)


# rank keys each module kind actually consumes
RANK_KEYS = {
    "attention": ("r_q", "r_k", "r_v", "r_o"),
    "mlp": ("r_u", "r_d"),
    "ssd": ("r_in", "r_out"),
    "moe": (),
}


def _lr(d_in: int, d_out: int, r: int, block_identity: bool) -> int:
    n = r * (d_in + d_out)
    return n - r * r if block_identity else n


def _module_params(cfg: ModelConfig, module: str, rk: Dict[str, int]
                   ) -> Tuple[int, int]:
    """(dense, latent) analytic param counts for one module instance."""
    bi = cfg.latent.junction == "block_identity"
    d = cfg.d_model
    if module == "attention":
        dense = d * cfg.q_dim + 2 * d * cfg.kv_dim + cfg.q_dim * d
        lat = (_lr(d, cfg.q_dim, rk["r_q"], bi)
               + _lr(d, cfg.kv_dim, rk["r_k"], bi)
               + _lr(d, cfg.kv_dim, rk["r_v"], bi)
               + _lr(cfg.q_dim, d, rk["r_o"], bi))
        return dense, lat
    if module == "mlp":
        mats = 3 if cfg.gated_mlp else 2
        dense = mats * d * cfg.d_ff
        up_mats = 2 if cfg.gated_mlp else 1
        lat = (up_mats * _lr(d, cfg.d_ff, rk["r_u"], bi)
               + _lr(cfg.d_ff, d, rk["r_d"], bi))
        return dense, lat
    if module == "ssd":
        di = cfg.d_inner
        proj_out = 2 * di + 2 * cfg.ssm_ngroups * cfg.ssm_state + cfg.ssm_nheads
        dense = d * proj_out + di * d
        lat = _lr(d, proj_out, rk["r_in"], bi) + _lr(di, d, rk["r_out"], bi)
        return dense, lat
    if module == "moe":
        mats = 3 if cfg.gated_mlp else 2
        per = mats * d * cfg.d_ff
        dense = (cfg.num_experts + cfg.num_shared_experts) * per \
            + d * cfg.num_experts
        return dense, dense  # experts stay dense (passthrough)
    raise ValueError(f"unknown module kind {module!r}")
