"""Activation-aware int8 fake-quantization of compressed factors.

The ``quantize`` flag on a :class:`CompressionMethod` (the built-in
``"quant"`` method) runs this pass right after the module compressor's
SVD: every latent factor is rounded to a symmetric per-channel int8 grid
and immediately dequantized (fake-quant), so the emitted params tree
keeps its float dtypes and loads into ``transformer.forward`` unchanged
while exhibiting exactly the error a real int8 weight store would.

Channel layout: the scale lives per OUTPUT channel — one fp32 scale per
column of a ``(d_in, d_out)`` factor (``amax`` over the contraction
axis, which is always ``-2`` for this repo's factor shapes, including
the per-head ``(H, r, Dh)`` and MoE ``(E, d, F)`` tensors).

Clip search (AWQ-lite): the scale is ``alpha * amax / 127`` with
``alpha`` swept over a small grid; clipping outliers shrinks the grid
step for everything else. The winning ``alpha`` minimizes

* ``tr((W - What)^T C (W - What))`` — the expected output distortion
  ``E[|x^T (W - What)|^2]`` under the streamed input covariance ``C`` —
  whenever the factor consumes the calibrated module input (its leading
  dim matches ``C``): activation-aware in the §3.2 sense;
* plain ``||W - What||_F^2`` otherwise (latent-side factors whose input
  covariance was never streamed).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax.numpy as jnp

Params = Dict[str, Any]

INT8_MAX = 127
CLIP_GRID = (1.0, 0.95, 0.9, 0.85, 0.8)

__all__ = ["INT8_MAX", "CLIP_GRID", "fake_quant_weight",
           "fake_quant_module"]


def _quant_dequant(w32: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    q = jnp.clip(jnp.round(w32 / scale), -INT8_MAX, INT8_MAX)
    return q * scale


def fake_quant_weight(w: jnp.ndarray, C: Optional[jnp.ndarray] = None,
                      grid: Tuple[float, ...] = CLIP_GRID
                      ) -> Tuple[jnp.ndarray, Dict[str, float]]:
    """Per-channel symmetric int8 round-trip of one factor.

    Returns ``(w_hat, info)`` with ``w_hat`` in ``w``'s dtype and
    ``info`` carrying the winning clip ratio and relative error."""
    w32 = w.astype(jnp.float32)
    amax = jnp.max(jnp.abs(w32), axis=-2, keepdims=True)
    amax = jnp.where(amax > 0, amax, 1.0)
    use_c = (C is not None and w32.ndim == 2
             and w32.shape[0] == C.shape[0])
    best = best_err = best_alpha = None
    for alpha in grid:
        wq = _quant_dequant(w32, alpha * amax / INT8_MAX)
        d = wq - w32
        if use_c:
            err = float(jnp.einsum("ir,ij,jr->", d,
                                   C.astype(jnp.float32), d))
        else:
            err = float(jnp.sum(d * d))
        if best_err is None or err < best_err:
            best, best_err, best_alpha = wq, err, alpha
    rel = float(jnp.linalg.norm(best - w32)
                / jnp.maximum(jnp.linalg.norm(w32), 1e-12))
    return best.astype(w.dtype), {"alpha": best_alpha, "rel_err": rel,
                                  "weighted": bool(use_c)}


def fake_quant_module(params: Params, C: Optional[jnp.ndarray] = None
                      ) -> Tuple[Params, Dict[str, Any]]:
    """Fake-quantize every matrix-valued leaf of a compressed module.

    Vectors (biases, norm scales, per-head gains) pass through — int8
    weight stores keep those in fp anyway. Nested dicts (the SSD
    module's sub-layers) recurse."""
    out: Params = {}
    info: Dict[str, Any] = {}
    for k, v in params.items():
        if isinstance(v, dict):
            out[k], sub = fake_quant_module(v, C)
            if sub:
                info[k] = sub
        elif (hasattr(v, "ndim") and v.ndim >= 2
                and jnp.issubdtype(v.dtype, jnp.floating)):
            out[k], info[k] = fake_quant_weight(v, C)
        else:
            out[k] = v
    return out, info
