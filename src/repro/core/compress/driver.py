"""LatentLLM model compression driver.

Walks a trained model's group-structured params layer-by-layer
(GPTQ/SparseLLM-style sequential calibration: layer ℓ is compressed, then
the COMPRESSED activations propagate to layer ℓ+1), producing a latent
params tree that loads into ``transformer.forward`` with
``cfg.latent.enabled``.

The :class:`Compressor` entry point composes the three public
abstractions: the method/module registries (``registry``/``modules``),
per-layer :class:`~repro.core.compress.plan.CompressionPlan` policies,
and streaming multi-batch calibration (``stats``)::

    comp = Compressor(params, cfg, plan=plan)
    comp.calibrate(batches)            # any iterable of calibration batches
    latent_params, report = comp.compress()
    print(plan.summary(cfg, report))

``compress_model(params, cfg, batch, method)`` remains as the seed's
single-batch wrapper.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, Iterable, List, Optional, Tuple, Union

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, dtype_of
from repro.core import ranks as ranks_lib
from repro.models import layers as L
from repro.models import transformer as T
from repro.core.compress.plan import (RANK_KEYS, CompressionPlan,
                                      ResolvedModulePlan)
from repro.core.compress.registry import (CalibContext, get_method,
                                          get_module_compressor)
from repro.core.compress import quant as wquant
from repro.core.compress.stats import StreamingStats

Params = Dict[str, Any]
Batch = Dict[str, jnp.ndarray]

__all__ = ["Compressor", "compress_model"]

# rank-key -> (param key, axis) padding map; factors are zero-padded up to
# the config-uniform ranks so stacked group scan + latent cache shapes stay
# homogeneous (padded rows/cols are zero: numerically exact).
_ATTN_PAD = {"a_q": ("r_q", 1), "b_q": ("r_q", 1), "a_k": ("r_k", 1),
             "b_k": ("r_k", 1), "a_v": ("r_v", 1), "b_v": ("r_v", 1),
             "a_o": ("r_o", 1), "b_o": ("r_o", 0)}
_MLP_PAD = {"up_a": ("r_u", 1), "up_b": ("r_u", 0), "gate_a": ("r_u", 1),
            "gate_b": ("r_u", 0), "down_a": ("r_d", 1), "down_b": ("r_d", 0)}
_SSD_PAD = {("in_proj", "a"): ("r_in", 1), ("in_proj", "b"): ("r_in", 0),
            ("out_proj", "a"): ("r_out", 1), ("out_proj", "b"): ("r_out", 0)}


def _pad_axis(a: jnp.ndarray, axis: int, target: int) -> jnp.ndarray:
    extra = target - a.shape[axis]
    if extra == 0:
        return a
    widths = [(0, 0)] * a.ndim
    widths[axis] = (0, extra)
    return jnp.pad(a, widths)


def _check_ranks(res: ResolvedModulePlan, pad_ranks: Dict[str, int]) -> None:
    for k in RANK_KEYS.get(res.module, ()):
        v = res.ranks.get(k)
        if v is not None and k in pad_ranks and v > pad_ranks[k]:
            raise ValueError(
                f"plan resolves {k}={v} at block {res.block} above the "
                f"config-uniform rank {pad_ranks[k]} (cfg.latent.compression "
                f"sizes the stacked params and latent cache); per-layer "
                f"overrides may only reduce ranks — set "
                f"cfg.latent.compression to the LIGHTEST level in the plan")


class Compressor:
    """Composable compression pipeline: plan + streaming calibration.

    ``plan`` defaults to a uniform plan from ``cfg.latent`` (``method``
    may be passed as a shorthand instead). ``calibrate`` accepts a single
    batch dict or an iterable of them; statistics at every module site
    accumulate across all batches (Welford merges) before each solve.
    """

    def __init__(self, params: Params, cfg: ModelConfig,
                 plan: Optional[CompressionPlan] = None,
                 method: Optional[str] = None):
        if plan is not None and method is not None:
            raise ValueError("pass either plan= or method=, not both")
        if plan is None:
            plan = CompressionPlan.from_config(cfg, method=method)
        get_method(plan.method)  # fail fast on unknown methods
        self.params = params
        self.cfg = cfg
        self.plan = plan
        self._xs: Optional[List[jnp.ndarray]] = None
        self._positions: List[jnp.ndarray] = []

    # ------------------------------------------------------------------
    def calibrate(self, batches: Union[Batch, Iterable[Batch]]
                  ) -> "Compressor":
        """Embed calibration batches; stats stream across all of them."""
        if isinstance(batches, dict):
            batches = [batches]
        cfg, params = self.cfg, self.params
        comp_dtype = dtype_of(cfg)
        xs, positions = [], []
        for batch in batches:
            tokens = batch.get("tokens")
            frames = batch.get("frames")
            if frames is not None:
                x = frames.astype(comp_dtype)
            else:
                x = params["embed"].astype(comp_dtype)[tokens]
            S = x.shape[1]
            pos = jnp.arange(S, dtype=jnp.int32)
            if cfg.pos_emb == "learned":
                x = x + params["pos_embed"].astype(comp_dtype)[pos]
            xs.append(x)
            positions.append(pos)
        if not xs:
            raise ValueError("calibrate() needs at least one batch")
        self._xs = xs
        self._positions = positions
        return self

    # ------------------------------------------------------------------
    def compress(self) -> Tuple[Params, Dict[str, Any]]:
        if self._xs is None:
            raise RuntimeError("call calibrate(batches) before compress()")
        cfg, params, plan = self.cfg, self.params, self.plan
        latent_cfg = dataclasses.replace(
            cfg, latent=dataclasses.replace(cfg.latent, enabled=True))
        pad_ranks = ranks_lib.latent_ranks(cfg)
        group, n, trailing = T.group_spec(cfg)
        n_blocks = n * len(group) + len(trailing)
        damp = cfg.latent.damping

        xs = list(self._xs)
        positions = self._positions
        shared_latent: Optional[Params] = None
        report: Dict[str, Any] = {"method": plan.method, "blocks": 0,
                                  "n_blocks": n_blocks, "entries": []}

        def stream_stats(h_list: List[jnp.ndarray],
                         keep_raw: bool) -> StreamingStats:
            st = StreamingStats(h_list[0].shape[-1], keep_raw=keep_raw)
            for h in h_list:
                st.update(h)
            return st

        def resolve(idx: int, module: str) -> ResolvedModulePlan:
            res = plan.resolve(cfg, idx, n_blocks, module)
            _check_ranks(res, pad_ranks)
            return res

        def compress_block(p_blk: Params, desc: T.BlockDesc, xs, idx: int
                           ) -> Params:
            t0 = time.perf_counter()
            entry: Dict[str, Any] = {"block": idx, "kind": desc.kind,
                                     "modules": {}}

            def run_module(module: str, p_mod: Params, h_list) -> Params:
                res = resolve(idx, module)
                comp = get_module_compressor(module)
                st = stream_stats(h_list, keep_raw=comp.needs_raw)
                ctx = CalibContext(cfg=cfg, method=res.method,
                                   ranks=res.ranks,
                                   stats=st.finalize(damp),
                                   h_list=tuple(h_list))
                new_mod, info = comp.compress(p_mod, ctx)
                if res.method.quantize:
                    # post-SVD int8 fake-quant of the latent factors,
                    # clip-searched against this module's streamed input
                    # covariance (core.compress.quant)
                    new_mod, qinfo = wquant.fake_quant_module(
                        new_mod, ctx.stats.C)
                    info = dict(info, weight_quant=qinfo)
                entry["modules"][module] = dict(
                    info, method=res.method.name,
                    compression=res.compression,
                    ranks={k: v for k, v in res.ranks.items()
                           if k in RANK_KEYS.get(module, ())})
                return new_mod

            if desc.kind == "ssd":
                h_list = [L.norm_fwd(p_blk["ln"], x) for x in xs]
                new_ssd = run_module("ssd", p_blk["ssd"], h_list)
                for (mod, key), (rk, axis) in _SSD_PAD.items():
                    new_ssd[mod][key] = _pad_axis(new_ssd[mod][key], axis,
                                                  pad_ranks[rk])
                new_blk = {"ln": p_blk["ln"], "ssd": new_ssd}
            else:
                h1 = [L.norm_fwd(p_blk["ln1"], x) for x in xs]
                new_attn = run_module("attention", p_blk["attn"], h1)
                for key, (rk, axis) in _ATTN_PAD.items():
                    if key in new_attn:
                        new_attn[key] = _pad_axis(new_attn[key], axis,
                                                  pad_ranks[rk])
                new_blk = {"ln1": p_blk["ln1"], "ln2": p_blk["ln2"],
                           "attn": new_attn}
                # propagate through compressed attention for the MLP stats
                h2 = []
                for x, h, pos in zip(xs, h1, positions):
                    y, _ = L.latent_attention_fwd(
                        new_attn, h, latent_cfg,
                        positions=pos, window=desc.window)
                    h2.append(L.norm_fwd(p_blk["ln2"], x + y))
                if "moe" in p_blk:
                    new_blk["moe"] = run_module("moe", p_blk["moe"], h2)
                else:
                    new_mlp = run_module("mlp", p_blk["mlp"], h2)
                    for key, (rk, axis) in _MLP_PAD.items():
                        if key in new_mlp:
                            new_mlp[key] = _pad_axis(new_mlp[key], axis,
                                                     pad_ranks[rk])
                    new_blk["mlp"] = new_mlp
            entry["seconds"] = time.perf_counter() - t0
            report["blocks"] += 1
            report["entries"].append(entry)
            return new_blk

        def run_block(p_new: Params, desc: T.BlockDesc, xs) -> List:
            """Forward through the compressed block (sequential propagation)."""
            blk = shared_latent if desc.kind == "shared_attn" else p_new
            out = []
            for x, pos in zip(xs, positions):
                if desc.kind == "ssd":
                    h = L.norm_fwd(blk["ln"], x)
                    if "a" in blk["ssd"]["in_proj"]:
                        y, _ = T._ssd_fwd_factored(blk["ssd"], h, cfg, None)
                    else:
                        y, _ = L.ssd_fwd(blk["ssd"], h, cfg)
                    out.append(x + y)
                    continue
                h = L.norm_fwd(blk["ln1"], x)
                y, _ = L.latent_attention_fwd(blk["attn"], h, latent_cfg,
                                              positions=pos,
                                              window=desc.window)
                x = x + y
                h2 = L.norm_fwd(blk["ln2"], x)
                if "moe" in blk:
                    y2, _ = L.moe_fwd(blk["moe"], h2, cfg)
                else:
                    y2 = L.latent_mlp_fwd(blk["mlp"], h2, latent_cfg)
                out.append(x + y2)
            return out

        # compress the zamba-style shared block against its first application
        shared_desc = T.BlockDesc("attn", window=None, moe=False)

        new_groups: List[List[Params]] = []
        idx = 0
        for g in range(n):
            new_blocks = []
            for bi, desc in enumerate(group):
                p_blk = jax.tree.map(lambda a: a[g], params["groups"][bi])
                if desc.kind == "shared_attn":
                    if shared_latent is None:
                        shared_latent = compress_block(
                            params["shared_block"], shared_desc, xs, idx)
                    new_blk = {}
                else:
                    new_blk = compress_block(p_blk, desc, xs, idx)
                xs = run_block(new_blk, desc, xs)
                new_blocks.append(new_blk)
                idx += 1
            new_groups.append(new_blocks)

        new_trailing = []
        for i, desc in enumerate(trailing):
            new_blk = compress_block(params["trailing"][i], desc, xs, idx)
            xs = run_block(new_blk, desc, xs)
            new_trailing.append(new_blk)
            idx += 1

        # restack group params
        stacked = []
        for bi in range(len(group)):
            blocks = [new_groups[g][bi] for g in range(n)]
            stacked.append(jax.tree.map(lambda *a: jnp.stack(a), *blocks))

        new_params = dict(params)
        new_params["groups"] = stacked
        new_params["trailing"] = new_trailing
        if shared_latent is not None:
            new_params["shared_block"] = shared_latent
        return new_params, report


def compress_model(params: Params, cfg: ModelConfig, batch: Batch,
                   method: str = "latentllm") -> Tuple[Params, Dict]:
    """Seed-compatible single-batch wrapper around :class:`Compressor`."""
    comp = Compressor(params, cfg,
                      plan=CompressionPlan.from_config(cfg, method=method))
    return comp.calibrate(batch).compress()
