"""Streaming calibration statistics (multi-batch Welford accumulation).

The compression solvers consume second-moment statistics of layer inputs:
``C = XXᵀ/l + λI`` and the mean ``mu`` (paper §3.2, Remark 3). The seed
driver computed these from ONE calibration batch; production calibration
wants many small batches streamed through the model. ``StreamingStats``
accumulates (mean, centered comoment, ℓ1 row-sums, count) across chunks
with Chan/Welford merge updates, so the finalized ``C``/``mu`` match
``activation_stats`` on the concatenated data to float32 round-off.

Raw activation chunks are retained by default (``keep_raw=True``) because
two consumers genuinely need raw columns rather than moments: the joint
UD solver (App. H) and the hidden-state statistics of gated MLPs. Pass
``keep_raw=False`` for a pure-moment O(d²) memory profile when those
paths are not taken.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp

__all__ = ["CalibStats", "StreamingStats"]


@dataclasses.dataclass
class CalibStats:
    """Finalized calibration statistics for one module site.

    ``C`` is damped exactly like :func:`repro.core.precond.activation_stats`:
    ``C = Σxxᵀ/n + λ·mean(diag)·I``.
    """

    C: jnp.ndarray                       # (d, d) damped second moment
    mu: jnp.ndarray                      # (d,)
    count: int                           # total columns accumulated
    l1_diag: Optional[jnp.ndarray] = None  # (d,) mean |x| per feature
    chunks: Tuple[jnp.ndarray, ...] = ()   # retained raw (d, l_i) blocks

    @property
    def X(self) -> Optional[jnp.ndarray]:
        """Concatenated raw activations (d, Σl_i), or None if not retained."""
        if not self.chunks:
            return None
        if len(self.chunks) == 1:
            return self.chunks[0]
        return jnp.concatenate(self.chunks, axis=1)


class StreamingStats:
    """Accumulates activation statistics over an arbitrary batch stream.

    ``update`` accepts either hidden states ``(B, S, d)`` / ``(l, d)`` rows
    or an already-transposed column matrix ``(d, l)`` via ``columns=True``.
    """

    def __init__(self, d: int, keep_raw: bool = True):
        self.d = int(d)
        self.keep_raw = keep_raw
        self.count = 0
        self._mean = jnp.zeros((d,), jnp.float32)
        self._M2 = jnp.zeros((d, d), jnp.float32)   # centered comoment
        self._l1 = jnp.zeros((d,), jnp.float32)
        self._chunks = []

    def update(self, h: jnp.ndarray, columns: bool = False) -> "StreamingStats":
        if columns:
            X = h.astype(jnp.float32)
        else:
            X = h.astype(jnp.float32).reshape(-1, h.shape[-1]).T
        if X.shape[0] != self.d:
            raise ValueError(
                f"feature dim mismatch: got {X.shape[0]}, expected {self.d}")
        l = X.shape[1]
        if l == 0:
            return self
        bmean = jnp.mean(X, axis=1)
        Xc = X - bmean[:, None]
        Sb = Xc @ Xc.T
        n = self.count
        tot = n + l
        delta = bmean - self._mean
        self._mean = self._mean + delta * (l / tot)
        self._M2 = self._M2 + Sb + jnp.outer(delta, delta) * (n * l / tot)
        self._l1 = self._l1 + jnp.sum(jnp.abs(X), axis=1)
        self.count = tot
        if self.keep_raw:
            self._chunks.append(X)
        return self

    def second_moment(self) -> jnp.ndarray:
        """Undamped E[xxᵀ] over everything accumulated so far."""
        if self.count == 0:
            raise ValueError("no calibration data accumulated")
        return (self._M2 + self.count * jnp.outer(self._mean, self._mean)
                ) / self.count

    def finalize(self, damping: float = 1e-2) -> CalibStats:
        C = self.second_moment()
        lam = damping * jnp.mean(jnp.diag(C)) + 1e-12
        C = C + lam * jnp.eye(self.d, dtype=jnp.float32)
        return CalibStats(
            C=C,
            mu=self._mean,
            count=self.count,
            l1_diag=self._l1 / self.count,
            chunks=tuple(self._chunks),
        )
