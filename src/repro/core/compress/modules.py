"""Per-module-kind compressors (attention / MLP / SSD / MoE passthrough).

Each class consumes a :class:`~repro.core.compress.registry.CalibContext`
(streamed input statistics + raw per-batch activations where a solver
genuinely needs them) and produces the latent parameter dict that
``models.layers``' latent forward functions load, plus an info dict of
per-projection reconstruction errors for the compression report.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.joint_qk import joint_qk_svd
from repro.core.joint_vo import split_vo
from repro.core.mlp_ud import joint_ud
from repro.core.svd import weighted_svd
from repro.models import layers as L
from repro.core.compress.registry import (CalibContext, ModuleCompressor,
                                          precond_pair,
                                          register_module_compressor)
from repro.core.compress.stats import StreamingStats

Params = Dict[str, Any]

_ACTS = {"relu": jax.nn.relu, "gelu": jax.nn.gelu, "silu": jax.nn.silu}


def _rel_err(W: jnp.ndarray, What: jnp.ndarray) -> float:
    """Relative Frobenius reconstruction error ‖W−Ŵ‖/‖W‖."""
    num = jnp.linalg.norm(W.astype(jnp.float32) - What.astype(jnp.float32))
    den = jnp.linalg.norm(W.astype(jnp.float32)) + 1e-30
    return float(num / den)


@register_module_compressor("attention")
class AttentionCompressor(ModuleCompressor):
    """QKVO projections: joint QK + split VO (latentllm) or local ASVD."""

    def compress(self, p_attn: Params, ctx: CalibContext
                 ) -> Tuple[Params, Dict[str, Any]]:
        cfg, method, rk = ctx.cfg, ctx.method, ctx.ranks
        d, H, Hk, dh = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
        C, mu = ctx.stats.C, ctx.stats.mu
        P, P_pinv = method.precond_pair(ctx.stats, ctx.damping)

        Wq = p_attn["q"]["w"].T.astype(jnp.float32).reshape(H, dh, d)
        Wk = p_attn["k"]["w"].T.astype(jnp.float32).reshape(Hk, dh, d)
        Wv = p_attn["v"]["w"].T.astype(jnp.float32).reshape(Hk, dh, d)
        Wo = p_attn["o"]["w"].T.astype(jnp.float32)  # (d, H*dh)
        bq = p_attn["q"].get("b")
        bk = p_attn["k"].get("b")
        bv = p_attn["v"].get("b")
        bo = p_attn["o"].get("b")
        if bq is not None:
            bq = bq.reshape(H, dh)
            bk = bk.reshape(Hk, dh)

        out: Params = {}
        if method.attention_aware and cfg.latent.joint_qk:
            jqk = joint_qk_svd(Wq, Wk, P, rk["r_q"], rk["r_k"],
                               iters=cfg.latent.qk_iters, bq=bq, bk=bk, mu=mu,
                               C0=C if bq is not None else None, P_pinv=P_pinv)
            A_q, A_k, B_q, B_k = jqk.A_q, jqk.A_k, jqk.B_q, jqk.B_k
            nbq, nbk = jqk.b_q, jqk.b_k
        else:  # local: shared-A joint-head ASVD per projection
            lrq = weighted_svd(Wq.reshape(H * dh, d), P, rk["r_q"],
                               junction="left", P_pinv=P_pinv)
            lrk = weighted_svd(Wk.reshape(Hk * dh, d), P, rk["r_k"],
                               junction="left", P_pinv=P_pinv)
            A_q, B_q = lrq.A, lrq.B.reshape(H, dh, rk["r_q"])
            A_k, B_k = lrk.A, lrk.B.reshape(Hk, dh, rk["r_k"])
            nbq, nbk = bq, bk

        vo = split_vo(Wv, Wo, P, rk["r_v"], rk["r_o"],
                      C=C if method.attention_aware else None,
                      bv=bv.reshape(Hk, dh) if bv is not None else None,
                      bo=bo, mu=mu, P_pinv=P_pinv)

        out["a_q"] = A_q.T.astype(jnp.float32)
        out["a_k"] = A_k.T.astype(jnp.float32)
        out["a_v"] = vo.A_v.T.astype(jnp.float32)
        out["b_q"] = jnp.transpose(B_q, (0, 2, 1)).astype(jnp.float32)
        out["b_k"] = jnp.transpose(B_k, (0, 2, 1)).astype(jnp.float32)
        out["b_v"] = jnp.transpose(vo.B_v, (0, 2, 1)).astype(jnp.float32)
        out["a_o"] = vo.A_o.T.astype(jnp.float32)
        out["b_o"] = vo.B_o.T.astype(jnp.float32)
        if cfg.qkv_bias:
            out["bias_q"] = (nbq if nbq is not None
                             else jnp.zeros((H, dh))).reshape(-1)
            out["bias_k"] = (nbk if nbk is not None
                             else jnp.zeros((Hk, dh))).reshape(-1)
            out["bias_v"] = (bv if bv is not None
                             else jnp.zeros((Hk * dh,))).reshape(-1)
        if cfg.o_bias:
            out["bias_o"] = bo if bo is not None else jnp.zeros((d,))

        info = {"recon": {
            "q": _rel_err(Wq.reshape(H * dh, d),
                          B_q.reshape(H * dh, -1) @ A_q),
            "k": _rel_err(Wk.reshape(Hk * dh, d),
                          B_k.reshape(Hk * dh, -1) @ A_k),
            "v": _rel_err(Wv.reshape(Hk * dh, d),
                          vo.B_v.reshape(Hk * dh, -1) @ vo.A_v),
            "o": _rel_err(Wo, vo.B_o @ vo.A_o),
        }}
        return out, info


@register_module_compressor("mlp")
class MlpCompressor(ModuleCompressor):
    """Up/gate/down projections: joint UD (App. H) or local ASVD.

    ``needs_raw``: the hidden-state statistics for the down projection
    (and the joint UD solver) are nonlinear in the inputs, so streamed
    moments are not enough — raw chunks are required.

    Weights are cast to float32 ONCE up front (the calibration statistics
    are float32 — mixing the bf16 param dtype into ``W @ X`` both loses
    precision and re-materializes casts), and the gate matrix is reused
    between its factorization and the hidden-state statistics.
    """

    needs_raw = True

    def compress(self, p_mlp: Params, ctx: CalibContext
                 ) -> Tuple[Params, Dict[str, Any]]:
        cfg, method, rk = ctx.cfg, ctx.method, ctx.ranks
        damp = ctx.damping
        P, P_pinv = method.precond_pair(ctx.stats, damp)
        junction = "left"

        Wu = p_mlp["up"]["w"].T.astype(jnp.float32)      # (F, d)
        Wd = p_mlp["down"]["w"].T.astype(jnp.float32)    # (d, F)
        bu = p_mlp["up"].get("b")
        bd = p_mlp["down"].get("b")
        gated = "gate" in p_mlp
        Wg = p_mlp["gate"]["w"].T.astype(jnp.float32) if gated else None
        bg = p_mlp["gate"].get("b") if gated else None
        out: Params = {}
        info: Dict[str, Any] = {"recon": {}}

        use_joint = (method.joint_ud and cfg.latent.joint_ud
                     and cfg.activation == "relu" and not gated)
        if use_joint:
            X = ctx.stats.X
            if X is None:
                raise ValueError(
                    "joint UD needs retained raw activations; calibrate with "
                    "keep_raw=True (the default)")
            ud = joint_ud(Wu, Wd, X, rk["r_u"], rk["r_d"], act=cfg.activation,
                          iters=cfg.latent.ud_iters, bu=bu, bd=bd,
                          junction=junction, damping=damp)
            out["up_a"], out["up_b"] = ud.up.A.T, ud.up.B.T
            out["down_a"], out["down_b"] = ud.down.A.T, ud.down.B.T
            if cfg.mlp_bias:
                out["up_bias"], out["down_bias"] = ud.b_u, ud.b_d
            info["recon"]["up"] = _rel_err(Wu, ud.up.reconstruct())
            info["recon"]["down"] = _rel_err(Wd, ud.down.reconstruct())
            return out, info

        lru = weighted_svd(Wu, P, rk["r_u"], junction=junction, P_pinv=P_pinv)
        out["up_a"], out["up_b"] = lru.A.T, lru.B.T
        info["recon"]["up"] = _rel_err(Wu, lru.reconstruct())
        if gated:
            lrg = weighted_svd(Wg, P, rk["r_u"], junction=junction,
                               P_pinv=P_pinv)
            out["gate_a"], out["gate_b"] = lrg.A.T, lrg.B.T
            info["recon"]["gate"] = _rel_err(Wg, lrg.reconstruct())

        # hidden statistics for the down projection, streamed per chunk
        act_fn = _ACTS[cfg.activation]
        if not ctx.stats.chunks:
            raise ValueError(
                "MLP down-projection statistics need retained raw "
                "activations; calibrate with keep_raw=True (the default)")
        hidden = StreamingStats(Wu.shape[0], keep_raw=False)
        bu32 = bu.astype(jnp.float32)[:, None] if bu is not None else 0.0
        bg32 = bg.astype(jnp.float32)[:, None] if bg is not None else 0.0
        for Xb in ctx.stats.chunks:
            u = Wu @ Xb + bu32
            if gated:
                A_hidden = u * act_fn(Wg @ Xb + bg32)
            else:
                A_hidden = act_fn(u)
            hidden.update(A_hidden, columns=True)
        hstats = hidden.finalize(damp)
        Pa, Pa_pinv = precond_pair(method.precond, hstats, damp)
        lrd = weighted_svd(Wd, Pa, rk["r_d"], junction=junction,
                           P_pinv=Pa_pinv)
        out["down_a"], out["down_b"] = lrd.A.T, lrd.B.T
        info["recon"]["down"] = _rel_err(Wd, lrd.reconstruct())
        if cfg.mlp_bias:
            out["up_bias"] = bu if bu is not None else jnp.zeros((Wu.shape[0],))
            out["down_bias"] = bd if bd is not None else jnp.zeros((Wd.shape[0],))
            if gated:
                out["gate_bias"] = (bg if bg is not None
                                    else jnp.zeros((Wu.shape[0],)))
        return out, info


@register_module_compressor("ssd")
class SsdCompressor(ModuleCompressor):
    """Latent SSM: factor in/out projections (QK/VO are N/A — DESIGN §5)."""

    def compress(self, p_ssd: Params, ctx: CalibContext
                 ) -> Tuple[Params, Dict[str, Any]]:
        cfg, method, rk = ctx.cfg, ctx.method, ctx.ranks
        damp = ctx.damping
        P, P_pinv = method.precond_pair(ctx.stats, damp)
        Win = p_ssd["in_proj"]["w"].T.astype(jnp.float32)   # (proj_out, d)
        lri = weighted_svd(Win, P, rk["r_in"], junction="left", P_pinv=P_pinv)
        out = dict(p_ssd)
        out["in_proj"] = {"a": lri.A.T, "b": lri.B.T}
        # out_proj input: gated y — recompute internals for its statistics
        if not ctx.h_list:
            raise ValueError("SSD compression needs raw per-batch inputs")
        di = cfg.d_inner
        ostats = StreamingStats(di, keep_raw=False)
        for h in ctx.h_list:
            ostats.update(_ssd_out_input(p_ssd, h, cfg))
        ofin = ostats.finalize(damp)
        Po, Po_pinv = precond_pair(method.precond, ofin, damp)
        Wout = p_ssd["out_proj"]["w"].T.astype(jnp.float32)  # (d, d_i)
        lro = weighted_svd(Wout, Po, rk["r_out"], junction="left",
                           P_pinv=Po_pinv)
        out["out_proj"] = {"a": lro.A.T, "b": lro.B.T}
        info = {"recon": {"in_proj": _rel_err(Win, lri.reconstruct()),
                          "out_proj": _rel_err(Wout, lro.reconstruct())}}
        return out, info


@register_module_compressor("moe")
class MoeCompressor(ModuleCompressor):
    """Experts stay dense (DESIGN §5): pass the module through untouched."""

    def compress(self, p_moe: Params, ctx: CalibContext
                 ) -> Tuple[Params, Dict[str, Any]]:
        return p_moe, {"passthrough": True}


def _ssd_out_input(p: Params, x: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    """Replicates layers.ssd_fwd up to the out_proj input."""
    B, S, d = x.shape
    di, G, N = cfg.d_inner, cfg.ssm_ngroups, cfg.ssm_state
    Hs, Pd = cfg.ssm_nheads, cfg.ssm_head_dim
    W = cfg.ssm_conv_width
    zxbcdt = L.dense(p["in_proj"], x)
    z, xbc, dt = jnp.split(zxbcdt, [di, di + di + 2 * G * N], axis=-1)
    conv_in = jnp.pad(xbc, ((0, 0), (W - 1, 0), (0, 0)))
    xbc = L._causal_conv(conv_in, p["conv_w"], p["conv_b"], S)
    xbc = jax.nn.silu(xbc)
    xs, Bm, Cm = jnp.split(xbc, [di, di + G * N], axis=-1)
    xh = xs.reshape(B, S, Hs, Pd)
    Bm = Bm.reshape(B, S, G, N)
    Cm = Cm.reshape(B, S, G, N)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    y, _ = L._ssd_chunked(xh, dt, A, Bm, Cm, min(cfg.ssm_chunk, S))
    y = y + xh * p["D"].astype(x.dtype)[None, None, :, None]
    y = y.reshape(B, S, di)
    return L.norm_fwd(p["norm"], y) * jax.nn.silu(z)
