"""Composable compression pipeline (paper methods as a pluggable API).

Public surface:

* :class:`Compressor` — plan + streaming calibration + sequential driver.
* :func:`compress_model` — seed-compatible single-batch wrapper.
* :class:`CompressionPlan` / :class:`PlanRule` — per-layer/module policy.
* :func:`register_method` / :class:`CompressionMethod` — method registry.
* ``register_module_compressor`` + the per-kind compressor classes.
* :class:`StreamingStats` — multi-batch Welford calibration statistics.
"""
from repro.core.compress.stats import CalibStats, StreamingStats
from repro.core.compress.registry import (METHODS, CalibContext,
                                          CompressionMethod, ModuleCompressor,
                                          available_methods,
                                          available_module_kinds, get_method,
                                          get_module_compressor,
                                          register_method,
                                          register_module_compressor)
from repro.core.compress.modules import (AttentionCompressor, MlpCompressor,
                                         MoeCompressor, SsdCompressor)
from repro.core.compress.plan import (CompressionPlan, PlanRule,
                                      ResolvedModulePlan)
from repro.core.compress.quant import fake_quant_module, fake_quant_weight
from repro.core.compress.driver import Compressor, compress_model

__all__ = [
    "METHODS", "CalibStats", "StreamingStats", "CalibContext",
    "CompressionMethod", "ModuleCompressor", "available_methods",
    "available_module_kinds", "get_method", "get_module_compressor",
    "register_method", "register_module_compressor", "AttentionCompressor",
    "MlpCompressor", "MoeCompressor", "SsdCompressor", "CompressionPlan",
    "PlanRule", "ResolvedModulePlan", "Compressor", "compress_model",
    "fake_quant_module", "fake_quant_weight",
]
