"""Method and module-compressor registries for the compression pipeline.

Two extension points:

* **Methods** — a :class:`CompressionMethod` bundles the Tab. 1
  preconditioner choice with the paper's attention-aware flags. Built-ins
  cover every baseline in the paper; new methods register with
  ``@register_method("name")`` (or a direct call) and are immediately
  usable from :class:`~repro.core.compress.driver.Compressor`,
  :class:`~repro.core.compress.plan.CompressionPlan` rules, and the CLI
  tools — no driver edits.

* **Module compressors** — one class per module kind ("attention",
  "mlp", "ssd", "moe"); the driver looks the class up by the block's
  module kind, so new module kinds (or replacement solvers for existing
  kinds) plug in via ``@register_module_compressor("kind")``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple, Type, Union

import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.precond import preconditioner, psd_pinv
from repro.core.compress.stats import CalibStats

Params = Dict[str, Any]

__all__ = [
    "METHODS", "CompressionMethod", "CalibContext", "ModuleCompressor",
    "register_method", "get_method", "available_methods",
    "register_module_compressor", "get_module_compressor",
    "available_module_kinds", "precond_pair",
]


def precond_pair(kind: str, stats: CalibStats, damping: float
                 ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """(P, P⁺) for a Tab. 1 preconditioner kind from streamed statistics."""
    if kind == "l1":
        if stats.l1_diag is None:
            raise ValueError("diag-ℓ1 preconditioner needs streamed |x| sums")
        P = jnp.diag(stats.l1_diag + 1e-12)
    else:
        P = preconditioner(kind, C=stats.C, damping=damping)
    if kind == "identity":
        return P, P
    if kind in ("hessian", "l1", "l2"):
        d = jnp.diag(P)
        return P, jnp.diag(jnp.where(d > 1e-12, 1.0 / d, 0.0))
    return P, psd_pinv(P)


@dataclasses.dataclass(frozen=True)
class CompressionMethod:
    """A named compression recipe.

    ``precond`` picks the Tab. 1 preconditioner; ``attention_aware``
    enables joint QK (Alg. 1) and the attention-aware C_o in split VO;
    ``joint_ud`` enables the App. H joint up/down MLP solver (applies to
    non-gated ReLU MLPs). All methods share the same latent structure, so
    parameter counts are identical across methods — only the solution
    differs.
    """

    name: str
    precond: str = "rootcov"
    attention_aware: bool = False
    joint_ud: bool = False
    # post-SVD per-channel int8 fake-quant of the absorbed factors,
    # activation-aware via the streamed covariance (clip-ratio search
    # minimizing tr((W-Ŵ)C(W-Ŵ)ᵀ)) — see core.compress.quant
    quantize: bool = False
    description: str = ""

    def precond_pair(self, stats: CalibStats, damping: float
                     ) -> Tuple[jnp.ndarray, jnp.ndarray]:
        return precond_pair(self.precond, stats, damping)


_METHOD_REGISTRY: Dict[str, CompressionMethod] = {}


def register_method(method: Union[str, CompressionMethod], *,
                    overwrite: bool = False):
    """Register a compression method.

    Usable as a direct call with a :class:`CompressionMethod` instance, or
    as a decorator on an instance-returning factory / subclass::

        register_method(CompressionMethod("mine", precond="l2"))

        @register_method("mine")
        class Mine(CompressionMethod): ...
    """
    if isinstance(method, CompressionMethod):
        _register(method, overwrite)
        return method

    name = method

    def deco(obj):
        m = obj if isinstance(obj, CompressionMethod) else obj(name=name)
        if m.name != name:
            m = dataclasses.replace(m, name=name)
        _register(m, overwrite)
        return obj

    return deco


def _register(m: CompressionMethod, overwrite: bool) -> None:
    if m.name in _METHOD_REGISTRY and not overwrite:
        raise ValueError(f"compression method {m.name!r} already registered; "
                         f"pass overwrite=True to replace it")
    _METHOD_REGISTRY[m.name] = m


def get_method(method: Union[str, CompressionMethod]) -> CompressionMethod:
    if isinstance(method, CompressionMethod):
        return method
    try:
        return _METHOD_REGISTRY[method]
    except KeyError:
        raise ValueError(
            f"unknown compression method {method!r}; available: "
            f"{', '.join(available_methods())}") from None


def available_methods() -> Tuple[str, ...]:
    return tuple(_METHOD_REGISTRY)


# -- built-ins (paper Tab. 1 / Tab. 2 lineup) --------------------------------

for _m in (
    CompressionMethod("plain", precond="identity",
                      description="truncated SVD, no activation awareness"),
    CompressionMethod("asvd_hessian", precond="hessian",
                      description="OBS/GPTQ diag-Hessian weighting"),
    CompressionMethod("asvd_l1", precond="l1",
                      description="ASVD/AWQ diag-ℓ1 weighting"),
    CompressionMethod("asvd_l2", precond="l2",
                      description="WandA diag-ℓ2 weighting"),
    CompressionMethod("asvd_cov", precond="cov",
                      description="CorDA full-covariance weighting"),
    CompressionMethod("asvd_rootcov", precond="rootcov",
                      description="optimal local weighting C^{1/2} (§3.2)"),
    CompressionMethod("latentllm", precond="rootcov", attention_aware=True,
                      joint_ud=True,
                      description="rootcov + joint QK (Alg. 1) + "
                                  "attention-aware VO + joint UD (App. H)"),
    CompressionMethod("quant", precond="rootcov", attention_aware=True,
                      joint_ud=True, quantize=True,
                      description="latentllm + activation-aware per-channel "
                                  "int8 fake-quant of the latent factors "
                                  "(pairs with the int8 latent cache)"),
):
    _register(_m, overwrite=False)

# Back-compat: the seed exposed a fixed tuple of built-in method names.
METHODS = available_methods()


# -- module compressors ------------------------------------------------------

@dataclasses.dataclass
class CalibContext:
    """Everything a module compressor may consume for one module site."""

    cfg: ModelConfig
    method: CompressionMethod
    ranks: Dict[str, int]
    stats: CalibStats                       # streamed input statistics
    h_list: Tuple[jnp.ndarray, ...] = ()    # raw per-batch inputs (B, S, d)

    @property
    def damping(self) -> float:
        return self.cfg.latent.damping


class ModuleCompressor:
    """Base class: compress one module kind given calibration context."""

    kind: str = ""
    # whether this compressor consumes raw activation chunks (ctx.stats.X /
    # .chunks) beyond the streamed moments; the driver retains raw copies
    # only when set, keeping other sites at the O(d²) memory profile.
    needs_raw: bool = False

    def compress(self, params: Params, ctx: CalibContext
                 ) -> Tuple[Params, Dict[str, Any]]:
        """Returns (latent module params, info dict for the report)."""
        raise NotImplementedError


_MODULE_REGISTRY: Dict[str, Type[ModuleCompressor]] = {}


def register_module_compressor(kind: str, *, overwrite: bool = False
                               ) -> Callable[[Type[ModuleCompressor]],
                                             Type[ModuleCompressor]]:
    def deco(cls: Type[ModuleCompressor]) -> Type[ModuleCompressor]:
        if kind in _MODULE_REGISTRY and not overwrite:
            raise ValueError(f"module compressor {kind!r} already registered")
        cls.kind = kind
        _MODULE_REGISTRY[kind] = cls
        return cls

    return deco


def get_module_compressor(kind: str) -> ModuleCompressor:
    try:
        return _MODULE_REGISTRY[kind]()
    except KeyError:
        raise ValueError(
            f"no compressor registered for module kind {kind!r}; "
            f"available: {', '.join(available_module_kinds())}") from None


def available_module_kinds() -> Tuple[str, ...]:
    return tuple(_MODULE_REGISTRY)
