"""Activation-aware truncated SVD with junction matrices (paper §3.2–3.3).

``BAP = svd_r[WP]`` is only defined up to an invertible junction J
(B = USJ, A = J⁺VP⁺). The paper's observation: J = V₁ (the leading r×r
block of VP⁺, column-pivoted if singular) makes A = [I | V₁⁺V₂] — an
identity block that saves exactly r² parameters and FLOPs, turning
low-rank factorization into a guaranteed win for every r < min(d, d').
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro.core.precond import psd_pinv

JUNCTIONS = ("left", "right", "symmetric", "block_identity")


@dataclasses.dataclass
class LowRank:
    """Ŵ = B @ A_full, with A_full optionally structured as
    A_full[:, perm] = [I_r | A2] (block-identity junction)."""

    B: jnp.ndarray            # (d', r)
    A: jnp.ndarray            # (r, d) dense functional form
    A2: Optional[jnp.ndarray] = None    # (r, d-r) when block-identity
    perm: Optional[np.ndarray] = None   # column permutation, len d
    junction: str = "left"

    @property
    def rank(self) -> int:
        return self.B.shape[1]

    def reconstruct(self) -> jnp.ndarray:
        return self.B @ self.A

    def apply(self, X: jnp.ndarray) -> jnp.ndarray:
        """Ŵ X exploiting the identity block when present: (d, l) -> (d', l)."""
        if self.A2 is None:
            return self.B @ (self.A @ X)
        r = self.rank
        Xp = X[np.asarray(self.perm)]
        z = Xp[:r] + self.A2 @ Xp[r:]
        return self.B @ z

    def num_params(self) -> int:
        d_out, r = self.B.shape
        d = self.A.shape[1]
        if self.A2 is not None:
            return r * (d_out + d) - r * r  # paper §3.3
        return r * (d_out + d)


def _pivoted_leading_block(Vp: np.ndarray, r: int):
    """Column permutation making the leading r×r block of Vp (r,d)
    well-conditioned (Remark 4), via pivoted QR on Vp."""
    import scipy.linalg
    _, _, piv = scipy.linalg.qr(Vp, pivoting=True, mode="economic")
    perm = np.concatenate([piv[:r], np.sort(piv[r:])])
    return perm


def weighted_svd(W: jnp.ndarray, P: jnp.ndarray, r: int,
                 junction: str = "block_identity",
                 P_pinv: Optional[jnp.ndarray] = None) -> LowRank:
    """Rank-r activation-aware factorization of W (d'×d) under
    preconditioner P (d×d): minimizes ‖(W−BA)P‖²."""
    W = W.astype(jnp.float32)
    Wp = W @ P
    U, s, Vt = jnp.linalg.svd(Wp, full_matrices=False)
    U, s, Vt = U[:, :r], s[:r], Vt[:r]
    if P_pinv is None:
        if P.ndim == 2 and jnp.count_nonzero(P - jnp.diag(jnp.diag(P))) == 0:
            dp = jnp.diag(P)
            P_pinv = jnp.diag(jnp.where(dp > 1e-12, 1.0 / dp, 0.0))
        else:
            P_pinv = psd_pinv(P)
    Vp = Vt @ P_pinv  # (r, d) whitened right factor mapped back

    if junction == "left":  # J = I
        return LowRank(B=U * s[None, :], A=Vp, junction=junction)
    if junction == "right":  # J = S⁺
        return LowRank(B=U, A=s[:, None] * Vp, junction=junction)
    if junction == "symmetric":  # J = (S^{1/2})⁺
        rs = jnp.sqrt(s)
        return LowRank(B=U * rs[None, :], A=rs[:, None] * Vp,
                       junction=junction)
    if junction == "block_identity":
        Vp_np = np.asarray(Vp)
        d = Vp_np.shape[1]
        perm = np.arange(d)
        V1 = Vp_np[:, :r]
        # pivot when the leading block is ill-conditioned
        if r > 0 and (np.linalg.matrix_rank(V1) < r
                      or np.linalg.cond(V1) > 1e6):
            perm = _pivoted_leading_block(Vp_np, r)
        Vp_perm = Vp_np[:, perm]
        V1 = Vp_perm[:, :r]
        V1_inv = np.linalg.pinv(V1)
        A2 = jnp.asarray(V1_inv @ Vp_perm[:, r:])       # (r, d-r)
        B = (U * s[None, :]) @ jnp.asarray(V1)          # B = U S J, J = V₁
        # dense functional A (identity block under the permutation)
        A_perm = jnp.concatenate([jnp.eye(r, dtype=jnp.float32), A2], axis=1)
        inv_perm = np.argsort(perm)
        A = A_perm[:, inv_perm]
        return LowRank(B=B, A=A, A2=A2, perm=perm, junction=junction)
    raise ValueError(f"unknown junction {junction!r}")


def activation_loss(W: jnp.ndarray, lr: LowRank, P: jnp.ndarray) -> float:
    """‖(W − BA)P‖² — the quantity the factorization minimizes."""
    R = (W.astype(jnp.float32) - lr.reconstruct()) @ P
    return float(jnp.sum(R * R))
