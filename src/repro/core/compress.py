"""LatentLLM model compression driver.

Walks a trained model's group-structured params layer-by-layer
(GPTQ/SparseLLM-style sequential calibration: layer ℓ is compressed, then
the COMPRESSED activations propagate to layer ℓ+1), producing a latent
params tree that loads into ``transformer.forward`` with
``cfg.latent.enabled``.

Methods (same latent structure, so #params are identical across methods —
only the solution differs):
  plain / asvd_hessian / asvd_l1 / asvd_l2 / asvd_cov / asvd_rootcov:
      local activation-aware SVD per projection (shared-A-over-heads).
  latentllm:
      rootcov + attention-aware joint QK (Alg. 1) + split VO with
      attention-aware C_o + joint UD for ReLU MLPs (App. H).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, dtype_of
from repro.core import ranks as ranks_lib
from repro.core.joint_qk import joint_qk_svd
from repro.core.joint_vo import split_vo
from repro.core.mlp_ud import joint_ud, local_ud
from repro.core.precond import (activation_stats, preconditioner, psd_pinv,
                                psd_sqrt)
from repro.core.svd import weighted_svd
from repro.models import layers as L
from repro.models import transformer as T

Params = Dict[str, Any]

METHODS = ("plain", "asvd_hessian", "asvd_l1", "asvd_l2", "asvd_cov",
           "asvd_rootcov", "latentllm")

_PRECOND = {
    "plain": "identity", "asvd_hessian": "hessian", "asvd_l1": "l1",
    "asvd_l2": "l2", "asvd_cov": "cov", "asvd_rootcov": "rootcov",
    "latentllm": "rootcov",
}


def _stats_of(h: jnp.ndarray, damping: float):
    """h: (B, S, d) -> (X (d, l), C, mu)."""
    X = h.astype(jnp.float32).reshape(-1, h.shape[-1]).T
    C, mu = activation_stats(X, damping)
    return X, C, mu


def _precond_pair(kind, X, C, damping):
    P = preconditioner(kind, X=X, C=C, damping=damping)
    if kind in ("identity",):
        return P, P
    if kind in ("hessian", "l1", "l2"):
        d = jnp.diag(P)
        return P, jnp.diag(jnp.where(d > 1e-12, 1.0 / d, 0.0))
    return P, psd_pinv(P)


# ----------------------------------------------------------------------
# per-module compressors
# ----------------------------------------------------------------------

def _compress_attention(p_attn: Params, cfg: ModelConfig, h: jnp.ndarray,
                        method: str, rk: Dict[str, int]) -> Params:
    d, H, Hk, dh = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    damp = cfg.latent.damping
    X, C, mu = _stats_of(h, damp)
    kind = _PRECOND[method]
    P, P_pinv = _precond_pair(kind, X, C, damp)

    Wq = p_attn["q"]["w"].T.reshape(H, dh, d)
    Wk = p_attn["k"]["w"].T.reshape(Hk, dh, d)
    Wv = p_attn["v"]["w"].T.reshape(Hk, dh, d)
    Wo = p_attn["o"]["w"].T  # (d, H*dh)
    bq = p_attn["q"].get("b")
    bk = p_attn["k"].get("b")
    bv = p_attn["v"].get("b")
    bo = p_attn["o"].get("b")
    if bq is not None:
        bq = bq.reshape(H, dh)
        bk = bk.reshape(Hk, dh)

    out: Params = {}
    if method == "latentllm" and cfg.latent.joint_qk:
        jqk = joint_qk_svd(Wq, Wk, P, rk["r_q"], rk["r_k"],
                           iters=cfg.latent.qk_iters, bq=bq, bk=bk, mu=mu,
                           C0=C if bq is not None else None, P_pinv=P_pinv)
        A_q, A_k, B_q, B_k = jqk.A_q, jqk.A_k, jqk.B_q, jqk.B_k
        nbq, nbk = jqk.b_q, jqk.b_k
    else:  # local: shared-A joint-head ASVD per projection
        lrq = weighted_svd(Wq.reshape(H * dh, d), P, rk["r_q"],
                           junction="left", P_pinv=P_pinv)
        lrk = weighted_svd(Wk.reshape(Hk * dh, d), P, rk["r_k"],
                           junction="left", P_pinv=P_pinv)
        A_q, B_q = lrq.A, lrq.B.reshape(H, dh, rk["r_q"])
        A_k, B_k = lrk.A, lrk.B.reshape(Hk, dh, rk["r_k"])
        nbq, nbk = bq, bk

    vo = split_vo(Wv, Wo, P, rk["r_v"], rk["r_o"],
                  C=C if method == "latentllm" else None,
                  bv=bv.reshape(Hk, dh) if bv is not None else None,
                  bo=bo, mu=mu, P_pinv=P_pinv)

    out["a_q"] = A_q.T.astype(jnp.float32)
    out["a_k"] = A_k.T.astype(jnp.float32)
    out["a_v"] = vo.A_v.T.astype(jnp.float32)
    out["b_q"] = jnp.transpose(B_q, (0, 2, 1)).astype(jnp.float32)
    out["b_k"] = jnp.transpose(B_k, (0, 2, 1)).astype(jnp.float32)
    out["b_v"] = jnp.transpose(vo.B_v, (0, 2, 1)).astype(jnp.float32)
    out["a_o"] = vo.A_o.T.astype(jnp.float32)
    out["b_o"] = vo.B_o.T.astype(jnp.float32)
    if cfg.qkv_bias:
        out["bias_q"] = (nbq if nbq is not None else jnp.zeros((H, dh))).reshape(-1)
        out["bias_k"] = (nbk if nbk is not None else jnp.zeros((Hk, dh))).reshape(-1)
        out["bias_v"] = (bv if bv is not None else jnp.zeros((Hk * dh,))).reshape(-1)
    if cfg.o_bias:
        out["bias_o"] = bo if bo is not None else jnp.zeros((d,))
    return out


def _compress_mlp(p_mlp: Params, cfg: ModelConfig, h: jnp.ndarray,
                  method: str, rk: Dict[str, int]) -> Params:
    damp = cfg.latent.damping
    X, C, mu = _stats_of(h, damp)
    kind = _PRECOND[method]
    P, P_pinv = _precond_pair(kind, X, C, damp)
    junction = "left"

    Wu = p_mlp["up"]["w"].T      # (F, d)
    Wd = p_mlp["down"]["w"].T    # (d, F)
    bu = p_mlp["up"].get("b")
    bd = p_mlp["down"].get("b")
    out: Params = {}

    gated = "gate" in p_mlp
    use_joint = (method == "latentllm" and cfg.latent.joint_ud
                 and cfg.activation == "relu" and not gated)
    if use_joint:
        ud = joint_ud(Wu, Wd, X, rk["r_u"], rk["r_d"], act=cfg.activation,
                      iters=cfg.latent.ud_iters, bu=bu, bd=bd,
                      junction=junction, damping=damp)
        out["up_a"], out["up_b"] = ud.up.A.T, ud.up.B.T
        out["down_a"], out["down_b"] = ud.down.A.T, ud.down.B.T
        if cfg.mlp_bias:
            out["up_bias"], out["down_bias"] = ud.b_u, ud.b_d
        return out

    lru = weighted_svd(Wu, P, rk["r_u"], junction=junction, P_pinv=P_pinv)
    out["up_a"], out["up_b"] = lru.A.T, lru.B.T
    if gated:
        Wg = p_mlp["gate"]["w"].T
        lrg = weighted_svd(Wg, P, rk["r_u"], junction=junction, P_pinv=P_pinv)
        out["gate_a"], out["gate_b"] = lrg.A.T, lrg.B.T
    # hidden statistics for the down projection
    act_fn = {"relu": jax.nn.relu, "gelu": jax.nn.gelu,
              "silu": jax.nn.silu}[cfg.activation]
    u = (Wu @ X + (bu[:, None] if bu is not None else 0.0))
    if gated:
        g = p_mlp["gate"]["w"].T.astype(jnp.float32) @ X
        A_hidden = u * act_fn(g)
    else:
        A_hidden = act_fn(u)
    Ca, _ = activation_stats(A_hidden, damp)
    Pa, Pa_pinv = _precond_pair(kind if kind != "l1" else "l2", A_hidden, Ca, damp)
    lrd = weighted_svd(Wd, Pa, rk["r_d"], junction=junction, P_pinv=Pa_pinv)
    out["down_a"], out["down_b"] = lrd.A.T, lrd.B.T
    if cfg.mlp_bias:
        out["up_bias"] = bu if bu is not None else jnp.zeros((Wu.shape[0],))
        out["down_bias"] = bd if bd is not None else jnp.zeros((Wd.shape[0],))
        if gated:
            out["gate_bias"] = p_mlp["gate"].get(
                "b", jnp.zeros((Wu.shape[0],)))
    return out


def _compress_ssd(p_ssd: Params, cfg: ModelConfig, h: jnp.ndarray,
                  method: str, rk: Dict[str, int]) -> Params:
    """Latent SSM: factor in/out projections (QK/VO are N/A — DESIGN §5)."""
    damp = cfg.latent.damping
    X, C, mu = _stats_of(h, damp)
    kind = _PRECOND[method]
    P, P_pinv = _precond_pair(kind, X, C, damp)
    Win = p_ssd["in_proj"]["w"].T   # (proj_out, d)
    lri = weighted_svd(Win, P, rk["r_in"], junction="left", P_pinv=P_pinv)
    out = dict(p_ssd)
    out["in_proj"] = {"a": lri.A.T, "b": lri.B.T}
    # out_proj input: gated y — recompute internals for its statistics
    y_in = _ssd_out_input(p_ssd, h, cfg)
    Xo, Co, _ = _stats_of(y_in, damp)
    Po, Po_pinv = _precond_pair(kind if kind != "l1" else "l2", Xo, Co, damp)
    Wout = p_ssd["out_proj"]["w"].T  # (d, d_i)
    lro = weighted_svd(Wout, Po, rk["r_out"], junction="left", P_pinv=Po_pinv)
    out["out_proj"] = {"a": lro.A.T, "b": lro.B.T}
    return out


def _ssd_out_input(p: Params, x: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    """Replicates layers.ssd_fwd up to the out_proj input."""
    B, S, d = x.shape
    di, G, N = cfg.d_inner, cfg.ssm_ngroups, cfg.ssm_state
    Hs, Pd = cfg.ssm_nheads, cfg.ssm_head_dim
    W = cfg.ssm_conv_width
    zxbcdt = L.dense(p["in_proj"], x)
    z, xbc, dt = jnp.split(zxbcdt, [di, di + di + 2 * G * N], axis=-1)
    conv_in = jnp.pad(xbc, ((0, 0), (W - 1, 0), (0, 0)))
    xbc = L._causal_conv(conv_in, p["conv_w"], p["conv_b"], S)
    xbc = jax.nn.silu(xbc)
    xs, Bm, Cm = jnp.split(xbc, [di, di + G * N], axis=-1)
    xh = xs.reshape(B, S, Hs, Pd)
    Bm = Bm.reshape(B, S, G, N)
    Cm = Cm.reshape(B, S, G, N)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    y, _ = L._ssd_chunked(xh, dt, A, Bm, Cm, min(cfg.ssm_chunk, S))
    y = y + xh * p["D"].astype(x.dtype)[None, None, :, None]
    y = y.reshape(B, S, di)
    return L.norm_fwd(p["norm"], y) * jax.nn.silu(z)


# ----------------------------------------------------------------------
# driver
# ----------------------------------------------------------------------

def compress_model(params: Params, cfg: ModelConfig,
                   batch: Dict[str, jnp.ndarray],
                   method: str = "latentllm") -> Tuple[Params, Dict]:
    """Sequential layer-by-layer compression with activation propagation.

    ``batch``: calibration tokens {'tokens': (B, S)} (or frames).
    Returns (latent_params, report)."""
    assert method in METHODS, method
    latent_cfg = dataclasses.replace(
        cfg, latent=dataclasses.replace(cfg.latent, enabled=True))
    rk = ranks_lib.latent_ranks(cfg)
    group, n, trailing = T.group_spec(cfg)
    comp_dtype = dtype_of(cfg)

    tokens = batch.get("tokens")
    frames = batch.get("frames")
    if frames is not None:
        x = frames.astype(comp_dtype)
    else:
        x = params["embed"].astype(comp_dtype)[tokens]
    B, S = x.shape[:2]
    positions = jnp.arange(S, dtype=jnp.int32)
    if cfg.pos_emb == "learned":
        x = x + params["pos_embed"].astype(comp_dtype)[positions]

    new_groups: List[Params] = []
    shared_latent: Optional[Params] = None
    shared_stats: List[jnp.ndarray] = []
    report = {"blocks": 0, "method": method}

    def compress_block(p_blk: Params, desc, x):
        nonlocal shared_latent
        if desc.kind == "ssd":
            h = L.norm_fwd(p_blk["ln"], x)
            new_blk = {"ln": p_blk["ln"],
                       "ssd": _compress_ssd(p_blk["ssd"], cfg, h, method, rk)}
        elif desc.kind == "shared_attn":
            new_blk = {}
        else:
            h1 = L.norm_fwd(p_blk["ln1"], x)
            new_attn = _compress_attention(p_blk["attn"], cfg, h1, method, rk)
            # propagate through compressed attention for the MLP stats
            lat_blk = {"ln1": p_blk["ln1"], "ln2": p_blk["ln2"],
                       "attn": new_attn}
            y, _ = L.latent_attention_fwd(new_attn, h1, latent_cfg,
                                          positions=positions,
                                          window=desc.window)
            x_mid = x + y
            h2 = L.norm_fwd(p_blk["ln2"], x_mid)
            if "moe" in p_blk:
                lat_blk["moe"] = p_blk["moe"]  # experts stay dense (DESIGN §5)
            else:
                lat_blk["mlp"] = _compress_mlp(p_blk["mlp"], cfg, h2,
                                               method, rk)
            new_blk = lat_blk
        report["blocks"] += 1
        return new_blk

    def run_block(p_new: Params, desc, x):
        """Forward through the compressed block (sequential propagation)."""
        nonlocal shared_latent
        if desc.kind == "shared_attn":
            blk = shared_latent
        else:
            blk = p_new
        if desc.kind == "ssd":
            h = L.norm_fwd(blk["ln"], x)
            if "a" in blk["ssd"]["in_proj"]:
                y, _ = T._ssd_fwd_factored(blk["ssd"], h, cfg, None)
            else:
                y, _ = L.ssd_fwd(blk["ssd"], h, cfg)
            return x + y
        h = L.norm_fwd(blk["ln1"], x)
        y, _ = L.latent_attention_fwd(blk["attn"], h, latent_cfg,
                                      positions=positions, window=desc.window)
        x = x + y
        h2 = L.norm_fwd(blk["ln2"], x)
        if "moe" in blk:
            y2, _ = L.moe_fwd(blk["moe"], h2, cfg)
        else:
            y2 = L.latent_mlp_fwd(blk["mlp"], h2, latent_cfg)
        return x + y2

    # compress the zamba-style shared block against its first application
    shared_desc = T.BlockDesc("attn", window=None, moe=False)

    for g in range(n):
        new_blocks = []
        for bi, desc in enumerate(group):
            p_blk = jax.tree.map(lambda a: a[g], params["groups"][bi])
            if desc.kind == "shared_attn":
                if shared_latent is None:
                    shared_latent = compress_block(
                        params["shared_block"], shared_desc, x)
                new_blk = {}
            else:
                new_blk = compress_block(p_blk, desc, x)
            x = run_block(new_blk, desc, x)
            new_blocks.append(new_blk)
        new_groups.append(new_blocks)

    new_trailing = []
    for i, desc in enumerate(trailing):
        new_blk = compress_block(params["trailing"][i], desc, x)
        x = run_block(new_blk, desc, x)
        new_trailing.append(new_blk)

    # restack group params
    stacked = []
    for bi in range(len(group)):
        blocks = [new_groups[g][bi] for g in range(n)]
        stacked.append(jax.tree.map(lambda *a: jnp.stack(a), *blocks))

    new_params = dict(params)
    new_params["groups"] = stacked
    new_params["trailing"] = new_trailing
    if shared_latent is not None:
        new_params["shared_block"] = shared_latent
    return new_params, report
