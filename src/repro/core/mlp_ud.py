"""Joint Up/Down MLP compression (paper §4.3, App. H — SparseLLM-style).

Decouples the nonlinearity with auxiliary variables (Z, Z'):
    L = α‖W_u X − Z‖² + β‖Z' − σ(Z)‖² + γ‖W_d Z' − Y‖²
Alternates: closed-form Z' (ridge), closed-form Z (exact for ReLU,
elementwise branch cost), then activation-aware SVD of the EFFECTIVE maps
Ŵ_u ← svd[(Z−b̂_u)X⁺ C_x^{1/2}], Ŵ_d ← svd[(Y−b_d)Z'⁺ C_a^{1/2}].

For non-ReLU activations (SiLU/GELU archs) the Z-update uses the damped
convex combination (the ReLU closed form's z₊ branch) — documented
approximation; the paper's OPT testbed is ReLU where this is exact.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.precond import activation_stats, psd_pinv, psd_sqrt
from repro.core.svd import LowRank, weighted_svd


@dataclasses.dataclass
class JointUD:
    up: LowRank
    down: LowRank
    b_u: Optional[jnp.ndarray]
    b_d: Optional[jnp.ndarray]
    losses: Optional[List[float]] = None


def _ridge_solve(WtW: jnp.ndarray, rhs: jnp.ndarray, beta: float) -> jnp.ndarray:
    d = WtW.shape[0]
    return jnp.linalg.solve(WtW + beta * jnp.eye(d, dtype=jnp.float32), rhs)


def _relu_z_update(z_lin, z_prime, alpha, beta):
    """Exact elementwise minimizer of α(z−z₋)² + β(z'−σ(z))² for ReLU."""
    z_pos = (alpha * z_lin + beta * z_prime) / (alpha + beta)
    z_pos = jnp.maximum(z_pos, 0.0)
    cost_pos = alpha * (z_pos - z_lin) ** 2 + beta * (z_prime - z_pos) ** 2
    z_neg = jnp.minimum(z_lin, 0.0)
    cost_neg = alpha * (z_neg - z_lin) ** 2 + beta * z_prime ** 2
    return jnp.where(cost_pos <= cost_neg, z_pos, z_neg)


def joint_ud(
    Wu: jnp.ndarray,            # (d_i, d)
    Wd: jnp.ndarray,            # (d, d_i)
    X: jnp.ndarray,             # (d, l) calibration input
    r_u: int,
    r_d: int,
    act: str = "relu",
    iters: int = 4,
    alpha: float = 1.0,
    beta: float = 1.0,
    gamma: float = 1.0,
    bu: Optional[jnp.ndarray] = None,
    bd: Optional[jnp.ndarray] = None,
    junction: str = "left",
    damping: float = 1e-2,
) -> JointUD:
    act_fn = {"relu": jax.nn.relu, "gelu": jax.nn.gelu,
              "silu": jax.nn.silu}[act]
    Wu32, Wd32 = Wu.astype(jnp.float32), Wd.astype(jnp.float32)
    X = X.astype(jnp.float32)
    d_i, d = Wu32.shape
    bu_ = jnp.zeros((d_i,)) if bu is None else bu.astype(jnp.float32)
    bd_ = jnp.zeros((d,)) if bd is None else bd.astype(jnp.float32)

    # teacher targets
    Z_t = Wu32 @ X + bu_[:, None]
    Y = Wd32 @ act_fn(Z_t) + bd_[:, None]

    # input stats (fixed)
    Cx, mu_x = activation_stats(X, damping)
    Px = psd_sqrt(Cx)
    Cx_pinv = psd_pinv(Cx)

    # current estimates
    up = weighted_svd(Wu32, Px, r_u, junction=junction)
    b_u = bu_
    Wd_hat, b_d = Wd32, bd_
    down = None
    Z = up.reconstruct() @ X + b_u[:, None]
    losses: List[float] = []

    WtW = Wd32.T @ Wd32  # for the Z' ridge (γ WᵀW + βI)
    for _ in range(iters):
        # ---- Z' closed form (Eq. 21) -------------------------------
        rhs = beta * act_fn(Z) + gamma * (Wd_hat.T @ (Y - b_d[:, None]))
        WtW_cur = Wd_hat.T @ Wd_hat
        Zp = _ridge_solve(gamma * WtW_cur, rhs, beta)
        # ---- Z closed form (Eq. 22; exact for ReLU) ----------------
        z_lin = up.reconstruct() @ X + b_u[:, None]
        if act == "relu":
            Z = _relu_z_update(z_lin, Zp, alpha, beta)
        else:
            Z = (alpha * z_lin + beta * Zp) / (alpha + beta)
        # ---- refit Ŵ_u from effective map X -> Z -------------------
        W_eff_u = (Z @ X.T) @ Cx_pinv / X.shape[1]
        up = weighted_svd(W_eff_u, Px, r_u, junction=junction)
        b_u = jnp.mean(Z, axis=1) - up.reconstruct() @ mu_x
        # ---- refit Ŵ_d from effective map Z' -> Y ------------------
        Ca, mu_a = activation_stats(Zp, damping)
        Pa = psd_sqrt(Ca)
        Ca_pinv = psd_pinv(Ca)
        W_eff_d = ((Y - bd_[:, None]) @ Zp.T) @ Ca_pinv / Zp.shape[1]
        down = weighted_svd(W_eff_d, Pa, r_d, junction=junction)
        Wd_hat = down.reconstruct()
        b_d = jnp.mean(Y, axis=1) - Wd_hat @ mu_a
        # ---- track the true MLP output loss ------------------------
        z_now = up.reconstruct() @ X + b_u[:, None]
        y_now = Wd_hat @ act_fn(z_now) + b_d[:, None]
        losses.append(float(jnp.mean(jnp.sum((Y - y_now) ** 2, axis=0))))

    return JointUD(up=up, down=down, b_u=b_u, b_d=b_d, losses=losses)


def local_ud(Wu, Wd, X, r_u, r_d, act="relu", bu=None, bd=None,
             junction="left", damping=1e-2) -> JointUD:
    """Baseline: independent activation-aware SVD of W_u and W_d (the
    'local' compression every prior method uses)."""
    act_fn = {"relu": jax.nn.relu, "gelu": jax.nn.gelu,
              "silu": jax.nn.silu}[act]
    Wu32, Wd32 = Wu.astype(jnp.float32), Wd.astype(jnp.float32)
    X = X.astype(jnp.float32)
    bu_ = jnp.zeros((Wu32.shape[0],)) if bu is None else bu.astype(jnp.float32)
    bd_ = jnp.zeros((Wd32.shape[0],)) if bd is None else bd.astype(jnp.float32)
    Cx, _ = activation_stats(X, damping)
    Px = psd_sqrt(Cx)
    up = weighted_svd(Wu32, Px, r_u, junction=junction)
    A = act_fn(Wu32 @ X + bu_[:, None])
    Ca, _ = activation_stats(A, damping)
    Pa = psd_sqrt(Ca)
    down = weighted_svd(Wd32, Pa, r_d, junction=junction)
    return JointUD(up=up, down=down, b_u=bu_, b_d=bd_)


def mlp_output_loss(Wu, Wd, ud: JointUD, X, act="relu", bu=None, bd=None) -> float:
    act_fn = {"relu": jax.nn.relu, "gelu": jax.nn.gelu,
              "silu": jax.nn.silu}[act]
    X = X.astype(jnp.float32)
    bu_ = jnp.zeros((Wu.shape[0],)) if bu is None else bu.astype(jnp.float32)
    bd_ = jnp.zeros((Wd.shape[0],)) if bd is None else bd.astype(jnp.float32)
    Y = Wd.astype(jnp.float32) @ act_fn(Wu.astype(jnp.float32) @ X + bu_[:, None]) + bd_[:, None]
    z = ud.up.reconstruct() @ X + ud.b_u[:, None]
    y = ud.down.reconstruct() @ act_fn(z) + ud.b_d[:, None]
    return float(jnp.mean(jnp.sum((Y - y) ** 2, axis=0)))
