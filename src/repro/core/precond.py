"""Pre-conditioning matrices for activation-aware SVD (paper §3.2, Tab. 1).

The paper's result: minimizing E‖WX − BAX‖² is EXACTLY the truncated SVD
of W·C^{1/2} with C = XXᵀ + λI — i.e. the optimal preconditioner is the
root-covariance. All published variants (GPTQ's diag-Hessian, ASVD/AWQ's
diag-ℓ1, WandA's diag-ℓ2, CorDA's full covariance) are implemented for
the baseline comparisons in Tab. 2 / Fig. 7.

PSD matrix functions go through eigh — symmetric eigendecomposition is
the numerically robust (and TPU-friendly) primitive here.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

KINDS = ("identity", "hessian", "l1", "l2", "cov", "rootcov")


def activation_stats(X: jnp.ndarray, damping: float = 1e-2
                     ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """X: (d, l) calibration activations -> (C, mu).

    C = XXᵀ/l + λ·mean(diag)·I  (damped, scale-normalized — Remark 3)."""
    d, l = X.shape
    X = X.astype(jnp.float32)
    C = (X @ X.T) / l
    lam = damping * jnp.mean(jnp.diag(C)) + 1e-12
    C = C + lam * jnp.eye(d, dtype=jnp.float32)
    mu = jnp.mean(X, axis=1)
    return C, mu


def psd_sqrt(C: jnp.ndarray) -> jnp.ndarray:
    w, V = jnp.linalg.eigh(C)
    w = jnp.clip(w, 0.0)
    return (V * jnp.sqrt(w)[None, :]) @ V.T


def psd_inv_sqrt(C: jnp.ndarray, rel_eps: float = 1e-10) -> jnp.ndarray:
    w, V = jnp.linalg.eigh(C)
    thresh = jnp.max(w) * rel_eps
    inv_sqrt = jnp.where(w > thresh, 1.0 / jnp.sqrt(jnp.clip(w, thresh)), 0.0)
    return (V * inv_sqrt[None, :]) @ V.T


def psd_pinv(C: jnp.ndarray, rel_eps: float = 1e-10) -> jnp.ndarray:
    w, V = jnp.linalg.eigh(C)
    thresh = jnp.max(w) * rel_eps
    inv = jnp.where(w > thresh, 1.0 / jnp.clip(w, thresh), 0.0)
    return (V * inv[None, :]) @ V.T


def preconditioner(kind: str, X: Optional[jnp.ndarray] = None,
                   C: Optional[jnp.ndarray] = None,
                   damping: float = 1e-2) -> jnp.ndarray:
    """Tab. 1 variants. Pass raw activations X (d,l) or a covariance C."""
    if C is None:
        assert X is not None
        C, _ = activation_stats(X, damping)
    d = C.shape[0]
    if kind == "identity":
        return jnp.eye(d, dtype=jnp.float32)
    if kind == "rootcov":
        return psd_sqrt(C)
    if kind == "cov":
        return C
    if kind == "l2":
        return jnp.diag(jnp.sqrt(jnp.diag(C)))
    if kind == "l1":
        assert X is not None, "diag-ℓ1 needs raw activations"
        return jnp.diag(jnp.sum(jnp.abs(X.astype(jnp.float32)), axis=1)
                        / X.shape[1] + 1e-12)
    if kind == "hessian":
        # OBS/GPTQ/SparseGPT: diag[(XXᵀ+λI)^{-1}]^{-1/2}
        Cinv = psd_pinv(C)
        return jnp.diag(1.0 / jnp.sqrt(jnp.clip(jnp.diag(Cinv), 1e-12)))
    raise ValueError(f"unknown preconditioner {kind!r}")
