"""Sparse and low-rank+sparse decomposition (paper App. I).

Three solvers for Ŵ = BA + D with ‖D‖₀ ≤ κ under the activation metric
‖(Ŵ−W)C^{1/2}‖²:
  - hardshrink: alternating truncated-SVD / top-κ magnitude selection with
    exact re-fit of the kept entries' values by one proximal step
    (the paper found hard shrinkage works best, Fig. 13);
  - fista: ℓ1-relaxed proximal gradient with Nesterov acceleration
    (Eqs. 233–236);
  - sparse_only: κ-sparse approximation without the low-rank part — the
    paper's observation (Fig. 14) that sparse-alone can beat
    low-rank+sparse at matched parameter budget is reproduced in
    benchmarks/appi_sparse.py.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.precond import psd_sqrt
from repro.core.svd import weighted_svd


@dataclasses.dataclass
class LowRankSparse:
    B: Optional[jnp.ndarray]      # (d', r) or None for sparse-only
    A: Optional[jnp.ndarray]      # (r, d)
    D: jnp.ndarray                # (d', d) sparse
    losses: Optional[List[float]] = None

    def reconstruct(self) -> jnp.ndarray:
        out = self.D
        if self.B is not None:
            out = out + self.B @ self.A
        return out

    def nnz(self) -> int:
        return int(jnp.sum(self.D != 0))


def _topk_mask(x: jnp.ndarray, k: int) -> jnp.ndarray:
    """Keep the k largest-magnitude entries (hard shrinkage Sκ)."""
    flat = jnp.abs(x).reshape(-1)
    if k >= flat.size:
        return jnp.ones_like(x, bool)
    thresh = jnp.sort(flat)[-k]
    return jnp.abs(x) >= thresh


def sparse_only(W: jnp.ndarray, C: jnp.ndarray, k: int,
                iters: int = 20, lr: float = None) -> LowRankSparse:
    """min ‖(D−W)C^{1/2}‖² s.t. ‖D‖₀≤k — proximal gradient with hard
    shrinkage (the paper's best-performing variant)."""
    W = W.astype(jnp.float32)
    C = C.astype(jnp.float32)
    # Lipschitz constant of ∇ = 2·λmax(C)
    lmax = jnp.linalg.eigvalsh(C)[-1]
    step = 1.0 / (2 * lmax) if lr is None else lr
    D = jnp.where(_topk_mask(W, k), W, 0.0)
    losses = []
    for _ in range(iters):
        grad = 2.0 * (D - W) @ C
        D = D - step * grad
        D = jnp.where(_topk_mask(D, k), D, 0.0)
        R = (D - W)
        losses.append(float(jnp.trace(R @ C @ R.T)))
    return LowRankSparse(B=None, A=None, D=D, losses=losses)


def lowrank_plus_sparse_hard(W: jnp.ndarray, C: jnp.ndarray, r: int, k: int,
                             iters: int = 8) -> LowRankSparse:
    """Alternate: (BA) = svd_r[(W−D)C^{1/2}] ; D = prox-step + hard κ."""
    W = W.astype(jnp.float32)
    P = psd_sqrt(C)
    lmax = jnp.linalg.eigvalsh(C.astype(jnp.float32))[-1]
    step = 1.0 / (2 * lmax)
    D = jnp.zeros_like(W)
    losses = []
    lr_part = None
    for _ in range(iters):
        lr_part = weighted_svd(W - D, P, r, junction="left")
        BA = lr_part.reconstruct()
        grad = 2.0 * (D + BA - W) @ C.astype(jnp.float32)
        D = D - step * grad
        D = jnp.where(_topk_mask(D, k), D, 0.0)
        R = (BA + D - W)
        losses.append(float(jnp.trace(R @ C @ R.T)))
    return LowRankSparse(B=lr_part.B, A=lr_part.A, D=D, losses=losses)


def lowrank_plus_sparse_fista(W: jnp.ndarray, C: jnp.ndarray, r: int,
                              lam: float, iters: int = 25) -> LowRankSparse:
    """Eqs. 233–236: FISTA on D with soft shrinkage, SVD refit outside."""
    W = W.astype(jnp.float32)
    C32 = C.astype(jnp.float32)
    P = psd_sqrt(C)
    lmax = jnp.linalg.eigvalsh(C32)[-1]
    mu = 1.0 / (2 * lmax)
    lr_part = weighted_svd(W, P, r, junction="left")
    D = jnp.zeros_like(W)
    D_prev = D
    t = 1.0
    losses = []
    for _ in range(iters):
        BA = lr_part.reconstruct()
        grad = 2.0 * (D + BA - W) @ C32
        z = D - mu * grad
        D_new = jnp.sign(z) * jnp.maximum(jnp.abs(z) - lam * mu, 0.0)
        t_new = 0.5 * (1 + (1 + 4 * t * t) ** 0.5)
        D = D_new + ((t - 1) / t_new) * (D_new - D_prev)
        D_prev, t = D_new, t_new
        lr_part = weighted_svd(W - D_new, P, r, junction="left")
        R = (lr_part.reconstruct() + D_new - W)
        losses.append(float(jnp.trace(R @ C32 @ R.T)))
    return LowRankSparse(B=lr_part.B, A=lr_part.A, D=D_prev, losses=losses)


def weighted_loss(W: jnp.ndarray, approx: jnp.ndarray, C: jnp.ndarray) -> float:
    R = (approx - W).astype(jnp.float32)
    return float(jnp.trace(R @ C.astype(jnp.float32) @ R.T))
