"""Symmetric int8 row quantization for the latent KV cache.

The latent cache stores one c_k/c_v row per token; each row is a small
rank-r vector shared by every head in the group, so the natural
quantization block is the ROW: ``q = round(c / scale)`` with one fp32
scale per (slot, row) — ``scale = max|c| / 127``. Stored leaves become
int8 ``c_k``/``c_v`` siblings plus ``ck_scale``/``cv_scale`` fp32
``(..., 1)`` columns that flow through the same generic tree scatters
the fp cache uses (arena admission, paged block gather/scatter, ring
writes).

Guards (both property-tested):

* zero rows — a zero scale would divide 0/0; the divisor is clamped to
  1 so zero rows round-trip to exact zeros;
* non-finite inputs — NaN/Inf contaminate the row max and then every
  element of the row; non-finite entries are zeroed BEFORE the absmax
  so one poisoned element cannot blank a row (the serving engine's NaN
  quarantine handles the request-level response).

Dequantization error is bounded by scale/2 = max|c|/254 per element —
the bound ``|c - deq(q)| <= max|c|/253`` is asserted in tests with the
rounding slack.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax.numpy as jnp

INT8_MAX = 127

__all__ = ["INT8_MAX", "quantize_rows", "dequantize_rows",
           "quantize_cache_entry", "dequantize_cache_entry",
           "is_quantized_cache"]


def quantize_rows(c: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """(int8 values, fp32 scales) with one scale per trailing row.

    ``c`` is ``(..., r)``; scales come back ``(..., 1)`` so they
    broadcast against the row on dequantization.
    """
    c32 = jnp.where(jnp.isfinite(c), c, 0.0).astype(jnp.float32)
    scale = jnp.max(jnp.abs(c32), axis=-1, keepdims=True) / INT8_MAX
    div = jnp.where(scale > 0, scale, 1.0)
    q = jnp.clip(jnp.round(c32 / div), -INT8_MAX, INT8_MAX).astype(jnp.int8)
    return q, scale


def dequantize_rows(q: jnp.ndarray, scale: jnp.ndarray,
                    dtype=jnp.float32) -> jnp.ndarray:
    return (q.astype(jnp.float32) * scale).astype(dtype)


def quantize_cache_entry(c_k: jnp.ndarray, c_v: jnp.ndarray
                         ) -> Dict[str, jnp.ndarray]:
    """Fresh latent rows -> the int8 cache leaf dict layers.py stores."""
    qk, sk = quantize_rows(c_k)
    qv, sv = quantize_rows(c_v)
    return {"c_k": qk, "ck_scale": sk, "c_v": qv, "cv_scale": sv}


def dequantize_cache_entry(cache: Dict[str, Any], dtype=jnp.float32
                           ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """(c_k, c_v) in ``dtype`` from an int8 cache leaf dict."""
    return (dequantize_rows(cache["c_k"], cache["ck_scale"], dtype),
            dequantize_rows(cache["c_v"], cache["cv_scale"], dtype))


def is_quantized_cache(cache: Dict[str, Any]) -> bool:
    return isinstance(cache, dict) and "ck_scale" in cache
