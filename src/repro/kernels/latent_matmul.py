"""Pallas TPU kernel: block-identity latent matmul (paper §3.3).

Computes ``y = B · (x_id + x_rest @ A2ᵀ)`` — the compressed projection
with junction J = V₁, where the identity block never touches the MXU
(that is the r² FLOP saving the paper proves always exists).

One generic tiled ``matmul_init`` primitive is instantiated twice:
  stage 1:  z = x_id + x_rest @ a2t      (init = x_id block)
  stage 2:  y = z @ b                    (init = 0)

Tiling: grid (M/bm, N/bn, K/bk); K innermost ("arbitrary") accumulating
into fp32 VMEM scratch; MXU-aligned tiles; HBM→VMEM streaming via
BlockSpec.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams; support both.
_CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or pltpu.TPUCompilerParams


def _tile(n: int, pref: int) -> int:
    """Largest block size <= pref that divides n (MXU-aligned preferred,
    descending-divisor fallback for awkward lengths). Shared by the
    latent-attention kernels."""
    pref = min(pref, n)
    for t in (pref, 512, 256, 128, 64, 32, 16, 8):
        if t <= pref and n % t == 0:
            return t
    for t in range(pref, 0, -1):
        if n % t == 0:
            return t
    return n


def _mm_kernel(x_ref, w_ref, o_ref, acc_ref, *, n_k: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(x_ref[...], w_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def _mm_init_kernel(x_ref, w_ref, init_ref, o_ref, acc_ref, *, n_k: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _():
        acc_ref[...] = init_ref[...].astype(jnp.float32)

    acc_ref[...] += jnp.dot(x_ref[...], w_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def matmul_init(x: jax.Array, w: jax.Array, init=None, *,
                bm: int = 128, bn: int = 128, bk: int = 128,
                interpret: bool = False) -> jax.Array:
    """out = (init or 0) + x @ w.  x: (M, K), w: (K, N), init: (M, N)."""
    M, K = x.shape
    K2, N = w.shape
    assert K == K2, (x.shape, w.shape)
    bm, bn, bk = _tile(M, bm), _tile(N, bn), _tile(K, bk)
    n_k = K // bk
    out_dtype = x.dtype

    in_specs = [
        pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
        pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
    ]
    args = [x, w]
    if init is not None:
        in_specs.append(pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)))
        args.append(init)
        kernel = functools.partial(_mm_init_kernel, n_k=n_k)
    else:
        kernel = functools.partial(_mm_kernel, n_k=n_k)

    return pl.pallas_call(
        kernel,
        grid=(M // bm, N // bn, n_k),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(*args)


def latent_matmul(x: jax.Array, a2t: jax.Array, b: jax.Array,
                  perm=None, *, interpret: bool = False,
                  bm: int = 128, bn: int = 128, bk: int = 128) -> jax.Array:
    """Block-identity low-rank projection.

    x: (M, d) activations; a2t: (d−r, r) = A2ᵀ; b: (r, N);
    perm: optional length-d column permutation (Remark 4).
    Returns y (M, N) = (x_id + x_rest @ a2t) @ b."""
    d = x.shape[1]
    r = a2t.shape[1]
    if perm is not None:
        x = jnp.take(x, jnp.asarray(perm), axis=1)
    x_id, x_rest = x[:, :r], x[:, r:]
    if d - r == 0:
        z = x_id
    else:
        z = matmul_init(x_rest, a2t, x_id, bm=bm, bn=bn, bk=bk,
                        interpret=interpret)
    return matmul_init(z, b, None, bm=bm, bn=bn, bk=bk, interpret=interpret)
