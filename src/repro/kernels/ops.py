"""Jitted public wrappers for the Pallas kernels.

``interpret`` defaults to True off-TPU so the same call sites run
everywhere; on TPU backends the real kernels lower.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp

from repro.kernels import latent_attention as _mla
from repro.kernels import latent_matmul as _lmm
from repro.kernels import ssd_scan as _ssd


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("interpret",))
def latent_matmul(x, a2t, b, perm=None, interpret=None):
    interpret = _default_interpret() if interpret is None else interpret
    return _lmm.latent_matmul(x, a2t, b, perm, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("scale", "interpret"))
def mla_decode(qt, ck, cv, valid_len, *, scale, interpret=None):
    interpret = _default_interpret() if interpret is None else interpret
    return _mla.mla_decode(qt, ck, cv, valid_len, scale=scale,
                           interpret=interpret)


@functools.partial(jax.jit, static_argnames=("scale", "softcap", "interpret"))
def mla_decode_grouped(qt, ck, cv, bv, valid_len, *, scale, softcap=None,
                       interpret=None):
    interpret = _default_interpret() if interpret is None else interpret
    return _mla.mla_decode_grouped(qt, ck, cv, bv, valid_len, scale=scale,
                                   softcap=softcap, interpret=interpret)


@functools.partial(jax.jit,
                   static_argnames=("scale", "softcap", "causal", "interpret"))
def mla_prefill(qt, ck, cv, valid_len, *, scale, softcap=None, causal=True,
                interpret=None):
    interpret = _default_interpret() if interpret is None else interpret
    return _mla.mla_prefill(qt, ck, cv, valid_len, scale=scale,
                            softcap=softcap, causal=causal,
                            interpret=interpret)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x, dt, A, Bm, Cm, *, chunk=128, interpret=None):
    interpret = _default_interpret() if interpret is None else interpret
    return _ssd.ssd_scan(x, dt, A, Bm, Cm, chunk=chunk, interpret=interpret)


def mla_decode_full(p, x, cfg, cache, valid_len):
    """End-to-end absorbed MLA decode step built on the grouped kernel:
    x: (B, 1, d) -> y: (B, 1, d). Mirrors layers.latent_attention_fwd's
    absorbed branch; absorption, latent attention, and per-head value
    decompression all run inside one pallas_call — no latent-u
    reshape/einsum round-trip."""
    B = x.shape[0]
    H, Hkv, Dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    R = H // Hkv
    xd = x[:, 0]
    c_q = xd @ p["a_q"].astype(xd.dtype)                 # (B, r_q)
    bq = p["b_q"].astype(xd.dtype).reshape(Hkv, R, *p["b_q"].shape[1:])
    qt = jnp.einsum("bq,grqd,gKd->bgrK", c_q, bq,
                    p["b_k"].astype(xd.dtype))           # (B, Hkv, R, r_k)
    yh = mla_decode_grouped(qt, cache["c_k"], cache["c_v"],
                            p["b_v"].astype(xd.dtype), valid_len,
                            scale=1.0 / math.sqrt(Dh),
                            softcap=cfg.attn_logit_softcap)
    y = yh.reshape(B, 1, H * Dh)
    y = (y @ p["a_o"].astype(y.dtype)) @ p["b_o"].astype(y.dtype)
    if "bias_o" in p:
        y = y + p["bias_o"].astype(y.dtype)
    return y
