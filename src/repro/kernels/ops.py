"""Jitted public wrappers for the Pallas kernels.

``interpret`` defaults to True off-TPU so the same call sites run
everywhere; on TPU backends the real kernels lower.

Mesh awareness: a ``pallas_call`` has no GSPMD partitioning rule, so
inside a sharded computation XLA would gather its operands onto every
device. The ``*_sharded`` entry points therefore check the active mesh
at trace time: when the head axis divides the 'model' axis the kernel
runs PER SHARD under ``shard_map`` (bit-identical — the grid is
parallel over batch/heads, so splitting heads across devices changes
nothing numerically); otherwise they fall back to the ``ref.py`` einsum
path, which GSPMD partitions like any other contraction. With no mesh
they are exactly the plain kernel wrappers.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.kernels import latent_attention as _mla
from repro.kernels import latent_matmul as _lmm
from repro.kernels import ref as _ref
from repro.kernels import ssd_scan as _ssd


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _serving_mesh():
    """(mesh, batch_axes, model_size) for the active mesh, else None.

    Trace-time only: the engine traces its jitted heads inside
    ``with mesh:`` so the decision is baked into the compiled step."""
    from repro.distributed.constraints import current_mesh
    mesh = current_mesh()
    if mesh is None or "model" not in mesh.axis_names:
        return None
    if mesh.shape["model"] == 1 and mesh.size == 1:
        return None
    ba = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
    ba = tuple(a for a in ba if a in mesh.axis_names)
    return mesh, ba, mesh.shape["model"]


def _batch_spec(mesh, ba, b_dim: int):
    n = 1
    for a in ba:
        n *= mesh.shape[a]
    return ba if (ba and b_dim % n == 0) else None


@functools.partial(jax.jit, static_argnames=("interpret",))
def latent_matmul(x, a2t, b, perm=None, interpret=None):
    interpret = _default_interpret() if interpret is None else interpret
    return _lmm.latent_matmul(x, a2t, b, perm, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("scale", "interpret"))
def mla_decode(qt, ck, cv, valid_len, *, scale, interpret=None):
    interpret = _default_interpret() if interpret is None else interpret
    return _mla.mla_decode(qt, ck, cv, valid_len, scale=scale,
                           interpret=interpret)


@functools.partial(jax.jit, static_argnames=("scale", "softcap", "interpret"))
def mla_decode_grouped(qt, ck, cv, bv, valid_len, *, scale, softcap=None,
                       interpret=None):
    interpret = _default_interpret() if interpret is None else interpret
    return _mla.mla_decode_grouped(qt, ck, cv, bv, valid_len, scale=scale,
                                   softcap=softcap, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("scale", "interpret"))
def mla_decode_ring(qt, ck, cv, start, length, *, scale, interpret=None):
    interpret = _default_interpret() if interpret is None else interpret
    return _mla.mla_decode_ring(qt, ck, cv, start, length, scale=scale,
                                interpret=interpret)


@functools.partial(jax.jit, static_argnames=("scale", "softcap", "interpret"))
def mla_decode_grouped_ring(qt, ck, cv, bv, start, length, *, scale,
                            softcap=None, interpret=None):
    interpret = _default_interpret() if interpret is None else interpret
    return _mla.mla_decode_grouped_ring(qt, ck, cv, bv, start, length,
                                        scale=scale, softcap=softcap,
                                        interpret=interpret)


@functools.partial(jax.jit,
                   static_argnames=("scale", "softcap", "causal", "window",
                                    "interpret"))
def mla_prefill(qt, ck, cv, valid_len, q_offsets=None, *, scale,
                softcap=None, causal=True, window=None, interpret=None):
    interpret = _default_interpret() if interpret is None else interpret
    return _mla.mla_prefill(qt, ck, cv, valid_len, q_offsets, scale=scale,
                            softcap=softcap, causal=causal, window=window,
                            interpret=interpret)


@functools.partial(jax.jit, static_argnames=("scale", "softcap", "interpret"))
def mla_decode_grouped_quant(qt, ck, cks, cv, cvs, bv, valid_len, *, scale,
                             softcap=None, interpret=None):
    interpret = _default_interpret() if interpret is None else interpret
    return _mla.mla_decode_grouped_quant(qt, ck, cks, cv, cvs, bv,
                                         valid_len, scale=scale,
                                         softcap=softcap,
                                         interpret=interpret)


@functools.partial(jax.jit, static_argnames=("scale", "softcap", "interpret"))
def mla_decode_grouped_ring_quant(qt, ck, cks, cv, cvs, bv, start, length,
                                  *, scale, softcap=None, interpret=None):
    interpret = _default_interpret() if interpret is None else interpret
    return _mla.mla_decode_grouped_ring_quant(qt, ck, cks, cv, cvs, bv,
                                              start, length, scale=scale,
                                              softcap=softcap,
                                              interpret=interpret)


@functools.partial(jax.jit,
                   static_argnames=("scale", "softcap", "causal", "window",
                                    "interpret"))
def mla_prefill_quant(qt, ck, cks, cv, cvs, valid_len, q_offsets=None, *,
                      scale, softcap=None, causal=True, window=None,
                      interpret=None):
    interpret = _default_interpret() if interpret is None else interpret
    return _mla.mla_prefill_quant(qt, ck, cks, cv, cvs, valid_len,
                                  q_offsets, scale=scale, softcap=softcap,
                                  causal=causal, window=window,
                                  interpret=interpret)


def mla_decode_grouped_sharded(qt, ck, cv, bv, valid_len, *, scale,
                               softcap=None):
    """Mesh-aware grouped decode (see module docstring).

    qt: (B, Hkv, R, r_k); ck/cv: (B, S, r); bv: (Hkv, r_v, Dh);
    valid_len: (B,). Per-shard kernel when Hkv divides 'model', ref
    einsum fallback otherwise, plain kernel with no mesh."""
    sm = _serving_mesh()
    if sm is None:
        return mla_decode_grouped(qt, ck, cv, bv, valid_len, scale=scale,
                                  softcap=softcap)
    mesh, ba, msize = sm
    Hkv = qt.shape[1]
    if Hkv % msize != 0:
        return _ref.mla_decode_grouped_ref(qt, ck, cv, bv, valid_len,
                                           scale=scale, softcap=softcap)
    bspec = _batch_spec(mesh, ba, qt.shape[0])
    fn = functools.partial(mla_decode_grouped, scale=scale, softcap=softcap)
    return shard_map(
        fn, mesh=mesh,
        in_specs=(P(bspec, "model", None, None), P(bspec, None, None),
                  P(bspec, None, None), P("model", None, None), P(bspec)),
        out_specs=P(bspec, "model", None, None),
        check_rep=False,
    )(qt, ck, cv, bv, valid_len)


def mla_decode_grouped_ring_sharded(qt, ck, cv, bv, start, length, *,
                                    scale, softcap=None):
    """Mesh-aware grouped RING decode (sliding-window caches).

    Same placement contract as ``mla_decode_grouped_sharded`` — per-shard
    kernel when Hkv divides 'model', ref einsum fallback otherwise, plain
    kernel with no mesh — but validity is the (start, length) ring
    descriptor. qt: (B, Hkv, R, r_k); ck/cv: (B, S, r); bv:
    (Hkv, r_v, Dh); start/length: (B,)."""
    sm = _serving_mesh()
    if sm is None:
        return mla_decode_grouped_ring(qt, ck, cv, bv, start, length,
                                       scale=scale, softcap=softcap)
    mesh, ba, msize = sm
    Hkv = qt.shape[1]
    if Hkv % msize != 0:
        return _ref.mla_decode_grouped_ring_ref(qt, ck, cv, bv, start,
                                                length, scale=scale,
                                                softcap=softcap)
    bspec = _batch_spec(mesh, ba, qt.shape[0])
    fn = functools.partial(mla_decode_grouped_ring, scale=scale,
                           softcap=softcap)
    return shard_map(
        fn, mesh=mesh,
        in_specs=(P(bspec, "model", None, None), P(bspec, None, None),
                  P(bspec, None, None), P("model", None, None), P(bspec),
                  P(bspec)),
        out_specs=P(bspec, "model", None, None),
        check_rep=False,
    )(qt, ck, cv, bv, start, length)


def mla_prefill_sharded(qt, ck, cv, valid_len, *, scale, softcap=None,
                        causal=True, window=None, q_offsets=None):
    """Mesh-aware flash prefill: per-shard kernel when H divides
    'model', ref einsum fallback otherwise, plain kernel with no mesh.

    qt: (B, H, T, r_k); ck/cv: (B, S, r); valid_len: (B,); ``window``
    adds sliding-window masking (kernel block mask + pruning);
    ``q_offsets`` (B,) shifts each row's queries to absolute positions
    ``offset + t`` (paged suffix prefill over a partially cached view)."""
    sm = _serving_mesh()
    if sm is None:
        return mla_prefill(qt, ck, cv, valid_len, q_offsets, scale=scale,
                           softcap=softcap, causal=causal, window=window)
    mesh, ba, msize = sm
    if q_offsets is None:
        q_offsets = jnp.zeros((qt.shape[0],), jnp.int32)
    H = qt.shape[1]
    if H % msize != 0:
        return _ref.mla_prefill_ref(qt, ck, cv, valid_len, q_offsets,
                                    scale=scale, softcap=softcap,
                                    causal=causal, window=window)
    bspec = _batch_spec(mesh, ba, qt.shape[0])
    fn = functools.partial(mla_prefill, scale=scale, softcap=softcap,
                           causal=causal, window=window)
    return shard_map(
        fn, mesh=mesh,
        in_specs=(P(bspec, "model", None, None), P(bspec, None, None),
                  P(bspec, None, None), P(bspec), P(bspec)),
        out_specs=P(bspec, "model", None, None),
        check_rep=False,
    )(qt, ck, cv, valid_len, q_offsets)


def mla_decode_grouped_quant_sharded(qt, ck, cks, cv, cvs, bv, valid_len, *,
                                     scale, softcap=None):
    """Mesh-aware grouped decode over an int8 latent cache.

    Same placement contract as ``mla_decode_grouped_sharded``; the two
    extra operands are the per-row fp32 scale columns (B, S, 1), which
    shard exactly like their int8 siblings (batch only)."""
    sm = _serving_mesh()
    if sm is None:
        return mla_decode_grouped_quant(qt, ck, cks, cv, cvs, bv, valid_len,
                                        scale=scale, softcap=softcap)
    mesh, ba, msize = sm
    Hkv = qt.shape[1]
    if Hkv % msize != 0:
        return _ref.mla_decode_grouped_quant_ref(qt, ck, cks, cv, cvs, bv,
                                                 valid_len, scale=scale,
                                                 softcap=softcap)
    bspec = _batch_spec(mesh, ba, qt.shape[0])
    fn = functools.partial(mla_decode_grouped_quant, scale=scale,
                           softcap=softcap)
    return shard_map(
        fn, mesh=mesh,
        in_specs=(P(bspec, "model", None, None), P(bspec, None, None),
                  P(bspec, None, None), P(bspec, None, None),
                  P(bspec, None, None), P("model", None, None), P(bspec)),
        out_specs=P(bspec, "model", None, None),
        check_rep=False,
    )(qt, ck, cks, cv, cvs, bv, valid_len)


def mla_decode_grouped_ring_quant_sharded(qt, ck, cks, cv, cvs, bv, start,
                                          length, *, scale, softcap=None):
    """Mesh-aware grouped RING decode over an int8 latent cache."""
    sm = _serving_mesh()
    if sm is None:
        return mla_decode_grouped_ring_quant(qt, ck, cks, cv, cvs, bv, start,
                                             length, scale=scale,
                                             softcap=softcap)
    mesh, ba, msize = sm
    Hkv = qt.shape[1]
    if Hkv % msize != 0:
        return _ref.mla_decode_grouped_ring_quant_ref(qt, ck, cks, cv, cvs,
                                                      bv, start, length,
                                                      scale=scale,
                                                      softcap=softcap)
    bspec = _batch_spec(mesh, ba, qt.shape[0])
    fn = functools.partial(mla_decode_grouped_ring_quant, scale=scale,
                           softcap=softcap)
    return shard_map(
        fn, mesh=mesh,
        in_specs=(P(bspec, "model", None, None), P(bspec, None, None),
                  P(bspec, None, None), P(bspec, None, None),
                  P(bspec, None, None), P("model", None, None), P(bspec),
                  P(bspec)),
        out_specs=P(bspec, "model", None, None),
        check_rep=False,
    )(qt, ck, cks, cv, cvs, bv, start, length)


def mla_prefill_quant_sharded(qt, ck, cks, cv, cvs, valid_len, *, scale,
                              softcap=None, causal=True, window=None,
                              q_offsets=None):
    """Mesh-aware flash prefill over an int8 latent cache."""
    sm = _serving_mesh()
    if sm is None:
        return mla_prefill_quant(qt, ck, cks, cv, cvs, valid_len, q_offsets,
                                 scale=scale, softcap=softcap, causal=causal,
                                 window=window)
    mesh, ba, msize = sm
    if q_offsets is None:
        q_offsets = jnp.zeros((qt.shape[0],), jnp.int32)
    H = qt.shape[1]
    if H % msize != 0:
        return _ref.mla_prefill_quant_ref(qt, ck, cks, cv, cvs, valid_len,
                                          q_offsets, scale=scale,
                                          softcap=softcap, causal=causal,
                                          window=window)
    bspec = _batch_spec(mesh, ba, qt.shape[0])
    fn = functools.partial(mla_prefill_quant, scale=scale, softcap=softcap,
                           causal=causal, window=window)
    return shard_map(
        fn, mesh=mesh,
        in_specs=(P(bspec, "model", None, None), P(bspec, None, None),
                  P(bspec, None, None), P(bspec, None, None),
                  P(bspec, None, None), P(bspec), P(bspec)),
        out_specs=P(bspec, "model", None, None),
        check_rep=False,
    )(qt, ck, cks, cv, cvs, valid_len, q_offsets)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x, dt, A, Bm, Cm, *, chunk=128, interpret=None):
    interpret = _default_interpret() if interpret is None else interpret
    return _ssd.ssd_scan(x, dt, A, Bm, Cm, chunk=chunk, interpret=interpret)


def mla_decode_full(p, x, cfg, cache, valid_len):
    """End-to-end absorbed MLA decode step built on the grouped kernel:
    x: (B, 1, d) -> y: (B, 1, d). Mirrors layers.latent_attention_fwd's
    absorbed branch; absorption, latent attention, and per-head value
    decompression all run inside one pallas_call — no latent-u
    reshape/einsum round-trip."""
    B = x.shape[0]
    H, Hkv, Dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    R = H // Hkv
    xd = x[:, 0]
    c_q = xd @ p["a_q"].astype(xd.dtype)                 # (B, r_q)
    bq = p["b_q"].astype(xd.dtype).reshape(Hkv, R, *p["b_q"].shape[1:])
    qt = jnp.einsum("bq,grqd,gKd->bgrK", c_q, bq,
                    p["b_k"].astype(xd.dtype))           # (B, Hkv, R, r_k)
    if "ck_scale" in cache:
        yh = mla_decode_grouped_quant_sharded(
            qt, cache["c_k"], cache["ck_scale"], cache["c_v"],
            cache["cv_scale"], p["b_v"].astype(xd.dtype), valid_len,
            scale=1.0 / math.sqrt(Dh), softcap=cfg.attn_logit_softcap)
    else:
        yh = mla_decode_grouped_sharded(qt, cache["c_k"], cache["c_v"],
                                        p["b_v"].astype(xd.dtype), valid_len,
                                        scale=1.0 / math.sqrt(Dh),
                                        softcap=cfg.attn_logit_softcap)
    y = yh.reshape(B, 1, H * Dh)
    y = (y @ p["a_o"].astype(y.dtype)) @ p["b_o"].astype(y.dtype)
    if "bias_o" in p:
        y = y + p["bias_o"].astype(y.dtype)
    return y
