"""Pallas TPU kernel: Mamba2 SSD chunked scan (for the ssm/hybrid archs).

TPU adaptation of the SSD algorithm (Dao & Gu 2024): the intra-chunk
quadratic form and chunk-state construction are MXU matmuls on a
(Q=chunk) tile held in VMEM; the inter-chunk recurrence is carried in a
VMEM scratch across the sequential chunk grid dimension (no HBM
round-trip for the running state).

Grid: (B, n_chunks) with the chunk axis "arbitrary" (sequential). Heads
are processed whole per block (H·P·N state fits VMEM for every assigned
config: mamba2-2.7b 80·64·128·4B = 2.6 MB).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams; support both.
_CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or pltpu.TPUCompilerParams


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, state_out_ref,
                state_ref, *, n_chunks: int, chunk: int, G: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0]          # (Q, H, P)
    dt = dt_ref[0]        # (Q, H) fp32
    A = a_ref[...]        # (H,) negative
    Bm = b_ref[0]         # (Q, G, N)
    Cm = c_ref[0]         # (Q, G, N)
    Q, H, P = x.shape
    N = Bm.shape[-1]
    R = H // G

    dA = dt * A[None, :]                       # (Q, H)
    cum = jnp.cumsum(dA, axis=0)               # (Q, H)
    xg = x.reshape(Q, G, R, P)
    dtg = dt.reshape(Q, G, R)
    cumg = cum.reshape(Q, G, R)

    # intra-chunk: CB shared over heads within a group
    CB = jnp.einsum("qgk,sgk->gqs", Cm, Bm,
                    preferred_element_type=jnp.float32)
    decay = jnp.exp(cumg[:, None] - cumg[None, :])      # (Q, S, G, R)
    mask = jnp.tril(jnp.ones((Q, Q), jnp.bool_))
    decay = jnp.where(mask[:, :, None, None], decay, 0.0)
    xdt = xg * dtg[..., None].astype(xg.dtype)
    y = jnp.einsum("gqs,qsgr,sgrp->qgrp", CB, decay.astype(xg.dtype), xdt)

    # inter-chunk: contribution of the carried state
    in_decay = jnp.exp(cumg)                            # (Q, G, R)
    prev = state_ref[...].reshape(G, R, P, N)
    y += jnp.einsum("qgk,grpk,qgr->qgrp", Cm, prev.astype(xg.dtype),
                    in_decay.astype(xg.dtype))
    y_ref[0] = y.reshape(Q, H, P).astype(y_ref.dtype)

    # update carried state: S ← decay_chunk · S + Σ B dt x
    last = cumg[-1]                                     # (G, R)
    state_decay = jnp.exp(last[None] - cumg)            # (Q, G, R)
    new = jnp.einsum("qgk,qgrp,qgr->grpk", Bm, xdt,
                     state_decay.astype(xg.dtype))
    chunk_decay = jnp.exp(last)                         # (G, R)
    state_ref[...] = (new.astype(jnp.float32)
                      + chunk_decay[..., None, None]
                      * state_ref[...].reshape(G, R, P, N)
                      ).reshape(H, P, N)

    @pl.when(ci == n_chunks - 1)
    def _():
        state_out_ref[0] = state_ref[...]


def ssd_scan(x: jax.Array, dt: jax.Array, A: jax.Array, Bm: jax.Array,
             Cm: jax.Array, *, chunk: int = 128,
             interpret: bool = False):
    """x: (B,S,H,P); dt: (B,S,H) fp32; A: (H,); Bm/Cm: (B,S,G,N).
    Returns (y (B,S,H,P), final_state (B,H,P,N) fp32)."""
    B, S, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    chunk = min(chunk, S)
    assert S % chunk == 0, (S, chunk)
    n_chunks = S // chunk

    kernel = functools.partial(_ssd_kernel, n_chunks=n_chunks, chunk=chunk,
                               G=G)
    y, state = pl.pallas_call(
        kernel,
        grid=(B, n_chunks),
        in_specs=[
            pl.BlockSpec((1, chunk, H, P), lambda b, c: (b, c, 0, 0)),
            pl.BlockSpec((1, chunk, H), lambda b, c: (b, c, 0)),
            pl.BlockSpec((H,), lambda b, c: (0,)),
            pl.BlockSpec((1, chunk, G, N), lambda b, c: (b, c, 0, 0)),
            pl.BlockSpec((1, chunk, G, N), lambda b, c: (b, c, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, H, P), lambda b, c: (b, c, 0, 0)),
            pl.BlockSpec((1, H, P, N), lambda b, c: (b, 0, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S, H, P), x.dtype),
            jax.ShapeDtypeStruct((B, H, P, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((H, P, N), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(x, dt, A, Bm, Cm)
    return y, state
