"""Pallas TPU kernels: latent (MLA) attention over a compressed KV cache
(paper §4.1/§4.2 payoff) — decode (per-head + grouped) and flash prefill.

The cache holds LATENTS c_k (S, r_k), c_v (S, r_v) — never the
decompressed per-head keys/values. Queries arrive pre-absorbed
(q̃ᵢ = Hᵢᵀ A_q x ∈ R^{r_k}, DeepSeek-style absorption done in ops.py), so
every kernel computes, flash-style over sequence blocks:

    sᵢₜ   = q̃ᵢ · c_k[t]           (scores directly in latent space)
    uᵢ    = Σₜ softmax(sᵢ)ₜ c_v[t]  (values reduced in latent space)

Online softmax (running max/denominator in VMEM scratch) over the S axis.
HBM traffic per step: S·(r_k+r_v) instead of S·2·H·d_h — exactly the
paper's KV-cache reduction.

Entry points:
  * ``mla_decode``         — (B, H) per-head decode, latent-space output.
  * ``mla_decode_grouped`` — (B, Hkv, R) grouped decode with the per-head
    value decompression (u · B_v) fused into the kernel epilogue, so one
    pallas_call goes latent cache -> per-head (R, Dh) outputs.
  * ``mla_prefill``        — flash-style causal prefill: q̃ blocks ×
    c_k/c_v sequence blocks, causal + ragged-length masking, never
    materializing the (…, T, S) score tensor. ``window=w`` adds
    sliding-window masking with two-sided block pruning (blocks entirely
    above the diagonal OR entirely below the window are skipped).

Cache layouts (models/cache_layout.CacheLayout): the decode kernels above
mask a ``valid_len`` PREFIX — a linear cache. Their ``*_ring`` variants
(``mla_decode_ring`` / ``mla_decode_grouped_ring``) take a per-row
``(start, length)`` ring descriptor instead: slot ``t`` is live iff
``(t - start) mod S < length``, which is what a sliding-window ring cache
(writes wrap mod cache_len) produces. Same online softmax, same fused
epilogue — windowed models keep the fast path.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.kernels.latent_matmul import _tile

# jax renamed TPUCompilerParams -> CompilerParams; support both.
_CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or pltpu.TPUCompilerParams

NEG_INF = -1e30


def _softmax_step(s, mask, m_ref, l_ref, acc_ref, cv, p_scale=None):
    """One online-softmax accumulation step shared by all kernels.

    s: (rows, bs) fp32 masked scores (NEG_INF outside); mask: (rows, bs)
    bool. Masked lanes contribute exactly zero even when a whole row is
    masked (m stays NEG_INF -> exp(0) would otherwise count them).
    ``p_scale`` (1, bs): per-key weights folded into the VALUE reduce
    only — the int8 cache's cv dequant scales, applied as
    (p ∘ scale) @ cv_int8 == p @ (cv_int8 ∘ scaleᵀ) while the softmax
    denominator keeps the raw p sum."""
    m_prev = m_ref[...]                      # (rows, 1)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.where(mask, jnp.exp(s - m_new), 0.0)   # (rows, bs)
    corr = jnp.exp(m_prev - m_new)           # (rows, 1)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1, keepdims=True)
    pv = p if p_scale is None else p * p_scale
    acc_ref[...] = acc_ref[...] * corr + jnp.dot(
        pv.astype(cv.dtype), cv, preferred_element_type=jnp.float32)
    m_ref[...] = m_new


def _finalize(l_ref, acc_ref):
    """acc / l with an all-masked guard: rows with no valid key (e.g.
    valid_len == 0) output zeros instead of 0/0 NaNs."""
    l = l_ref[...]
    return acc_ref[...] / jnp.where(l == 0.0, 1.0, l)


def _ring_mask(t, start, length, n_total: int):
    """Ring-segment validity for global slot indices ``t`` (int32 array):
    live iff ``(t - start) mod n_total < length``. ``t`` and ``start``
    are both in [0, n_total), so ``t - start + n_total`` is positive and
    C-style ``lax.rem`` equals the mathematical mod."""
    off = jax.lax.rem(t - start + n_total, n_total)
    return off < length


# ----------------------------------------------------------------------
# decode: per-head layout (B, H) — latent-space outputs
# ----------------------------------------------------------------------

def _mla_decode_kernel(qt_ref, ck_ref, cv_ref, len_ref, o_ref,
                       m_ref, l_ref, acc_ref, *, n_s: int, bs: int,
                       scale: float):
    s_idx = pl.program_id(1)

    @pl.when(s_idx == 0)
    def _():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    qt = qt_ref[0]              # (H, r_k)
    ck = ck_ref[0]              # (bs, r_k)
    cv = cv_ref[0]              # (bs, r_v)
    valid_len = len_ref[0]      # tokens valid in the cache

    s = jnp.dot(qt, ck.T, preferred_element_type=jnp.float32) * scale  # (H, bs)
    t = s_idx * bs + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    mask = t < valid_len
    s = jnp.where(mask, s, NEG_INF)
    _softmax_step(s, mask, m_ref, l_ref, acc_ref, cv)

    @pl.when(s_idx == n_s - 1)
    def _():
        o_ref[0] = _finalize(l_ref, acc_ref).astype(o_ref.dtype)


def mla_decode(qt: jax.Array, ck: jax.Array, cv: jax.Array,
               valid_len, *, scale: float, bs: int = 512,
               interpret: bool = False) -> jax.Array:
    """qt: (B, H, r_k) absorbed queries; ck: (B, S, r_k); cv: (B, S, r_v);
    valid_len: (B,) int32 — number of live cache slots.
    Returns u: (B, H, r_v) latent-space attention outputs."""
    B, H, r_k = qt.shape
    S, r_v = ck.shape[1], cv.shape[2]
    bs = _tile(S, bs)
    n_s = S // bs

    kernel = functools.partial(_mla_decode_kernel, n_s=n_s, bs=bs,
                               scale=scale)
    return pl.pallas_call(
        kernel,
        grid=(B, n_s),
        in_specs=[
            pl.BlockSpec((1, H, r_k), lambda b, s: (b, 0, 0)),
            pl.BlockSpec((1, bs, r_k), lambda b, s: (b, s, 0)),
            pl.BlockSpec((1, bs, r_v), lambda b, s: (b, s, 0)),
            pl.BlockSpec((1,), lambda b, s: (b,)),
        ],
        out_specs=pl.BlockSpec((1, H, r_v), lambda b, s: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, r_v), qt.dtype),
        scratch_shapes=[
            pltpu.VMEM((H, 1), jnp.float32),
            pltpu.VMEM((H, 1), jnp.float32),
            pltpu.VMEM((H, r_v), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(qt, ck, cv, valid_len)


# ----------------------------------------------------------------------
# decode: per-head ring variant — (start, length) descriptor masking
# ----------------------------------------------------------------------

def _mla_decode_ring_kernel(qt_ref, ck_ref, cv_ref, start_ref, len_ref,
                            o_ref, m_ref, l_ref, acc_ref, *, n_s: int,
                            bs: int, n_total: int, scale: float):
    s_idx = pl.program_id(1)

    @pl.when(s_idx == 0)
    def _():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    qt = qt_ref[0]              # (H, r_k)
    ck = ck_ref[0]              # (bs, r_k)
    cv = cv_ref[0]              # (bs, r_v)
    start = start_ref[0]
    length = len_ref[0]

    s = jnp.dot(qt, ck.T, preferred_element_type=jnp.float32) * scale  # (H, bs)
    t = s_idx * bs + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    mask = _ring_mask(t, start, length, n_total)
    s = jnp.where(mask, s, NEG_INF)
    _softmax_step(s, mask, m_ref, l_ref, acc_ref, cv)

    @pl.when(s_idx == n_s - 1)
    def _():
        o_ref[0] = _finalize(l_ref, acc_ref).astype(o_ref.dtype)


def mla_decode_ring(qt: jax.Array, ck: jax.Array, cv: jax.Array,
                    start, length, *, scale: float, bs: int = 512,
                    interpret: bool = False) -> jax.Array:
    """Ring-cache per-head decode: like ``mla_decode`` but the live slots
    are the ring segment ``(start, length)`` per row instead of a prefix.
    qt: (B, H, r_k); ck: (B, S, r_k); cv: (B, S, r_v); start/length: (B,)
    int32. Returns u: (B, H, r_v)."""
    B, H, r_k = qt.shape
    S, r_v = ck.shape[1], cv.shape[2]
    bs = _tile(S, bs)
    n_s = S // bs

    kernel = functools.partial(_mla_decode_ring_kernel, n_s=n_s, bs=bs,
                               n_total=S, scale=scale)
    return pl.pallas_call(
        kernel,
        grid=(B, n_s),
        in_specs=[
            pl.BlockSpec((1, H, r_k), lambda b, s: (b, 0, 0)),
            pl.BlockSpec((1, bs, r_k), lambda b, s: (b, s, 0)),
            pl.BlockSpec((1, bs, r_v), lambda b, s: (b, s, 0)),
            pl.BlockSpec((1,), lambda b, s: (b,)),
            pl.BlockSpec((1,), lambda b, s: (b,)),
        ],
        out_specs=pl.BlockSpec((1, H, r_v), lambda b, s: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, r_v), qt.dtype),
        scratch_shapes=[
            pltpu.VMEM((H, 1), jnp.float32),
            pltpu.VMEM((H, 1), jnp.float32),
            pltpu.VMEM((H, r_v), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(qt, ck, cv, start, length)


# ----------------------------------------------------------------------
# decode: grouped-query layout (B, Hkv, R) with fused value decompression
# ----------------------------------------------------------------------

def _mla_decode_grouped_kernel(qt_ref, ck_ref, cv_ref, bv_ref, len_ref,
                               o_ref, m_ref, l_ref, acc_ref, *, n_s: int,
                               bs: int, scale: float, softcap):
    s_idx = pl.program_id(2)

    @pl.when(s_idx == 0)
    def _():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    qt = qt_ref[0, 0]           # (R, r_k) — this kv-group's absorbed queries
    ck = ck_ref[0]              # (bs, r_k)
    cv = cv_ref[0]              # (bs, r_v)
    valid_len = len_ref[0]

    s = jnp.dot(qt, ck.T, preferred_element_type=jnp.float32) * scale  # (R, bs)
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    t = s_idx * bs + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    mask = t < valid_len
    s = jnp.where(mask, s, NEG_INF)
    _softmax_step(s, mask, m_ref, l_ref, acc_ref, cv)

    @pl.when(s_idx == n_s - 1)
    def _():
        u = _finalize(l_ref, acc_ref)                    # (R, r_v) fp32
        bv = bv_ref[0]                                   # (r_v, Dh)
        o_ref[0, 0] = jnp.dot(u.astype(bv.dtype), bv,
                              preferred_element_type=jnp.float32
                              ).astype(o_ref.dtype)


def mla_decode_grouped(qt: jax.Array, ck: jax.Array, cv: jax.Array,
                       bv: jax.Array, valid_len, *, scale: float,
                       softcap=None, bs: int = 512,
                       interpret: bool = False) -> jax.Array:
    """Grouped-query decode with fused per-head value decompression.

    qt: (B, Hkv, R, r_k) absorbed queries; ck: (B, S, r_k);
    cv: (B, S, r_v); bv: (Hkv, r_v, Dh) decompression planes;
    valid_len: (B,) int32. Returns y: (B, Hkv, R, Dh) per-head outputs —
    absorption→attention→decompression in one pallas_call, no latent-u
    reshape/einsum round-trip on the host graph."""
    B, Hkv, R, r_k = qt.shape
    S, r_v = ck.shape[1], cv.shape[2]
    Dh = bv.shape[2]
    bs = _tile(S, bs)
    n_s = S // bs

    kernel = functools.partial(_mla_decode_grouped_kernel, n_s=n_s, bs=bs,
                               scale=scale, softcap=softcap)
    return pl.pallas_call(
        kernel,
        grid=(B, Hkv, n_s),
        in_specs=[
            pl.BlockSpec((1, 1, R, r_k), lambda b, g, s: (b, g, 0, 0)),
            pl.BlockSpec((1, bs, r_k), lambda b, g, s: (b, s, 0)),
            pl.BlockSpec((1, bs, r_v), lambda b, g, s: (b, s, 0)),
            pl.BlockSpec((1, r_v, Dh), lambda b, g, s: (g, 0, 0)),
            pl.BlockSpec((1,), lambda b, g, s: (b,)),
        ],
        out_specs=pl.BlockSpec((1, 1, R, Dh), lambda b, g, s: (b, g, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hkv, R, Dh), qt.dtype),
        scratch_shapes=[
            pltpu.VMEM((R, 1), jnp.float32),
            pltpu.VMEM((R, 1), jnp.float32),
            pltpu.VMEM((R, r_v), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(qt, ck, cv, bv, valid_len)


# ----------------------------------------------------------------------
# decode: grouped ring variant — (start, length) + fused decompression
# ----------------------------------------------------------------------

def _mla_decode_grouped_ring_kernel(qt_ref, ck_ref, cv_ref, bv_ref,
                                    start_ref, len_ref, o_ref, m_ref,
                                    l_ref, acc_ref, *, n_s: int, bs: int,
                                    n_total: int, scale: float, softcap):
    s_idx = pl.program_id(2)

    @pl.when(s_idx == 0)
    def _():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    qt = qt_ref[0, 0]           # (R, r_k)
    ck = ck_ref[0]              # (bs, r_k)
    cv = cv_ref[0]              # (bs, r_v)
    start = start_ref[0]
    length = len_ref[0]

    s = jnp.dot(qt, ck.T, preferred_element_type=jnp.float32) * scale  # (R, bs)
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    t = s_idx * bs + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    mask = _ring_mask(t, start, length, n_total)
    s = jnp.where(mask, s, NEG_INF)
    _softmax_step(s, mask, m_ref, l_ref, acc_ref, cv)

    @pl.when(s_idx == n_s - 1)
    def _():
        u = _finalize(l_ref, acc_ref)                    # (R, r_v) fp32
        bv = bv_ref[0]                                   # (r_v, Dh)
        o_ref[0, 0] = jnp.dot(u.astype(bv.dtype), bv,
                              preferred_element_type=jnp.float32
                              ).astype(o_ref.dtype)


def mla_decode_grouped_ring(qt: jax.Array, ck: jax.Array, cv: jax.Array,
                            bv: jax.Array, start, length, *, scale: float,
                            softcap=None, bs: int = 512,
                            interpret: bool = False) -> jax.Array:
    """Grouped decode + fused value decompression over a RING cache.

    Identical to ``mla_decode_grouped`` except validity: slot ``t`` is
    live iff ``(t - start) mod S < length`` — the (start, length) ring
    descriptor a sliding-window cache layout produces (CacheLayout.
    ring_state). qt: (B, Hkv, R, r_k); ck: (B, S, r_k); cv: (B, S, r_v);
    bv: (Hkv, r_v, Dh); start/length: (B,) int32. Returns
    (B, Hkv, R, Dh)."""
    B, Hkv, R, r_k = qt.shape
    S, r_v = ck.shape[1], cv.shape[2]
    Dh = bv.shape[2]
    bs = _tile(S, bs)
    n_s = S // bs

    kernel = functools.partial(_mla_decode_grouped_ring_kernel, n_s=n_s,
                               bs=bs, n_total=S, scale=scale,
                               softcap=softcap)
    return pl.pallas_call(
        kernel,
        grid=(B, Hkv, n_s),
        in_specs=[
            pl.BlockSpec((1, 1, R, r_k), lambda b, g, s: (b, g, 0, 0)),
            pl.BlockSpec((1, bs, r_k), lambda b, g, s: (b, s, 0)),
            pl.BlockSpec((1, bs, r_v), lambda b, g, s: (b, s, 0)),
            pl.BlockSpec((1, r_v, Dh), lambda b, g, s: (g, 0, 0)),
            pl.BlockSpec((1,), lambda b, g, s: (b,)),
            pl.BlockSpec((1,), lambda b, g, s: (b,)),
        ],
        out_specs=pl.BlockSpec((1, 1, R, Dh), lambda b, g, s: (b, g, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hkv, R, Dh), qt.dtype),
        scratch_shapes=[
            pltpu.VMEM((R, 1), jnp.float32),
            pltpu.VMEM((R, 1), jnp.float32),
            pltpu.VMEM((R, r_v), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(qt, ck, cv, bv, start, length)


# ----------------------------------------------------------------------
# prefill: flash-style causal attention directly in latent space
# ----------------------------------------------------------------------

def _mla_prefill_kernel(qt_ref, ck_ref, cv_ref, len_ref, off_ref, o_ref,
                        m_ref, l_ref, acc_ref, *, n_s: int, bt: int,
                        bs: int, scale: float, softcap, causal: bool,
                        window):
    t_idx = pl.program_id(2)
    s_idx = pl.program_id(3)
    off = off_ref[0]            # per-row query offset (0 = aligned prefill)

    @pl.when(s_idx == 0)
    def _():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    def accumulate():
        qt = qt_ref[0, 0]       # (bt, r_k)
        ck = ck_ref[0]          # (bs, r_k)
        cv = cv_ref[0]          # (bs, r_v)
        valid_len = len_ref[0]

        s = jnp.dot(qt, ck.T, preferred_element_type=jnp.float32) * scale
        if softcap:
            s = jnp.tanh(s / softcap) * softcap
        kpos = s_idx * bs + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = kpos < valid_len
        if causal or window is not None:
            qpos = off + t_idx * bt \
                + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        if causal:
            mask &= kpos <= qpos
        if window is not None:
            # bounded difference (local chunk indices): never qpos - window
            mask &= (qpos - kpos) < window
        s = jnp.where(mask, s, NEG_INF)
        _softmax_step(s, mask, m_ref, l_ref, acc_ref, cv)

    if causal:
        # two-sided block pruning: skip key blocks strictly above the
        # causal diagonal (shifted by the rows' query offset), and
        # (windowed) blocks entirely below every query's sliding window
        # — the matmul never runs for them.
        live = s_idx * bs <= off + t_idx * bt + bt - 1
        if window is not None:
            live &= s_idx * bs + bs - 1 + window > off + t_idx * bt

        @pl.when(live)
        def _():
            accumulate()
    else:
        accumulate()

    @pl.when(s_idx == n_s - 1)
    def _():
        o_ref[0, 0] = _finalize(l_ref, acc_ref).astype(o_ref.dtype)


def mla_prefill(qt: jax.Array, ck: jax.Array, cv: jax.Array,
                valid_len, q_offsets=None, *, scale: float, softcap=None,
                causal: bool = True, window=None, bt: int = 128,
                bs: int = 512, interpret: bool = False) -> jax.Array:
    """Flash prefill over the latent cache — never materializes (T, S).

    qt: (B, H, T, r_k) absorbed queries; ck: (B, S, r_k); cv: (B, S, r_v);
    valid_len: (B,) int32 ragged key lengths (queries at position >= their
    sequence's valid_len get zero outputs: their rows are fully masked).
    Causal masking compares local query index t vs key index s (queries
    and keys are assumed position-aligned, as in a prefill chunk).
    ``q_offsets`` (B,) int32 shifts each row's queries to absolute
    position ``offset + t`` against the keys — the paged engine's
    prefix-cached suffix prefill, where row b resumes after ``offset``
    cached latent rows (default 0: the aligned case, bit-identical).
    ``window=w`` adds sliding-window masking (key within w of the query)
    with two-sided block pruning. Returns u: (B, H, T, r_v) latent-space
    attention outputs."""
    B, H, T, r_k = qt.shape
    S, r_v = ck.shape[1], cv.shape[2]
    if q_offsets is None:
        q_offsets = jnp.zeros((B,), jnp.int32)
    q_offsets = q_offsets.astype(jnp.int32)
    bt = _tile(T, bt)
    bs = _tile(S, bs)
    n_t, n_s = T // bt, S // bs

    kernel = functools.partial(_mla_prefill_kernel, n_s=n_s, bt=bt, bs=bs,
                               scale=scale, softcap=softcap, causal=causal,
                               window=window)
    return pl.pallas_call(
        kernel,
        grid=(B, H, n_t, n_s),
        in_specs=[
            pl.BlockSpec((1, 1, bt, r_k), lambda b, h, t, s: (b, h, t, 0)),
            pl.BlockSpec((1, bs, r_k), lambda b, h, t, s: (b, s, 0)),
            pl.BlockSpec((1, bs, r_v), lambda b, h, t, s: (b, s, 0)),
            pl.BlockSpec((1,), lambda b, h, t, s: (b,)),
            pl.BlockSpec((1,), lambda b, h, t, s: (b,)),
        ],
        out_specs=pl.BlockSpec((1, 1, bt, r_v), lambda b, h, t, s: (b, h, t, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, T, r_v), qt.dtype),
        scratch_shapes=[
            pltpu.VMEM((bt, 1), jnp.float32),
            pltpu.VMEM((bt, 1), jnp.float32),
            pltpu.VMEM((bt, r_v), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(qt, ck, cv, valid_len, q_offsets)


# ----------------------------------------------------------------------
# int8 quantized-cache variants: in-kernel dequantization
# ----------------------------------------------------------------------
# The cache stores int8 c_k/c_v rows with one fp32 scale per row
# (kernels/quant.py). Dequantization fuses into the existing math
# instead of materializing fp rows in VMEM:
#   scores: q̃·(c_k ∘ s_k)ᵀ = (q̃·c_kᵀ) ∘ s_kᵀ  — one column multiply,
#     applied BEFORE softcap/masking so capped scores match the fp path;
#   values: p·(c_v ∘ s_v)  = (p ∘ s_vᵀ)·c_v    — folded into the online-
#     softmax accumulate via _softmax_step's p_scale (the softmax
#     denominator keeps the raw p sum).
# The value-decompression epilogue (u · B_v) is unchanged.


def _dequant_scores(qt, ck, cks, scale: float):
    """(rows, bs) fp32 scores from int8 keys: (q̃·c_kᵀ) ∘ s_kᵀ."""
    s = jnp.dot(qt.astype(jnp.float32), ck.astype(jnp.float32).T,
                preferred_element_type=jnp.float32) * scale
    return s * cks[:, 0][None, :]


def _mla_decode_grouped_quant_kernel(qt_ref, ck_ref, cks_ref, cv_ref,
                                     cvs_ref, bv_ref, len_ref, o_ref,
                                     m_ref, l_ref, acc_ref, *, n_s: int,
                                     bs: int, scale: float, softcap):
    s_idx = pl.program_id(2)

    @pl.when(s_idx == 0)
    def _():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    qt = qt_ref[0, 0]           # (R, r_k)
    ck = ck_ref[0]              # (bs, r_k) int8
    cks = cks_ref[0]            # (bs, 1) fp32 key scales
    cv = cv_ref[0]              # (bs, r_v) int8
    cvs = cvs_ref[0]            # (bs, 1) fp32 value scales
    valid_len = len_ref[0]

    s = _dequant_scores(qt, ck, cks, scale)              # (R, bs)
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    t = s_idx * bs + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    mask = t < valid_len
    s = jnp.where(mask, s, NEG_INF)
    _softmax_step(s, mask, m_ref, l_ref, acc_ref, cv.astype(jnp.float32),
                  p_scale=cvs[:, 0][None, :])

    @pl.when(s_idx == n_s - 1)
    def _():
        u = _finalize(l_ref, acc_ref)                    # (R, r_v) fp32
        bv = bv_ref[0]                                   # (r_v, Dh)
        o_ref[0, 0] = jnp.dot(u.astype(bv.dtype), bv,
                              preferred_element_type=jnp.float32
                              ).astype(o_ref.dtype)


def mla_decode_grouped_quant(qt: jax.Array, ck: jax.Array, cks: jax.Array,
                             cv: jax.Array, cvs: jax.Array, bv: jax.Array,
                             valid_len, *, scale: float, softcap=None,
                             bs: int = 512, interpret: bool = False
                             ) -> jax.Array:
    """``mla_decode_grouped`` over an int8 latent cache.

    qt: (B, Hkv, R, r_k) fp absorbed queries; ck: (B, S, r_k) int8;
    cks: (B, S, 1) fp32 per-row key scales; cv: (B, S, r_v) int8;
    cvs: (B, S, 1) fp32 per-row value scales; bv: (Hkv, r_v, Dh);
    valid_len: (B,) int32. Returns y: (B, Hkv, R, Dh)."""
    B, Hkv, R, r_k = qt.shape
    S, r_v = ck.shape[1], cv.shape[2]
    Dh = bv.shape[2]
    bs = _tile(S, bs)
    n_s = S // bs

    kernel = functools.partial(_mla_decode_grouped_quant_kernel, n_s=n_s,
                               bs=bs, scale=scale, softcap=softcap)
    return pl.pallas_call(
        kernel,
        grid=(B, Hkv, n_s),
        in_specs=[
            pl.BlockSpec((1, 1, R, r_k), lambda b, g, s: (b, g, 0, 0)),
            pl.BlockSpec((1, bs, r_k), lambda b, g, s: (b, s, 0)),
            pl.BlockSpec((1, bs, 1), lambda b, g, s: (b, s, 0)),
            pl.BlockSpec((1, bs, r_v), lambda b, g, s: (b, s, 0)),
            pl.BlockSpec((1, bs, 1), lambda b, g, s: (b, s, 0)),
            pl.BlockSpec((1, r_v, Dh), lambda b, g, s: (g, 0, 0)),
            pl.BlockSpec((1,), lambda b, g, s: (b,)),
        ],
        out_specs=pl.BlockSpec((1, 1, R, Dh), lambda b, g, s: (b, g, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hkv, R, Dh), qt.dtype),
        scratch_shapes=[
            pltpu.VMEM((R, 1), jnp.float32),
            pltpu.VMEM((R, 1), jnp.float32),
            pltpu.VMEM((R, r_v), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(qt, ck, cks, cv, cvs, bv, valid_len)


def _mla_decode_grouped_ring_quant_kernel(qt_ref, ck_ref, cks_ref, cv_ref,
                                          cvs_ref, bv_ref, start_ref,
                                          len_ref, o_ref, m_ref, l_ref,
                                          acc_ref, *, n_s: int, bs: int,
                                          n_total: int, scale: float,
                                          softcap):
    s_idx = pl.program_id(2)

    @pl.when(s_idx == 0)
    def _():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    qt = qt_ref[0, 0]           # (R, r_k)
    ck = ck_ref[0]              # (bs, r_k) int8
    cks = cks_ref[0]            # (bs, 1)
    cv = cv_ref[0]              # (bs, r_v) int8
    cvs = cvs_ref[0]            # (bs, 1)
    start = start_ref[0]
    length = len_ref[0]

    s = _dequant_scores(qt, ck, cks, scale)              # (R, bs)
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    t = s_idx * bs + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    mask = _ring_mask(t, start, length, n_total)
    s = jnp.where(mask, s, NEG_INF)
    _softmax_step(s, mask, m_ref, l_ref, acc_ref, cv.astype(jnp.float32),
                  p_scale=cvs[:, 0][None, :])

    @pl.when(s_idx == n_s - 1)
    def _():
        u = _finalize(l_ref, acc_ref)                    # (R, r_v) fp32
        bv = bv_ref[0]                                   # (r_v, Dh)
        o_ref[0, 0] = jnp.dot(u.astype(bv.dtype), bv,
                              preferred_element_type=jnp.float32
                              ).astype(o_ref.dtype)


def mla_decode_grouped_ring_quant(qt: jax.Array, ck: jax.Array,
                                  cks: jax.Array, cv: jax.Array,
                                  cvs: jax.Array, bv: jax.Array, start,
                                  length, *, scale: float, softcap=None,
                                  bs: int = 512, interpret: bool = False
                                  ) -> jax.Array:
    """``mla_decode_grouped_ring`` over an int8 latent cache (ring
    (start, length) validity, in-kernel dequant, fused decompression)."""
    B, Hkv, R, r_k = qt.shape
    S, r_v = ck.shape[1], cv.shape[2]
    Dh = bv.shape[2]
    bs = _tile(S, bs)
    n_s = S // bs

    kernel = functools.partial(_mla_decode_grouped_ring_quant_kernel,
                               n_s=n_s, bs=bs, n_total=S, scale=scale,
                               softcap=softcap)
    return pl.pallas_call(
        kernel,
        grid=(B, Hkv, n_s),
        in_specs=[
            pl.BlockSpec((1, 1, R, r_k), lambda b, g, s: (b, g, 0, 0)),
            pl.BlockSpec((1, bs, r_k), lambda b, g, s: (b, s, 0)),
            pl.BlockSpec((1, bs, 1), lambda b, g, s: (b, s, 0)),
            pl.BlockSpec((1, bs, r_v), lambda b, g, s: (b, s, 0)),
            pl.BlockSpec((1, bs, 1), lambda b, g, s: (b, s, 0)),
            pl.BlockSpec((1, r_v, Dh), lambda b, g, s: (g, 0, 0)),
            pl.BlockSpec((1,), lambda b, g, s: (b,)),
            pl.BlockSpec((1,), lambda b, g, s: (b,)),
        ],
        out_specs=pl.BlockSpec((1, 1, R, Dh), lambda b, g, s: (b, g, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hkv, R, Dh), qt.dtype),
        scratch_shapes=[
            pltpu.VMEM((R, 1), jnp.float32),
            pltpu.VMEM((R, 1), jnp.float32),
            pltpu.VMEM((R, r_v), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(qt, ck, cks, cv, cvs, bv, start, length)


def _mla_prefill_quant_kernel(qt_ref, ck_ref, cks_ref, cv_ref, cvs_ref,
                              len_ref, off_ref, o_ref, m_ref, l_ref,
                              acc_ref, *, n_s: int, bt: int, bs: int,
                              scale: float, softcap, causal: bool, window):
    t_idx = pl.program_id(2)
    s_idx = pl.program_id(3)
    off = off_ref[0]

    @pl.when(s_idx == 0)
    def _():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    def accumulate():
        qt = qt_ref[0, 0]       # (bt, r_k)
        ck = ck_ref[0]          # (bs, r_k) int8
        cks = cks_ref[0]        # (bs, 1)
        cv = cv_ref[0]          # (bs, r_v) int8
        cvs = cvs_ref[0]        # (bs, 1)
        valid_len = len_ref[0]

        s = _dequant_scores(qt, ck, cks, scale)
        if softcap:
            s = jnp.tanh(s / softcap) * softcap
        kpos = s_idx * bs + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = kpos < valid_len
        if causal or window is not None:
            qpos = off + t_idx * bt \
                + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        if causal:
            mask &= kpos <= qpos
        if window is not None:
            mask &= (qpos - kpos) < window
        s = jnp.where(mask, s, NEG_INF)
        _softmax_step(s, mask, m_ref, l_ref, acc_ref,
                      cv.astype(jnp.float32), p_scale=cvs[:, 0][None, :])

    if causal:
        live = s_idx * bs <= off + t_idx * bt + bt - 1
        if window is not None:
            live &= s_idx * bs + bs - 1 + window > off + t_idx * bt

        @pl.when(live)
        def _():
            accumulate()
    else:
        accumulate()

    @pl.when(s_idx == n_s - 1)
    def _():
        o_ref[0, 0] = _finalize(l_ref, acc_ref).astype(o_ref.dtype)


def mla_prefill_quant(qt: jax.Array, ck: jax.Array, cks: jax.Array,
                      cv: jax.Array, cvs: jax.Array, valid_len,
                      q_offsets=None, *, scale: float, softcap=None,
                      causal: bool = True, window=None, bt: int = 128,
                      bs: int = 512, interpret: bool = False) -> jax.Array:
    """``mla_prefill`` over an int8 latent cache: same causal / window /
    ragged masking and block pruning, keys and values dequantized
    in-kernel. qt: (B, H, T, r_k); ck/cv int8 with (B, S, 1) fp32
    scales. Returns u: (B, H, T, r_v)."""
    B, H, T, r_k = qt.shape
    S, r_v = ck.shape[1], cv.shape[2]
    if q_offsets is None:
        q_offsets = jnp.zeros((B,), jnp.int32)
    q_offsets = q_offsets.astype(jnp.int32)
    bt = _tile(T, bt)
    bs = _tile(S, bs)
    n_t, n_s = T // bt, S // bs

    kernel = functools.partial(_mla_prefill_quant_kernel, n_s=n_s, bt=bt,
                               bs=bs, scale=scale, softcap=softcap,
                               causal=causal, window=window)
    return pl.pallas_call(
        kernel,
        grid=(B, H, n_t, n_s),
        in_specs=[
            pl.BlockSpec((1, 1, bt, r_k), lambda b, h, t, s: (b, h, t, 0)),
            pl.BlockSpec((1, bs, r_k), lambda b, h, t, s: (b, s, 0)),
            pl.BlockSpec((1, bs, 1), lambda b, h, t, s: (b, s, 0)),
            pl.BlockSpec((1, bs, r_v), lambda b, h, t, s: (b, s, 0)),
            pl.BlockSpec((1, bs, 1), lambda b, h, t, s: (b, s, 0)),
            pl.BlockSpec((1,), lambda b, h, t, s: (b,)),
            pl.BlockSpec((1,), lambda b, h, t, s: (b,)),
        ],
        out_specs=pl.BlockSpec((1, 1, bt, r_v),
                               lambda b, h, t, s: (b, h, t, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, T, r_v), qt.dtype),
        scratch_shapes=[
            pltpu.VMEM((bt, 1), jnp.float32),
            pltpu.VMEM((bt, 1), jnp.float32),
            pltpu.VMEM((bt, r_v), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(qt, ck, cks, cv, cvs, valid_len, q_offsets)
