"""Pallas TPU kernel: latent (MLA) decode attention over a compressed
KV cache (paper §4.1/§4.2 payoff).

The cache holds LATENTS c_k (S, r_k), c_v (S, r_v) — never the
decompressed per-head keys/values. Queries arrive pre-absorbed
(q̃ᵢ = Hᵢᵀ A_q x ∈ R^{r_k}, DeepSeek-style absorption done in ops.py), so
the kernel computes, flash-style over sequence blocks:

    sᵢₜ   = q̃ᵢ · c_k[t]           (scores directly in latent space)
    uᵢ    = Σₜ softmax(sᵢ)ₜ c_v[t]  (values reduced in latent space)

Online softmax (running max/denominator in VMEM scratch) over the S axis;
per-head decompression of uᵢ happens outside on an (H, r_v) tensor —
S-independent. HBM traffic per step: S·(r_k+r_v) instead of
S·2·H·d_h — exactly the paper's KV-cache reduction.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams; support both.
_CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or pltpu.TPUCompilerParams

NEG_INF = -1e30


def _mla_decode_kernel(qt_ref, ck_ref, cv_ref, len_ref, o_ref,
                       m_ref, l_ref, acc_ref, *, n_s: int, bs: int,
                       scale: float):
    s_idx = pl.program_id(1)

    @pl.when(s_idx == 0)
    def _():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    qt = qt_ref[0]              # (H, r_k)
    ck = ck_ref[0]              # (bs, r_k)
    cv = cv_ref[0]              # (bs, r_v)
    valid_len = len_ref[0]      # tokens valid in the cache

    s = jnp.dot(qt, ck.T, preferred_element_type=jnp.float32) * scale  # (H, bs)
    t = s_idx * bs + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    s = jnp.where(t < valid_len, s, NEG_INF)

    m_prev = m_ref[...]                      # (H, 1)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.exp(s - m_new)                   # (H, bs)
    corr = jnp.exp(m_prev - m_new)           # (H, 1)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jnp.dot(
        p.astype(cv.dtype), cv, preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(s_idx == n_s - 1)
    def _():
        o_ref[0] = (acc_ref[...] / l_ref[...]).astype(o_ref.dtype)


def mla_decode(qt: jax.Array, ck: jax.Array, cv: jax.Array,
               valid_len, *, scale: float, bs: int = 512,
               interpret: bool = False) -> jax.Array:
    """qt: (B, H, r_k) absorbed queries; ck: (B, S, r_k); cv: (B, S, r_v);
    valid_len: (B,) int32 — number of live cache slots.
    Returns u: (B, H, r_v) latent-space attention outputs."""
    B, H, r_k = qt.shape
    S, r_v = ck.shape[1], cv.shape[2]
    bs = min(bs, S)
    assert S % bs == 0, (S, bs)
    n_s = S // bs

    kernel = functools.partial(_mla_decode_kernel, n_s=n_s, bs=bs,
                               scale=scale)
    return pl.pallas_call(
        kernel,
        grid=(B, n_s),
        in_specs=[
            pl.BlockSpec((1, H, r_k), lambda b, s: (b, 0, 0)),
            pl.BlockSpec((1, bs, r_k), lambda b, s: (b, s, 0)),
            pl.BlockSpec((1, bs, r_v), lambda b, s: (b, s, 0)),
            pl.BlockSpec((1,), lambda b, s: (b,)),
        ],
        out_specs=pl.BlockSpec((1, H, r_v), lambda b, s: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, r_v), qt.dtype),
        scratch_shapes=[
            pltpu.VMEM((H, 1), jnp.float32),
            pltpu.VMEM((H, 1), jnp.float32),
            pltpu.VMEM((H, r_v), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(qt, ck, cv, valid_len)
