"""Pure-jnp oracles for every Pallas kernel (allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def latent_matmul_ref(x, a2t, b, perm=None):
    """y = (x_id + x_rest @ a2t) @ b — dense reference."""
    r = a2t.shape[1]
    if perm is not None:
        x = jnp.take(x, jnp.asarray(perm), axis=1)
    z = x[:, :r] + x[:, r:].astype(jnp.float32) @ a2t.astype(jnp.float32)
    return (z.astype(jnp.float32) @ b.astype(jnp.float32)).astype(x.dtype)


def mla_decode_ref(qt, ck, cv, valid_len, *, scale, softcap=None):
    """qt: (B,H,r_k); ck: (B,S,r_k); cv: (B,S,r_v); valid_len: (B,).

    Rows with no valid key (valid_len == 0) return zeros, matching the
    kernel's all-masked guard."""
    s = jnp.einsum("bhk,bsk->bhs", qt.astype(jnp.float32),
                   ck.astype(jnp.float32)) * scale
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    mask = jnp.arange(ck.shape[1])[None, None, :] < valid_len[:, None, None]
    s = jnp.where(mask, s, -1e30)
    a = jax.nn.softmax(s, axis=-1)
    u = jnp.einsum("bhs,bsv->bhv", a, cv.astype(jnp.float32))
    u = jnp.where(valid_len[:, None, None] > 0, u, 0.0)
    return u.astype(qt.dtype)


def mla_decode_grouped_ref(qt, ck, cv, bv, valid_len, *, scale, softcap=None):
    """Grouped decode + fused value decompression oracle.

    qt: (B,Hkv,R,r_k); ck: (B,S,r_k); cv: (B,S,r_v); bv: (Hkv,r_v,Dh);
    valid_len: (B,). Returns (B,Hkv,R,Dh)."""
    B, Hkv, R, r_k = qt.shape
    u = mla_decode_ref(qt.reshape(B, Hkv * R, r_k), ck, cv, valid_len,
                       scale=scale, softcap=softcap)
    u = u.reshape(B, Hkv, R, -1).astype(jnp.float32)
    y = jnp.einsum("bgrv,gvd->bgrd", u, bv.astype(jnp.float32))
    return y.astype(qt.dtype)


def mla_decode_ring_ref(qt, ck, cv, start, length, *, scale, softcap=None):
    """Ring-cache decode oracle: live slots are the ring segment
    ``(start + i) % S, i < length`` per row (CacheLayout.ring_state).

    qt: (B,H,r_k); ck: (B,S,r_k); cv: (B,S,r_v); start/length: (B,).
    Rows with length == 0 return zeros (the kernel's all-masked guard)."""
    S = ck.shape[1]
    s = jnp.einsum("bhk,bsk->bhs", qt.astype(jnp.float32),
                   ck.astype(jnp.float32)) * scale
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    t = jnp.arange(S)
    off = (t[None, :] - start[:, None]) % S            # (B, S) >= 0
    mask = (off < length[:, None])[:, None, :]         # (B, 1, S)
    s = jnp.where(mask, s, -1e30)
    a = jax.nn.softmax(s, axis=-1)
    u = jnp.einsum("bhs,bsv->bhv", a, cv.astype(jnp.float32))
    u = jnp.where(length[:, None, None] > 0, u, 0.0)
    return u.astype(qt.dtype)


def mla_decode_grouped_ring_ref(qt, ck, cv, bv, start, length, *, scale,
                                softcap=None):
    """Grouped ring decode + fused value decompression oracle.

    qt: (B,Hkv,R,r_k); ck: (B,S,r_k); cv: (B,S,r_v); bv: (Hkv,r_v,Dh);
    start/length: (B,). Returns (B,Hkv,R,Dh)."""
    B, Hkv, R, r_k = qt.shape
    u = mla_decode_ring_ref(qt.reshape(B, Hkv * R, r_k), ck, cv, start,
                            length, scale=scale, softcap=softcap)
    u = u.reshape(B, Hkv, R, -1).astype(jnp.float32)
    y = jnp.einsum("bgrv,gvd->bgrd", u, bv.astype(jnp.float32))
    return y.astype(qt.dtype)


def mla_prefill_ref(qt, ck, cv, valid_len, q_offsets=None, *, scale,
                    softcap=None, causal=True, window=None):
    """Flash-prefill oracle (dense score tensor, fp32).

    qt: (B,H,T,r_k); ck: (B,S,r_k); cv: (B,S,r_v); valid_len: (B,).
    ``q_offsets`` (B,) shifts each row's queries to absolute position
    ``offset + t`` (the paged suffix prefill; default 0 = aligned).
    ``window=w`` masks keys more than w-1 behind their query.
    Returns u: (B,H,T,r_v). Query rows with no valid key return zeros."""
    B, H, T, _ = qt.shape
    S = ck.shape[1]
    s = jnp.einsum("bhtk,bsk->bhts", qt.astype(jnp.float32),
                   ck.astype(jnp.float32)) * scale
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    kpos = jnp.arange(S)
    qpos = jnp.arange(T)[None, :]                      # (1, T)
    if q_offsets is not None:
        qpos = qpos + q_offsets[:, None]               # (B, T)
    qpos = jnp.broadcast_to(qpos, (B, T))
    mask = kpos[None, :] < valid_len[:, None]          # (B, S)
    mask = mask[:, None, None, :]                      # (B, 1, 1, S)
    if causal:
        mask = mask & (kpos[None, None, None, :]
                       <= qpos[:, None, :, None])
    if window is not None:
        mask = mask & ((qpos[:, None, :, None]
                        - kpos[None, None, None, :]) < window)
    s = jnp.where(mask, s, -1e30)
    a = jax.nn.softmax(s, axis=-1)
    u = jnp.einsum("bhts,bsv->bhtv", a, cv.astype(jnp.float32))
    u = jnp.where(jnp.any(mask, axis=-1)[..., None], u, 0.0)
    return u.astype(qt.dtype)


# int8-cache oracles. These mirror the quant kernels' scale FACTORING,
# not just their math: scores are (q̃·c_k_int8ᵀ)·scale then ∘ s_kᵀ, and
# values fold s_v into the numerator while the softmax denominator keeps
# the raw p sum — the same association the kernels use. The sharded
# wrappers fall back to these refs when Hkv doesn't divide the model
# axis, and int8 grids make exact score ties common, so a
# different-but-equivalent float ordering here would flip greedy
# argmax ties between the mesh-fallback and single-device paths.


def _quant_softmax_values(s, mask, any_valid, cv, cvs):
    """u = (p ∘ s_vᵀ)·c_v / Σp with raw-p denominator (kernel order).

    s: (..., S) masked scores; mask: broadcastable to s; cv: (B,S,r_v)
    int8; cvs: (B,S,1). Rows with no valid key return zeros."""
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    p = jnp.where(mask, p, 0.0)
    l = jnp.sum(p, axis=-1, keepdims=True)
    pv = p * jnp.moveaxis(cvs, -2, -1)                 # fold s_v per key
    u = pv @ cv.astype(jnp.float32) / jnp.maximum(l, 1e-30)
    return jnp.where(any_valid, u, 0.0)


def mla_decode_grouped_quant_ref(qt, ck, cks, cv, cvs, bv, valid_len, *,
                                 scale, softcap=None):
    """int8-cache grouped decode oracle.

    qt: (B,Hkv,R,r_k); ck/cv: int8 (B,S,r); cks/cvs: (B,S,1) fp32
    per-row scales; bv: (Hkv,r_v,Dh). Returns (B,Hkv,R,Dh)."""
    B, Hkv, R, r_k = qt.shape
    q2 = qt.reshape(B, Hkv * R, r_k).astype(jnp.float32)
    s = jnp.einsum("bhk,bsk->bhs", q2, ck.astype(jnp.float32)) * scale
    s = s * cks[:, :, 0][:, None, :]
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    mask = jnp.arange(ck.shape[1])[None, None, :] < valid_len[:, None, None]
    s = jnp.where(mask, s, -1e30)
    u = _quant_softmax_values(s, mask, valid_len[:, None, None] > 0,
                              cv, cvs)
    u = u.reshape(B, Hkv, R, -1)
    y = jnp.einsum("bgrv,gvd->bgrd", u, bv.astype(jnp.float32))
    return y.astype(qt.dtype)


def mla_decode_grouped_ring_quant_ref(qt, ck, cks, cv, cvs, bv, start,
                                      length, *, scale, softcap=None):
    """int8-cache grouped RING decode oracle: validity is the wrapped
    segment ``(start + i) % S, i < length`` per row."""
    B, Hkv, R, r_k = qt.shape
    S = ck.shape[1]
    q2 = qt.reshape(B, Hkv * R, r_k).astype(jnp.float32)
    s = jnp.einsum("bhk,bsk->bhs", q2, ck.astype(jnp.float32)) * scale
    s = s * cks[:, :, 0][:, None, :]
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    t = jnp.arange(S)
    off = (t[None, :] - start[:, None]) % S
    mask = (off < length[:, None])[:, None, :]
    s = jnp.where(mask, s, -1e30)
    u = _quant_softmax_values(s, mask, length[:, None, None] > 0, cv, cvs)
    u = u.reshape(B, Hkv, R, -1)
    y = jnp.einsum("bgrv,gvd->bgrd", u, bv.astype(jnp.float32))
    return y.astype(qt.dtype)


def mla_prefill_quant_ref(qt, ck, cks, cv, cvs, valid_len, q_offsets=None,
                          *, scale, softcap=None, causal=True, window=None):
    """int8-cache flash-prefill oracle (dense scores, kernel's scale
    factoring). Same masking contract as ``mla_prefill_ref``."""
    B, H, T, _ = qt.shape
    S = ck.shape[1]
    s = jnp.einsum("bhtk,bsk->bhts", qt.astype(jnp.float32),
                   ck.astype(jnp.float32)) * scale
    s = s * cks[:, :, 0][:, None, None, :]
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    kpos = jnp.arange(S)
    qpos = jnp.arange(T)[None, :]
    if q_offsets is not None:
        qpos = qpos + q_offsets[:, None]
    qpos = jnp.broadcast_to(qpos, (B, T))
    mask = kpos[None, :] < valid_len[:, None]
    mask = mask[:, None, None, :]
    if causal:
        mask = mask & (kpos[None, None, None, :]
                       <= qpos[:, None, :, None])
    if window is not None:
        mask = mask & ((qpos[:, None, :, None]
                        - kpos[None, None, None, :]) < window)
    s = jnp.where(mask, s, -1e30)
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    p = jnp.where(mask, p, 0.0)
    l = jnp.sum(p, axis=-1, keepdims=True)
    pv = p * cvs[:, :, 0][:, None, None, :]
    u = jnp.einsum("bhts,bsv->bhtv", pv, cv.astype(jnp.float32)) \
        / jnp.maximum(l, 1e-30)
    u = jnp.where(jnp.any(mask, axis=-1)[..., None], u, 0.0)
    return u.astype(qt.dtype)


def ssd_scan_ref(x, dt, A, Bm, Cm, *, chunk=128):
    """Sequential-recurrence oracle (token by token, fp32)."""
    B, S, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    R = H // G
    x32 = x.astype(jnp.float32)
    dt32 = dt.astype(jnp.float32)

    def step(state, inp):
        xt, dtt, bt, ct = inp          # (B,H,P),(B,H),(B,G,N),(B,G,N)
        dA = jnp.exp(dtt * A[None, :])                 # (B,H)
        bth = jnp.repeat(bt, R, axis=1)                # (B,H,N)
        cth = jnp.repeat(ct, R, axis=1)
        dBx = jnp.einsum("bhn,bhp,bh->bhpn", bth, xt, dtt)
        state = state * dA[:, :, None, None] + dBx
        y = jnp.einsum("bhpn,bhn->bhp", state, cth)
        return state, y

    state0 = jnp.zeros((B, H, P, N), jnp.float32)
    xs = (x32.transpose(1, 0, 2, 3), dt32.transpose(1, 0, 2),
          Bm.astype(jnp.float32).transpose(1, 0, 2, 3),
          Cm.astype(jnp.float32).transpose(1, 0, 2, 3))
    state, ys = jax.lax.scan(step, state0, xs)
    y = ys.transpose(1, 0, 2, 3).astype(x.dtype)
    return y, state
