"""gemma2-27b — local/global alternating attention + logit softcaps
[arXiv:2408.00118; hf].

46L d_model=4608 32H (GQA kv=16) d_ff=36864 vocab=256000.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-27b",
    family="dense",
    num_layers=46,
    d_model=4608,
    num_heads=32,
    num_kv_heads=16,
    head_dim=128,
    d_ff=36864,
    vocab_size=256000,
    sliding_window=4096,
    local_global_period=2,  # layer 2i local(SWA), layer 2i+1 global
    attn_logit_softcap=50.0,
    final_logit_softcap=30.0,
    activation="gelu",
    gated_mlp=True,
    rope_theta=1e4,
    norm="rmsnorm",
    tie_embeddings=True,
)
