"""chameleon-34b — early-fusion VLM, VQ image tokens [arXiv:2405.09818].

48L d_model=8192 64H (GQA kv=8) d_ff=22016 vocab=65536.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="chameleon-34b",
    family="vlm",
    num_layers=48,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=22016,
    vocab_size=65536,
    activation="silu",
    gated_mlp=True,
    rope_theta=1e4,
    norm="rmsnorm",
    # VQ image tokens are ordinary vocabulary entries (early fusion);
    # frontend (VQ-GAN tokenizer) is a stub — inputs are token ids.
    input_mode="tokens",
)
