"""musicgen-large — decoder-only over EnCodec tokens [arXiv:2306.05284; hf].

48L d_model=2048 32H (GQA kv=32 => MHA) d_ff=8192 vocab=2048.
Modality frontend (EnCodec) is a STUB: input_specs() provides precomputed
frame embeddings (B, S, d_model).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-large",
    family="audio",
    num_layers=48,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    d_ff=8192,
    vocab_size=2048,
    activation="gelu",
    gated_mlp=False,
    pos_emb="learned",
    norm="layernorm",
    qkv_bias=False,
    input_mode="embeddings",
    max_position_embeddings=1 << 20,
)
