"""zamba2-7b — Mamba2 backbone + shared attention blocks [arXiv:2411.15242].

81L d_model=3584 32H (GQA kv=32) d_ff=14336 vocab=32000, ssm_state=64.
Every 6th layer additionally applies the single *shared* attention+MLP
block (weights reused at every application, as in Zamba2).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b",
    family="hybrid",
    num_layers=81,
    d_model=3584,
    num_heads=32,
    num_kv_heads=32,
    head_dim=112,
    d_ff=14336,
    vocab_size=32000,
    ssm_state=64,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_ngroups=1,
    ssm_chunk=256,
    hybrid_attn_period=6,
    activation="gelu",
    gated_mlp=True,
    rope_theta=1e4,
    norm="rmsnorm",
    tie_embeddings=True,
)
