"""OPT model family — the paper's own LLM testbed (Tab. 5, [49]).

OPT uses ReLU MLP (non-gated), learned positional embeddings, LayerNorm,
and biases everywhere — exactly the setting where the paper's closed-form
joint-UD update (App. H) is exact.
"""
from repro.configs.base import ModelConfig


def _opt(name, L, h, d, d_h):
    return ModelConfig(
        name=name,
        family="dense",
        num_layers=L,
        d_model=d,
        num_heads=h,
        num_kv_heads=h,
        head_dim=d_h,
        d_ff=4 * d,
        vocab_size=50272,
        qkv_bias=True,
        o_bias=True,
        mlp_bias=True,
        activation="relu",
        gated_mlp=False,
        pos_emb="learned",
        norm="layernorm",
        max_position_embeddings=2048,
        tie_embeddings=True,
    )


OPT_125M = _opt("opt-125m", 12, 12, 768, 64)
OPT_350M = _opt("opt-350m", 24, 16, 1024, 64)
OPT_1_3B = _opt("opt-1.3b", 24, 32, 2048, 64)
OPT_2_7B = _opt("opt-2.7b", 32, 32, 2560, 80)
OPT_6_7B = _opt("opt-6.7b", 32, 32, 4096, 128)
OPT_13B = _opt("opt-13b", 40, 40, 5120, 128)

CONFIG = OPT_125M  # default member exposed to the registry
