"""mamba2-2.7b — SSD (state-space duality), attention-free [arXiv:2405.21060].

64L d_model=2560 (attn-free) d_ff=0 vocab=50280, ssm_state=128.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-2.7b",
    family="ssm",
    num_layers=64,
    d_model=2560,
    num_heads=0,
    num_kv_heads=0,
    head_dim=64,
    d_ff=0,
    vocab_size=50280,
    ssm_state=128,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_ngroups=1,
    ssm_chunk=256,
    pos_emb="none",
    gated_mlp=False,
    norm="rmsnorm",
    tie_embeddings=True,
)
