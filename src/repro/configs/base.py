"""Model / shape configuration dataclasses for the repro framework.

Every assigned architecture is expressed as a ``ModelConfig``; input shapes
are ``ShapeConfig``; the paper's compression knobs are ``LatentConfig``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class LatentConfig:
    """LatentLLM compression configuration (the paper's technique).

    ``rank_ratio`` r/d applied uniformly unless per-module ranks are given.
    ``preconditioner`` selects the Tab. 1 variant.
    """

    enabled: bool = False
    # target *size reduction* in (0,1); ranks derived per module pair.
    compression: float = 0.2
    # default registered compression method (core.compress registry);
    # a CompressionPlan can override per layer/module.
    method: str = "latentllm"
    preconditioner: str = "rootcov"  # identity|hessian|l1|l2|cov|rootcov
    junction: str = "block_identity"  # identity|right|symmetric|block_identity
    joint_qk: bool = True
    joint_vo: bool = False  # paper Remark 11: split V/O usually better
    joint_ud: bool = True
    qk_iters: int = 8
    ud_iters: int = 4
    damping: float = 1e-2  # lambda, relative to mean diag of C
    # latent KV-cache storage dtype: "fp" keeps c_k/c_v in the model
    # compute dtype; "int8" stores symmetric per-row int8 with fp32
    # scales and dequantizes inside the absorbed kernels.
    cache_dtype: str = "fp"


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None  # default d_model // num_heads

    # --- attention variants ---
    qkv_bias: bool = False
    o_bias: bool = False
    attn_logit_softcap: Optional[float] = None
    final_logit_softcap: Optional[float] = None
    sliding_window: Optional[int] = None  # SWA width (h2o-danube3)
    local_global_period: Optional[int] = None  # gemma2: every 2nd layer global
    rope_theta: float = 1e4
    pos_emb: str = "rope"  # rope | learned | none

    # --- MLP variants ---
    activation: str = "silu"  # silu | gelu | relu
    gated_mlp: bool = True
    mlp_bias: bool = False

    # --- MoE ---
    num_experts: int = 0
    num_experts_per_tok: int = 0
    moe_layer_period: int = 1  # 1 = every layer is MoE; 2 = alternate
    num_shared_experts: int = 0
    capacity_factor: float = 1.25

    # --- SSM (Mamba2 / SSD) ---
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_ngroups: int = 1
    ssm_chunk: int = 128
    ssm_conv_width: int = 4

    # --- hybrid (zamba2) ---
    hybrid_attn_period: int = 0  # every k-th layer also runs shared attn block

    # --- misc ---
    norm: str = "rmsnorm"  # rmsnorm | layernorm
    tie_embeddings: bool = False
    input_mode: str = "tokens"  # tokens | embeddings (stub frontend)
    max_position_embeddings: int = 1 << 20
    dtype: str = "bfloat16"
    param_dtype: str = "float32"

    # paper's technique
    latent: LatentConfig = dataclasses.field(default_factory=LatentConfig)

    def __post_init__(self):
        if self.head_dim is None:
            object.__setattr__(self, "head_dim", self.d_model // max(self.num_heads, 1))

    # ------------------------------------------------------------------
    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def has_ssm(self) -> bool:
        return self.ssm_state > 0

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_nheads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def q_dim(self) -> int:
        return self.num_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim

    def num_params(self) -> int:
        """Analytic parameter count (embedding + blocks + head)."""
        d, L = self.d_model, self.num_layers
        n = self.vocab_size * d  # embed
        if not self.tie_embeddings:
            n += self.vocab_size * d
        per_attn = d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d
        if self.qkv_bias:
            per_attn += self.q_dim + 2 * self.kv_dim
        mlp_mats = 3 if self.gated_mlp else 2
        per_mlp_dense = mlp_mats * d * self.d_ff
        n_moe_layers = 0
        n_dense_layers = L
        if self.num_experts:
            n_moe_layers = L // self.moe_layer_period
            n_dense_layers = L - n_moe_layers
        if self.has_ssm:
            # mamba2 block: in_proj(d -> 2*d_inner + 2*ngroups*state + nheads),
            # conv (d_inner+2*g*state)*width, out_proj(d_inner -> d)
            di = self.d_inner
            conv_dim = di + 2 * self.ssm_ngroups * self.ssm_state
            per_ssm = d * (2 * di + 2 * self.ssm_ngroups * self.ssm_state + self.ssm_nheads)
            per_ssm += conv_dim * self.ssm_conv_width
            per_ssm += di * d
            per_ssm += 3 * self.ssm_nheads  # A_log, dt_bias, D
        else:
            per_ssm = 0

        if self.family == "ssm":
            n += L * (per_ssm + 2 * d)  # norm scales
            if self.d_ff:
                n += L * per_mlp_dense
        elif self.family == "hybrid":
            n += L * (per_ssm + 2 * d)
            # one shared attention+mlp block
            n += per_attn + per_mlp_dense + 2 * d
        else:
            n += n_dense_layers * per_mlp_dense
            if self.num_experts:
                per_moe = self.num_experts * mlp_mats * d * self.d_ff + d * self.num_experts
                per_moe += self.num_shared_experts * mlp_mats * d * self.d_ff
                n += n_moe_layers * per_moe
            n += L * (per_attn + 2 * d)
        n += d  # final norm
        return n

    def num_active_params(self) -> int:
        """Active parameters per token (for MoE 6·N_active·D flops)."""
        if not self.num_experts:
            return self.num_params()
        d, L = self.d_model, self.num_layers
        mlp_mats = 3 if self.gated_mlp else 2
        n_moe_layers = L // self.moe_layer_period
        inactive = n_moe_layers * (self.num_experts - self.num_experts_per_tok) * mlp_mats * d * self.d_ff
        return self.num_params() - inactive


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


TRAIN_4K = ShapeConfig("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524288, 1, "decode")

SHAPES = {s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}

# Archs allowed to run the long_500k cell (sub-quadratic attention only).
SUBQUADRATIC = {"mamba2-2.7b", "zamba2-7b", "h2o-danube-3-4b"}


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Whether an (arch, shape) cell is runnable; reason if skipped."""
    if shape.name == "long_500k" and cfg.name not in SUBQUADRATIC:
        return False, "full-attention arch: 512k dense decode skipped (DESIGN.md §5)"
    return True, ""


def dtype_of(cfg: ModelConfig):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
