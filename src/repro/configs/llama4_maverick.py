"""llama4-maverick-400b-a17b — MoE 128e top-1, early fusion
[hf:meta-llama/Llama-4 family].

48L d_model=5120 40H (GQA kv=8) d_ff=8192 vocab=202048, MoE every other
layer with 128 routed experts (top-1) + 1 shared expert.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=202048,
    num_experts=128,
    num_experts_per_tok=1,
    moe_layer_period=2,  # alternate dense / MoE
    num_shared_experts=1,
    activation="silu",
    gated_mlp=True,
    rope_theta=5e5,
    norm="rmsnorm",
    input_mode="tokens",  # early fusion: image patches are tokens (stub frontend)
)
