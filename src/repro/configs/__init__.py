"""Config registry: ``--arch <id>`` resolution, reduced smoke configs,
and ShapeDtypeStruct input specs for the dry-run.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import (
    LatentConfig,
    ModelConfig,
    ShapeConfig,
    SHAPES,
    TRAIN_4K,
    PREFILL_32K,
    DECODE_32K,
    LONG_500K,
    SUBQUADRATIC,
    shape_applicable,
)

from repro.configs.mamba2_2p7b import CONFIG as MAMBA2_2P7B
from repro.configs.chameleon_34b import CONFIG as CHAMELEON_34B
from repro.configs.musicgen_large import CONFIG as MUSICGEN_LARGE
from repro.configs.qwen1p5_110b import CONFIG as QWEN1P5_110B
from repro.configs.h2o_danube3_4b import CONFIG as H2O_DANUBE3_4B
from repro.configs.gemma2_27b import CONFIG as GEMMA2_27B
from repro.configs.deepseek_coder_33b import CONFIG as DEEPSEEK_CODER_33B
from repro.configs.phi3p5_moe import CONFIG as PHI35_MOE
from repro.configs.llama4_maverick import CONFIG as LLAMA4_MAVERICK
from repro.configs.zamba2_7b import CONFIG as ZAMBA2_7B
from repro.configs import opt_family

REGISTRY: Dict[str, ModelConfig] = {
    c.name: c
    for c in (
        MAMBA2_2P7B,
        CHAMELEON_34B,
        MUSICGEN_LARGE,
        QWEN1P5_110B,
        H2O_DANUBE3_4B,
        GEMMA2_27B,
        DEEPSEEK_CODER_33B,
        PHI35_MOE,
        LLAMA4_MAVERICK,
        ZAMBA2_7B,
        opt_family.OPT_125M,
        opt_family.OPT_350M,
        opt_family.OPT_1_3B,
        opt_family.OPT_2_7B,
        opt_family.OPT_6_7B,
        opt_family.OPT_13B,
    )
}

ASSIGNED = [
    "mamba2-2.7b",
    "chameleon-34b",
    "musicgen-large",
    "qwen1.5-110b",
    "h2o-danube-3-4b",
    "gemma2-27b",
    "deepseek-coder-33b",
    "phi3.5-moe-42b-a6.6b",
    "llama4-maverick-400b-a17b",
    "zamba2-7b",
]


def get_config(name: str, latent: Optional[LatentConfig] = None) -> ModelConfig:
    cfg = REGISTRY[name]
    if latent is not None:
        cfg = dataclasses.replace(cfg, latent=latent)
    return cfg


# ----------------------------------------------------------------------
# Reduced configs for CPU smoke tests: same family/wiring, tiny sizes.
# ----------------------------------------------------------------------

def reduced(cfg: ModelConfig, *, layers: int = 2, d_model: int = 64,
            vocab: int = 257) -> ModelConfig:
    heads = min(cfg.num_heads, 4) if cfg.num_heads else 0
    kv = 0
    if cfg.num_kv_heads:
        # keep the GQA ratio alive where possible
        kv = max(1, heads * cfg.num_kv_heads // max(cfg.num_heads, 1))
    head_dim = d_model // heads if heads else 16
    n_layers = layers
    if cfg.hybrid_attn_period:
        n_layers = max(layers, cfg.hybrid_attn_period + 1)  # hit the shared block
    if cfg.local_global_period:
        n_layers = max(layers, cfg.local_global_period)
    if cfg.num_experts and cfg.moe_layer_period > 1:
        n_layers = max(layers, cfg.moe_layer_period)
    return dataclasses.replace(
        cfg,
        num_layers=n_layers,
        d_model=d_model,
        num_heads=heads,
        num_kv_heads=kv,
        head_dim=head_dim,
        d_ff=0 if cfg.d_ff == 0 else d_model * 4,
        vocab_size=vocab,
        num_experts=min(cfg.num_experts, 4) if cfg.num_experts else 0,
        num_experts_per_tok=min(cfg.num_experts_per_tok, 2) if cfg.num_experts else 0,
        ssm_state=min(cfg.ssm_state, 16) if cfg.ssm_state else 0,
        ssm_head_dim=16 if cfg.ssm_state else cfg.ssm_head_dim,
        ssm_chunk=16 if cfg.ssm_state else cfg.ssm_chunk,
        sliding_window=min(cfg.sliding_window, 16) if cfg.sliding_window else None,
        max_position_embeddings=4096,
    )


# ----------------------------------------------------------------------
# input_specs: ShapeDtypeStruct stand-ins for every model input.
# ----------------------------------------------------------------------

def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, jax.ShapeDtypeStruct]:
    """Weak-type-correct, shardable, allocation-free input stand-ins."""
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        if cfg.input_mode == "embeddings":
            return {
                "frames": jax.ShapeDtypeStruct((B, S, cfg.d_model), jnp.bfloat16),
                "labels": jax.ShapeDtypeStruct((B, S), jnp.int32),
            }
        return {
            "tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
            "labels": jax.ShapeDtypeStruct((B, S), jnp.int32),
        }
    if shape.kind == "prefill":
        if cfg.input_mode == "embeddings":
            return {"frames": jax.ShapeDtypeStruct((B, S, cfg.d_model), jnp.bfloat16)}
        return {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32)}
    if shape.kind == "decode":
        # one new token against a KV/state cache of S tokens
        if cfg.input_mode == "embeddings":
            tok = {"frames": jax.ShapeDtypeStruct((B, 1, cfg.d_model), jnp.bfloat16)}
        else:
            tok = {"tokens": jax.ShapeDtypeStruct((B, 1), jnp.int32)}
        return tok
    raise ValueError(shape.kind)


__all__ = [
    "REGISTRY",
    "ASSIGNED",
    "SHAPES",
    "TRAIN_4K",
    "PREFILL_32K",
    "DECODE_32K",
    "LONG_500K",
    "SUBQUADRATIC",
    "LatentConfig",
    "ModelConfig",
    "ShapeConfig",
    "get_config",
    "reduced",
    "input_specs",
    "shape_applicable",
]
