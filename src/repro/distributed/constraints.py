"""Activation sharding constraints that degrade gracefully.

``constrain(x, prefs_per_dim)`` applies ``with_sharding_constraint`` using
the first divisible axis preference per dim — but only when a mesh is
active (smoke tests on 1 device trace the same code with no mesh and the
helper becomes a no-op). Preferences use the same fallback machinery as
the parameter rules (distributed/sharding.py) so one call site serves
every architecture in the pool.
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax
from jax.sharding import Mesh, PartitionSpec as P

from repro.distributed.sharding import Axis, spec_from_prefs


def current_mesh() -> Optional[Mesh]:
    try:
        m = jax.sharding.get_abstract_mesh()
        if m is not None and m.axis_names:
            return m
    except Exception:
        pass
    try:  # legacy `with mesh:` context
        from jax.interpreters.pxla import thread_resources
        pm = thread_resources.env.physical_mesh
        if pm is not None and not pm.empty:
            return pm
    except Exception:
        pass
    return None


def batch_prefs(mesh) -> list:
    if "pod" in mesh.axis_names:
        return [("pod", "data"), "data", None]
    return ["data", None]


def constrain(x: jax.Array, prefs_per_dim: Sequence[Sequence[Axis]]) -> jax.Array:
    mesh = current_mesh()
    if mesh is None:
        return x
    # drop prefs that mention axes this mesh doesn't have
    clean = []
    for prefs in prefs_per_dim:
        kept = []
        for p in prefs:
            if p is None:
                kept.append(None)
                continue
            names = (p,) if isinstance(p, str) else tuple(p)
            if all(n in mesh.axis_names for n in names):
                kept.append(p)
        if not kept or kept[-1] is not None:
            kept.append(None)
        clean.append(kept)
    spec = spec_from_prefs(mesh, x.shape, clean)
    return jax.lax.with_sharding_constraint(x, spec)


def constrain_bsd(x: jax.Array) -> jax.Array:
    """(B, S, d): batch on (pod, data) — else sequence — d replicated."""
    mesh = current_mesh()
    if mesh is None:
        return x
    ba = batch_prefs(mesh)
    return constrain(x, [ba, ba, [None]])


def constrain_bsf(x: jax.Array) -> jax.Array:
    """(B, S, F): batch on data axes, features on 'model' (hidden/qkv)."""
    mesh = current_mesh()
    if mesh is None:
        return x
    ba = batch_prefs(mesh)
    return constrain(x, [ba, ba, ["model", None]])


def constrain_heads(x: jax.Array, head_dims=(2, 3), seq_dim=1) -> jax.Array:
    """Attention tensors (B, S, G, R, Dh) / (B, S, H, Dh): put 'model' on a
    HEAD dim when one divides; otherwise shard the SEQUENCE dim (sequence-
    parallel attention). NEVER shard Dh — contracting a sharded head_dim
    in the scores einsum forces a full-scores all-reduce (measured 3.7 TB
    per prefill on deepseek-coder; EXPERIMENTS.md §Perf/B1)."""
    mesh = current_mesh()
    if mesh is None or "model" not in mesh.axis_names:
        return x
    msize = mesh.shape["model"]
    ba = batch_prefs(mesh)
    spec = [None] * x.ndim
    # batch first
    for p in ba:
        if p is None:
            break
        names = (p,) if isinstance(p, str) else tuple(p)
        sz = 1
        for n in names:
            sz *= mesh.shape[n]
        if x.shape[0] % sz == 0:
            spec[0] = p
            break
    placed = False
    for hd in head_dims:
        if hd < x.ndim - 1 and x.shape[hd] % msize == 0:
            spec[hd] = "model"
            placed = True
            break
    if not placed:
        total_heads = 1
        for hd in head_dims:
            if hd < x.ndim - 1:
                total_heads *= x.shape[hd]
        if total_heads % msize == 0:
            # GSPMD can mix-tile the head dims (e.g. 8×2 over 16) — leave
            # it unconstrained; overriding measurably regresses (§Perf/A4)
            return x
        if seq_dim is not None and x.shape[seq_dim] % msize == 0:
            spec[seq_dim] = "model"
    from jax.sharding import PartitionSpec as P
    return jax.lax.with_sharding_constraint(x, P(*spec))
