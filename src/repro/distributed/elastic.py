"""Elastic / fault-tolerance manager (launcher-level control plane).

On a real cluster each pod runs one controller process; this module is the
logic they execute. It is deliberately free of jax.distributed specifics
so the unit tests drive it directly:

  - heartbeat tracking with a deadline -> failed-node detection;
  - straggler mitigation: a step that exceeds ``straggler_factor`` × the
    trailing-median step time marks the slowest shard for replacement and
    the step is REPLAYED from the deterministic data pipeline (no data
    loss, no divergence — batches are keyed by (seed, step, shard));
  - elastic re-mesh: on membership change, pick the largest feasible mesh
    from the survivor count, restore the latest checkpoint under the new
    named shardings (CheckpointManager is mesh-shape-agnostic), and
    continue from the recorded step.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Tuple


@dataclasses.dataclass
class NodeState:
    last_heartbeat: float
    step_times: List[float] = dataclasses.field(default_factory=list)
    healthy: bool = True


@dataclasses.dataclass
class ElasticConfig:
    heartbeat_timeout_s: float = 60.0
    straggler_factor: float = 2.5
    straggler_window: int = 16
    min_nodes: int = 1


class ElasticManager:
    def __init__(self, nodes: List[str], cfg: ElasticConfig = ElasticConfig(),
                 clock: Callable[[], float] = time.monotonic):
        self.cfg = cfg
        self.clock = clock
        self.nodes: Dict[str, NodeState] = {
            n: NodeState(last_heartbeat=clock()) for n in nodes}
        self.generation = 0  # bumps on every membership change

    # ----------------------------------------------------------- health
    def heartbeat(self, node: str, step_time: Optional[float] = None):
        st = self.nodes[node]
        st.last_heartbeat = self.clock()
        if step_time is not None:
            st.step_times.append(step_time)
            st.step_times = st.step_times[-self.cfg.straggler_window:]

    def failed_nodes(self) -> List[str]:
        now = self.clock()
        return [n for n, st in self.nodes.items()
                if st.healthy and now - st.last_heartbeat
                > self.cfg.heartbeat_timeout_s]

    def stragglers(self) -> List[str]:
        times = []
        for st in self.nodes.values():
            if st.healthy and st.step_times:
                times.append(st.step_times[-1])
        if len(times) < 3:
            return []
        med = sorted(times)[len(times) // 2]
        out = []
        for n, st in self.nodes.items():
            if st.healthy and st.step_times \
                    and st.step_times[-1] > self.cfg.straggler_factor * med:
                out.append(n)
        return out

    # ---------------------------------------------------------- elastic
    def evict(self, nodes: List[str]) -> bool:
        changed = False
        for n in nodes:
            if self.nodes[n].healthy:
                self.nodes[n].healthy = False
                changed = True
        if changed:
            self.generation += 1
        return changed

    def join(self, node: str):
        self.nodes[node] = NodeState(last_heartbeat=self.clock())
        self.generation += 1

    def healthy_count(self) -> int:
        return sum(st.healthy for st in self.nodes.values())

    def feasible_mesh(self, chips_per_node: int,
                      model_parallel: int) -> Optional[Tuple[int, int]]:
        """Largest (data, model) mesh from the survivors: model axis fixed
        by the sharding plan, data axis = largest power-of-two that fits."""
        chips = self.healthy_count() * chips_per_node
        if chips < model_parallel * self.cfg.min_nodes:
            return None
        data = chips // model_parallel
        # largest power of two <= data (keeps batch divisibility simple)
        p = 1
        while p * 2 <= data:
            p *= 2
        return (p, model_parallel)

    def tick(self) -> Dict[str, object]:
        """One control-loop iteration: detect, evict, report actions."""
        failed = self.failed_nodes()
        stragglers = self.stragglers()
        actions: Dict[str, object] = {"failed": failed,
                                      "stragglers": stragglers,
                                      "generation": self.generation}
        if failed:
            self.evict(failed)
            actions["remesh"] = True
        elif stragglers:
            # replace-and-replay: straggler is evicted only if it repeats
            for n in stragglers:
                st = self.nodes[n]
                slow = sum(1 for t in st.step_times[-3:]
                           if t > self.cfg.straggler_factor
                           * min(x.step_times[-1] for x in self.nodes.values()
                                 if x.healthy and x.step_times))
                if slow >= 3:
                    self.evict([n])
                    actions["remesh"] = True
        actions["generation_after"] = self.generation
        return actions
