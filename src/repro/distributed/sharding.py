"""Sharding rules: params / optimizer state / batches / caches -> PartitionSpec.

Strategy (DESIGN.md §4):
  'pod'   — outer data parallelism (hierarchical all-reduce across pods)
  'data'  — FSDP axis: batch AND parameter d_model dims sharded here
  'model' — tensor parallelism: heads / d_ff / experts / vocab

Every rule is divisibility-checked against the mesh; a dim that does not
divide falls back to the next preference (eventually replication), so the
same rules serve every architecture in the pool.
"""
from __future__ import annotations

import re
from typing import Any, Dict, Optional, Sequence, Tuple, Union

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Axis = Union[None, str, Tuple[str, ...]]


def batch_axes(mesh: Mesh) -> Tuple[str, ...]:
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def _axis_size(mesh: Mesh, axis: Axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, str):
        return mesh.shape[axis]
    n = 1
    for a in axis:
        n *= mesh.shape[a]
    return n


def _fit(mesh: Mesh, dim: int, prefs: Sequence[Axis], used: set) -> Axis:
    """First preference whose size divides ``dim`` and whose axes are unused."""
    for pref in prefs:
        if pref is None:
            return None
        names = (pref,) if isinstance(pref, str) else tuple(pref)
        if any(a in used for a in names):
            continue
        if dim % _axis_size(mesh, pref) == 0:
            return pref
    return None


def spec_from_prefs(mesh: Mesh, shape: Sequence[int],
                    prefs_per_dim: Sequence[Sequence[Axis]]) -> P:
    used: set = set()
    out = []
    for dim, prefs in zip(shape, prefs_per_dim):
        ax = _fit(mesh, dim, prefs, used)
        out.append(ax)
        if ax is not None:
            for a in ((ax,) if isinstance(ax, str) else ax):
                used.add(a)
    return P(*out)


# ----------------------------------------------------------------------
# parameter rules, matched on the flattened tree-path suffix
# ----------------------------------------------------------------------
# Each entry: (path regex, prefs for the LAST ndims dims; leading dims None)
M = "model"
D = "data"
_RULES = [
    # embeddings / heads
    (r"\['embed'\]$",                     [[M, None], [D, None]]),
    (r"\['pos_embed'\]$",                 [[None], [D, None]]),
    (r"\['lm_head'\]$",                   [[D, None], [M, None]]),
    # attention dense
    (r"\['attn'\]\['[qkv]'\]\['w'\]$",    [[D, None], [M, None]]),
    (r"\['attn'\]\['[qkv]'\]\['b'\]$",    [[M, None]]),
    (r"\['attn'\]\['o'\]\['w'\]$",        [[M, None], [D, None]]),
    (r"\['attn'\]\['o'\]\['b'\]$",        [[None]]),
    # attention latent (the paper's MLA form)
    (r"\['attn'\]\['a_[qkv]'\]$",         [[D, None], [None]]),
    (r"\['attn'\]\['b_[qkv]'\]$",         [[M, None], [None], [None]]),
    (r"\['attn'\]\['a_o'\]$",             [[M, None], [None]]),
    (r"\['attn'\]\['b_o'\]$",             [[None], [D, None]]),
    (r"\['attn'\]\['bias_[qkvo]'\]$",     [[M, None]]),
    # MLP dense
    (r"\['mlp'\]\['(up|gate)'\]\['w'\]$", [[D, None], [M, None]]),
    (r"\['mlp'\]\['down'\]\['w'\]$",      [[M, None], [D, None]]),
    (r"\['mlp'\]\['(up|gate)'\]\['b'\]$", [[M, None]]),
    (r"\['mlp'\]\['down'\]\['b'\]$",      [[None]]),
    # MLP latent
    (r"\['mlp'\]\['(up|gate)_a'\]$",      [[D, None], [None]]),
    (r"\['mlp'\]\['(up|gate)_b'\]$",      [[None], [M, None]]),
    (r"\['mlp'\]\['down_a'\]$",           [[M, None], [None]]),
    (r"\['mlp'\]\['down_b'\]$",           [[None], [D, None]]),
    (r"\['mlp'\]\['(up|gate)_bias'\]$",   [[M, None]]),
    (r"\['mlp'\]\['down_bias'\]$",        [[None]]),
    # MoE (experts on the model axis = EP)
    (r"\['moe'\]\['router'\]$",           [[D, None], [None]]),
    (r"\['moe'\]\['(up|gate)'\]$",        [[M, None], [D, None], [None]]),
    (r"\['moe'\]\['down'\]$",             [[M, None], [None], [D, None]]),
    (r"\['moe'\]\['shared'\]\['(up|gate)'\]\['w'\]$", [[D, None], [M, None]]),
    (r"\['moe'\]\['shared'\]\['down'\]\['w'\]$",      [[M, None], [D, None]]),
    # SSD (mamba2) — dense or factored projections
    (r"\['ssd'\]\['in_proj'\]\['w'\]$",   [[D, None], [M, None]]),
    (r"\['ssd'\]\['in_proj'\]\['a'\]$",   [[D, None], [None]]),
    (r"\['ssd'\]\['in_proj'\]\['b'\]$",   [[None], [M, None]]),
    (r"\['ssd'\]\['out_proj'\]\['w'\]$",  [[M, None], [D, None]]),
    (r"\['ssd'\]\['out_proj'\]\['a'\]$",  [[M, None], [None]]),
    (r"\['ssd'\]\['out_proj'\]\['b'\]$",  [[None], [D, None]]),
    (r"\['ssd'\]\['conv_w'\]$",           [[None], [M, None]]),
    (r"\['ssd'\]\['conv_b'\]$",           [[M, None]]),
    (r"\['ssd'\]\['(A_log|dt_bias|D)'\]$", [[M, None]]),
    # norms & everything else: replicated (caught by fallback)
]
_COMPILED = [(re.compile(rx), prefs) for rx, prefs in _RULES]


def _path_str(path) -> str:
    return "".join(str(k) for k in path)


def param_specs(params_shape, mesh: Mesh):
    """Pytree of PartitionSpec for a (possibly abstract) params tree."""

    def one(path, leaf):
        s = _path_str(path)
        shape = leaf.shape
        for rx, prefs in _COMPILED:
            if rx.search(s):
                nlead = len(shape) - len(prefs)
                full = [[None]] * nlead + list(prefs)
                return spec_from_prefs(mesh, shape, full)
        return P()  # replicate

    return jax.tree_util.tree_map_with_path(one, params_shape)


def opt_specs(opt_state_shape, pspecs, mesh: Mesh):
    """Optimizer state mirrors parameter sharding (moments same shape).

    int8-quantized moments ({'q','scale'} leaves) shard their block dim on
    ('data',) when divisible."""

    def one(path, leaf):
        s = _path_str(path)
        shape = leaf.shape
        quant = s.endswith("['q']") or s.endswith("['scale']")
        if quant:  # int8 moment blocks mirror the param's leading sharding
            s = s[: s.rindex("['")]
        for rx, prefs in _COMPILED:
            if rx.search(s):
                if quant:
                    # shape = param_lead + (nblk, QBLOCK|1): param's last-dim
                    # pref applies to nblk; every dim gets fallback axes so
                    # moments shard SOMEWHERE even when nblk doesn't divide
                    pp = [[a for a in p if a is not None] + [M, D, None]
                          for p in prefs]
                    full = ([[None]] * (len(shape) - len(pp) - 1)
                            + pp[:-1] + [pp[-1], [None]])
                else:
                    full = [[None]] * (len(shape) - len(prefs)) + list(prefs)
                return spec_from_prefs(mesh, shape, full)
        return P()

    return jax.tree_util.tree_map_with_path(one, opt_state_shape)


# ----------------------------------------------------------------------
# batches & caches
# ----------------------------------------------------------------------

def batch_specs(mesh: Mesh, batch_shape: Dict[str, Any]):
    ba = batch_axes(mesh)

    def one(leaf):
        shape = leaf.shape
        prefs = [[ba, D, None]] + [[None]] * (len(shape) - 1)
        return spec_from_prefs(mesh, shape, prefs)

    return jax.tree.map(one, batch_shape)


def cache_specs(mesh: Mesh, cache_shape):
    """KV/state cache: batch on data axes when divisible, else sequence;
    heads/features on 'model' when divisible."""
    ba = batch_axes(mesh)

    def one(path, leaf):
        s = _path_str(path)
        shape = leaf.shape
        if not shape:  # pos scalar
            return P()
        if s.endswith("['k']") or s.endswith("['v']"):
            # (..., B, S, Hkv, Dh). Heads on 'model' when they divide;
            # otherwise shard the SEQUENCE on 'model' (context-parallel
            # decode) — NEVER Dh: a Dh-sharded cache makes the decode
            # scores contraction all-reduce the whole scores tensor
            # (§Perf/C1, measured 199 GB/step on qwen1.5-110b).
            hkv = shape[-2]
            if hkv % _axis_size(mesh, M) == 0:
                prefs = [[None]] * (len(shape) - 4) + [
                    [ba, D, None], [ba, D, None], [M], [None]]
            else:
                prefs = [[None]] * (len(shape) - 4) + [
                    [ba, D, None], [M, ba, D, None], [None], [None]]
            return spec_from_prefs(mesh, shape, prefs)
        if (s.endswith("['c_k']") or s.endswith("['c_v']")
                or s.endswith("['ck_scale']") or s.endswith("['cv_scale']")):
            # (..., B, S, r) latent cache: sequence-sharded (the latent r
            # dim is contracted by the absorbed scores — keep it local).
            # int8 caches carry (..., B, S, 1) fp32 scale columns; they
            # MUST shard exactly like their int8 siblings so the
            # (slot, row) alignment survives any resharding.
            prefs = [[None]] * (len(shape) - 3) + [
                [ba, D, None], [M, ba, D, None], [None]]
            return spec_from_prefs(mesh, shape, prefs)
        if s.endswith("['conv']"):
            prefs = [[None]] * (len(shape) - 3) + [[ba, D, None], [None], [M, None]]
            return spec_from_prefs(mesh, shape, prefs)
        if s.endswith("['ssm']"):
            prefs = [[None]] * (len(shape) - 4) + [
                [ba, D, None], [M, None], [None], [None]]
            return spec_from_prefs(mesh, shape, prefs)
        return P()

    return jax.tree_util.tree_map_with_path(one, cache_shape)


def _ok(mesh, dim, axis):
    return dim % _axis_size(mesh, axis) == 0


# ----------------------------------------------------------------------
# serving engine (repro.serve): arena cache + per-slot step state
# ----------------------------------------------------------------------

_GROUP_IDX = re.compile(r"\['groups'\]\[(\d+)\]")
_TRAIL_IDX = re.compile(r"\['trailing'\]\[(\d+)\]")


def _layout_for(path_str: str, layouts):
    """CacheLayout for a cache-tree leaf path, from the (group, trailing)
    layout lists ``models.transformer.cache_layouts`` builds."""
    if layouts is None:
        return None
    m = _GROUP_IDX.search(path_str)
    if m:
        return layouts[0][int(m.group(1))]
    m = _TRAIL_IDX.search(path_str)
    if m:
        return layouts[1][int(m.group(1))]
    return None


def serve_cache_specs(mesh: Mesh, cache_shape, layouts=None):
    """Specs for the slot-batched serving arena cache.

    Serving layout differs from the training cache rules: the SLOT
    (batch) dim goes on the data axes, heads on 'model' when they
    divide, and the latent ``c_k``/``c_v`` rank dims stay LOCAL — they
    are the contraction dims of the absorbed decode (scores contract
    r_k, the value reduce contracts r_v), so sharding them would
    all-reduce every step. The sequence dim is never sharded either:
    the engine scatters ONE ragged row per slot per step, and a
    sequence-sharded cache turns that scatter into a cross-device
    reshuffle. For RING leaves (sliding-window layers, sequence dim =
    ``min(max_len, window)``) sequence locality is a hard invariant, not
    a preference: the ring write wraps ``pos % cache_len`` per slot, so
    a sequence-sharded ring would bounce every decode write across
    devices — pass the arena's ``layouts`` tree and the rule is
    enforced. The per-slot ragged ``pos`` vector is replicated (it
    feeds every layer's validity mask, ring descriptors, and RoPE
    phase)."""
    ba = batch_axes(mesh)

    def one(path, leaf):
        s = _path_str(path)
        shape = leaf.shape
        if not shape or s.endswith("['pos']"):
            return P()
        if s.endswith("['k']") or s.endswith("['v']"):
            # (..., slots, S, Hkv, Dh)
            prefs = [[None]] * (len(shape) - 4) + [
                [ba, D, None], [None], [M, None], [None]]
            spec = spec_from_prefs(mesh, shape, prefs)
            seq_dim = len(shape) - 3
        elif (s.endswith("['c_k']") or s.endswith("['c_v']")
                or s.endswith("['ck_scale']") or s.endswith("['cv_scale']")):
            # (..., slots, S, r) — rank dim local (absorbed contraction);
            # int8 scale columns (..., slots, S, 1) ride the same rule so
            # they stay slot-aligned with their int8 siblings
            prefs = [[None]] * (len(shape) - 3) + [
                [ba, D, None], [None], [None]]
            spec = spec_from_prefs(mesh, shape, prefs)
            seq_dim = len(shape) - 2
        elif s.endswith("['conv']"):
            prefs = [[None]] * (len(shape) - 3) + [
                [ba, D, None], [None], [M, None]]
            return spec_from_prefs(mesh, shape, prefs)
        elif s.endswith("['ssm']"):
            prefs = [[None]] * (len(shape) - 4) + [
                [ba, D, None], [M, None], [None], [None]]
            return spec_from_prefs(mesh, shape, prefs)
        else:
            return P()
        lay = _layout_for(s, layouts)
        if lay is not None and lay.is_ring and spec[seq_dim] is not None:
            raise ValueError(
                f"ring cache leaf {s} must keep its sequence dim local "
                f"(got {spec}): ring writes wrap per slot")
        return spec

    return jax.tree_util.tree_map_with_path(one, cache_shape)


def engine_state_specs(mesh: Mesh) -> Dict[str, P]:
    """Specs for the engine step's per-slot state rows.

    Every row is (slots,)-shaped host-visible bookkeeping — the fed-back
    token column, per-slot PRNG base keys, fold counters, sampling
    params, and the active mask. They are far below any useful shard
    size and the fused sampling epilogue reads all of them against the
    (replicated-per-data-shard) logits row, so they are REPLICATED.
    The paged engine adds two more replicated rows: per-slot decode
    positions (``pos``) and the (slots, blocks_per_slot) block tables —
    tiny int32 indirection every device needs in full to gather its
    shard of the pool view."""
    del mesh
    return {"tok": P(), "base_keys": P(), "gen_count": P(),
            "temperature": P(), "top_k": P(), "top_p": P(), "active": P(),
            "pos": P(), "block_tables": P()}


def to_named(mesh: Mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))
