"""Fault-tolerant checkpointing: atomic, keep-k, mesh-shape-agnostic.

Format: one msgpack index (tree structure + dtypes + shapes + step
metadata) + raw .npy per leaf. Leaves are written from fully-addressable
host arrays; on restore, arrays are re-placed under ANY mesh whose named
shardings divide the shapes (elastic re-mesh: a checkpoint taken on
2×16×16 restores onto 16×16 or a debug 2×4 mesh unchanged — named-axis
metadata, not device counts, define placement).

Atomicity: write to ``<dir>/tmp.<step>``, fsync, rename to
``<dir>/step_<step>`` — a crash mid-write never corrupts the latest
checkpoint. ``keep`` bounds disk usage.
"""
from __future__ import annotations

import json
import os
import shutil
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

SEP = "/"


def _flatten(tree) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = SEP.join(_key_str(k) for k in path)
        flat[key] = leaf
    return flat


def _key_str(k) -> str:
    if hasattr(k, "key"):
        return f"d:{k.key}"
    if hasattr(k, "idx"):
        return f"i:{k.idx}"
    return f"x:{k}"


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------- save
    def save(self, step: int, tree, extra: Optional[Dict] = None) -> str:
        tmp = os.path.join(self.dir, f"tmp.{step}")
        final = os.path.join(self.dir, f"step_{step:08d}")
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        flat = _flatten(tree)
        index = {"step": step, "extra": extra or {}, "leaves": {}}
        for key, leaf in flat.items():
            arr = np.asarray(jax.device_get(leaf))
            fname = key.replace(SEP, "__") + ".npy"
            dtype_name = str(arr.dtype)
            if arr.dtype.kind not in "fiub" or dtype_name == "bfloat16":
                # non-native dtypes (bfloat16 & friends) stored as raw bits
                arr = arr.view(np.dtype(f"u{arr.dtype.itemsize}"))
            np.save(os.path.join(tmp, fname), arr)
            index["leaves"][key] = {
                "file": fname, "shape": list(arr.shape), "dtype": dtype_name}
        with open(os.path.join(tmp, "index.json"), "w") as f:
            json.dump(index, f)
            f.flush()
            os.fsync(f.fileno())
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        self._gc()
        return final

    def _gc(self):
        steps = self.all_steps()
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)

    # ---------------------------------------------------------- restore
    def all_steps(self) -> List[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_"):
                out.append(int(name[len("step_"):]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, target_tree, step: Optional[int] = None,
                shardings=None) -> Tuple[Any, Dict]:
        """Restore into the STRUCTURE of target_tree (shapes validated).

        ``shardings``: optional matching pytree of NamedSharding — arrays
        are placed shard-by-shard (elastic re-mesh path)."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        path = os.path.join(self.dir, f"step_{step:08d}")
        with open(os.path.join(path, "index.json")) as f:
            index = json.load(f)
        flat_target = _flatten(target_tree)
        shard_flat = _flatten(shardings) if shardings is not None else {}
        out_flat = {}
        for key, ref in flat_target.items():
            meta = index["leaves"].get(key)
            if meta is None:
                raise KeyError(f"checkpoint missing leaf {key}")
            arr = np.load(os.path.join(path, meta["file"]))
            if str(arr.dtype) != meta["dtype"]:  # raw-bit round trip
                import ml_dtypes  # ships with jax
                arr = arr.view(np.dtype(meta["dtype"]))
            if tuple(arr.shape) != tuple(ref.shape):
                raise ValueError(
                    f"shape mismatch for {key}: ckpt {arr.shape} vs "
                    f"target {ref.shape}")
            sh = shard_flat.get(key)
            out_flat[key] = (jax.device_put(arr, sh) if sh is not None
                             else jax.numpy.asarray(arr, dtype=ref.dtype))
        # rebuild the tree in target structure
        leaves_in_order = []
        for p, _ in jax.tree_util.tree_flatten_with_path(target_tree)[0]:
            leaves_in_order.append(out_flat[SEP.join(_key_str(k) for k in p)])
        tree = jax.tree_util.tree_unflatten(
            jax.tree_util.tree_structure(target_tree), leaves_in_order)
        return tree, index["extra"]
