"""Gradient compression for the data-parallel reduction (PowerSGD-style).

Thematically aligned with the paper: gradients of 2-D weights are
approximated low-rank (G ≈ P Qᵀ) before the cross-replica reduction, with
error feedback so the bias is compensated over steps. On a real multi-pod
deployment the launcher reduces (P, Q) across the 'pod' axis instead of
the dense gradient — an O(rank·(m+n)/(m·n)) bandwidth saving recorded in
the roofline's collective term. Also provides int8 stochastic-rounding
quantization as a cheaper alternative.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class GradCompressionConfig:
    method: str = "powersgd"  # powersgd | int8 | none
    rank: int = 8
    min_size: int = 65536  # don't compress small tensors


def init_state(params, cfg: GradCompressionConfig) -> Dict[str, Any]:
    """Error-feedback residuals + warm-start Q factors."""

    def leaf(p):
        if cfg.method != "powersgd" or p.ndim < 2 or p.size < cfg.min_size:
            return None
        m, n = p.shape[-2], p.shape[-1]
        lead = p.shape[:-2]
        key = jax.random.PRNGKey(hash(p.shape) % (2 ** 31))
        return {
            "err": jnp.zeros(p.shape, jnp.float32),
            "q": jax.random.normal(key, lead + (n, cfg.rank), jnp.float32),
        }

    return jax.tree.map(leaf, params, is_leaf=lambda x: x is None)


def compress_decompress(grads, state, cfg: GradCompressionConfig):
    """Returns (approx_grads, new_state, stats).

    approx_grads is what a bandwidth-limited reduction would deliver;
    applying it keeps training semantics identical to the deployed system."""
    if cfg.method == "none":
        return grads, state, {"compressed_bytes": 0, "dense_bytes": 0}
    dense_bytes = 0
    comp_bytes = 0

    def leaf(g, s):
        nonlocal dense_bytes, comp_bytes
        g32 = g.astype(jnp.float32)
        dense_bytes += g.size * 4
        if cfg.method == "int8":
            comp_bytes += g.size + g.size // 256 * 4
            scale = jnp.max(jnp.abs(g32)) / 127.0 + 1e-12
            q = jnp.round(g32 / scale).astype(jnp.int8)
            return q.astype(jnp.float32) * scale, s
        if s is None:  # too small / not 2D: sent dense
            comp_bytes += g.size * 4
            return g, s
        work = g32 + s["err"]
        # single power iteration: P = G Q; orthonormalize; Q = Gᵀ P
        p = work @ s["q"]
        p, _ = jnp.linalg.qr(p)
        q = jnp.swapaxes(work, -1, -2) @ p
        approx = p @ jnp.swapaxes(q, -1, -2)
        comp_bytes += (p.size + q.size) * 4
        new_s = {"err": work - approx, "q": q}
        return approx.astype(g.dtype), new_s

    flat_g, treedef = jax.tree.flatten(grads)
    flat_s = treedef.flatten_up_to(state) if state is not None else [None] * len(flat_g)
    out = [leaf(g, s) for g, s in zip(flat_g, flat_s)]
    new_g = treedef.unflatten([o[0] for o in out])
    new_s = treedef.unflatten([o[1] for o in out])
    return new_g, new_s, {"compressed_bytes": comp_bytes, "dense_bytes": dense_bytes}
