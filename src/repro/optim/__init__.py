from repro.optim.adamw import AdamW, AdamWConfig
from repro.optim.compression import (GradCompressionConfig, compress_decompress,
                                     init_state as init_compression_state)

__all__ = ["AdamW", "AdamWConfig", "GradCompressionConfig",
           "compress_decompress", "init_compression_state"]
