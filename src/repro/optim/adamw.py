"""AdamW in pure JAX with distributed-scale options:

- moments dtype: fp32 | bf16 | int8 (blockwise-quantized, 8-bit-Adam style)
- global-norm gradient clipping
- linear-warmup + cosine-decay schedule
- weight decay decoupled (AdamW)

State is a pytree mirroring params, so it shards with the same
NamedShardings (FSDP over 'data' in the production mesh).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

QBLOCK = 256  # block size for int8 moment quantization


def quantizable(shape) -> bool:
    """int8 moments only for tensors whose LAST dim splits into QBLOCK
    blocks — blocking the last axis keeps the leading dims (and therefore
    the FSDP/TP sharding) intact; odd/small tensors stay fp32."""
    return len(shape) >= 1 and shape[-1] % QBLOCK == 0 and shape[-1] >= QBLOCK


def _quantize_blockwise(x: jax.Array):
    """int8 blockwise quantization along the last dim (sharding-preserving)."""
    *lead, n = x.shape
    blocks = x.reshape(*lead, n // QBLOCK, QBLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=-1, keepdims=True) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def _dequantize_blockwise(q: jax.Array, scale: jax.Array, shape):
    return (q.astype(jnp.float32) * scale).reshape(shape)


_V_FLOOR = 1e-16


def _quantize_v(v: jax.Array):
    """Second moments span ~10 orders of magnitude — linear int8 diverges
    (verified in tests). Quantize log(v) instead: the error becomes a
    bounded MULTIPLICATIVE factor on the Adam denominator (8-bit-Adam's
    dynamic-map trick, log-space variant)."""
    return _quantize_blockwise(jnp.log(v + _V_FLOOR))


def _dequantize_v(q: jax.Array, scale: jax.Array, shape):
    return jnp.exp(_dequantize_blockwise(q, scale, shape)) - _V_FLOOR


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: Optional[float] = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    moments_dtype: str = "float32"  # float32 | bfloat16 | int8


class AdamW:
    def __init__(self, cfg: AdamWConfig = AdamWConfig()):
        self.cfg = cfg

    # -------------------------------------------------------------- sched
    def lr_at(self, step: jax.Array) -> jax.Array:
        c = self.cfg
        step = step.astype(jnp.float32)
        warm = jnp.minimum((step + 1.0) / max(c.warmup_steps, 1), 1.0)
        prog = jnp.clip((step - c.warmup_steps)
                        / max(c.total_steps - c.warmup_steps, 1), 0.0, 1.0)
        cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return c.lr * warm * (c.min_lr_ratio + (1 - c.min_lr_ratio) * cos)

    # -------------------------------------------------------------- state
    def init(self, params) -> Dict[str, Any]:
        c = self.cfg

        def zeros_like_moment(p, is_v=False):
            if c.moments_dtype == "int8" and quantizable(p.shape):
                lead = p.shape[:-1]
                nblk = p.shape[-1] // QBLOCK
                if is_v:  # v stored in log space: encode v = 0 exactly
                    logz = float(np.log(_V_FLOOR))
                    return {"q": jnp.full(lead + (nblk, QBLOCK), -127, jnp.int8),
                            "scale": jnp.full(lead + (nblk, 1), -logz / 127.0,
                                              jnp.float32)}
                return {"q": jnp.zeros(lead + (nblk, QBLOCK), jnp.int8),
                        "scale": jnp.zeros(lead + (nblk, 1), jnp.float32)}
            dt = jnp.bfloat16 if c.moments_dtype == "bfloat16" else jnp.float32
            return jnp.zeros(p.shape, dt)

        return {
            "m": jax.tree.map(zeros_like_moment, params),
            "v": jax.tree.map(lambda p: zeros_like_moment(p, True), params),
        }

    # -------------------------------------------------------------- update
    def update(self, grads, state, params, step):
        c = self.cfg
        if c.clip_norm:
            gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                                 for g in jax.tree.leaves(grads)))
            scale = jnp.minimum(1.0, c.clip_norm / (gnorm + 1e-9))
            grads = jax.tree.map(lambda g: g * scale, grads)
        lr = self.lr_at(step)
        t = step.astype(jnp.float32) + 1.0
        bc1 = 1.0 - c.b1 ** t
        bc2 = 1.0 - c.b2 ** t

        def upd(p, g, m, v):
            g = g.astype(jnp.float32)
            quantized = isinstance(m, dict)
            if quantized:
                m_f = _dequantize_blockwise(m["q"], m["scale"], p.shape)
                v_f = _dequantize_v(v["q"], v["scale"], p.shape)
            else:
                m_f, v_f = m.astype(jnp.float32), v.astype(jnp.float32)
            m_f = c.b1 * m_f + (1 - c.b1) * g
            v_f = c.b2 * v_f + (1 - c.b2) * jnp.square(g)
            upd_ = (m_f / bc1) / (jnp.sqrt(v_f / bc2) + c.eps)
            new_p = p - lr * (upd_ + c.weight_decay * p)
            if quantized:
                qm, sm = _quantize_blockwise(m_f)
                qv, sv = _quantize_v(jnp.maximum(v_f, 0.0))
                return new_p, {"q": qm, "scale": sm}, {"q": qv, "scale": sv}
            dt = jnp.bfloat16 if c.moments_dtype == "bfloat16" else jnp.float32
            return new_p, m_f.astype(dt), v_f.astype(dt)

        def upd_leaf(p, g, m, v):
            # scan-over-layers leaves are stacked (L, ...); lax.map over the
            # stack keeps the fp32 dequant/update working set to ONE layer
            # instead of L layers (critical for int8 moments at 400B scale)
            if p.ndim >= 3 and p.shape[0] > 1 and p.size > (1 << 22):
                return jax.lax.map(lambda a: upd(*a), (p, g, m, v))
            return upd(p, g, m, v)

        flat_p, treedef = jax.tree.flatten(params)
        flat_g = treedef.flatten_up_to(grads)
        flat_m = treedef.flatten_up_to(state["m"])
        flat_v = treedef.flatten_up_to(state["v"])
        out = [upd_leaf(p, g, m, v)
               for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
        new_p = treedef.unflatten([o[0] for o in out])
        new_m = treedef.unflatten([o[1] for o in out])
        new_v = treedef.unflatten([o[2] for o in out])
        return new_p, {"m": new_m, "v": new_v}
